"""Control-plane overhead (paper §4.2 reports <10% of one vCPU and <200 MB
for the proxy): decision throughput of the scalar proxy event path and the
vectorized fleet controller (decisions/second)."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MLProxy, MonitorConfig, OptimizerConfig, ProxyConfig, Request, SLAConfig
from repro.core import jax_controller as jc

from benchmarks.common import write_csv


def scalar_proxy_throughput(n_events: int = 50_000, tracer=None) -> float:
    cfg = ProxyConfig(
        sla=SLAConfig(slo_target=0.5),
        monitor=MonitorConfig(min_samples=1),
        optimizer=OptimizerConfig(initial_max_bs=8),
    )
    sink: List = []
    proxy = MLProxy(cfg, dispatch_fn=sink.append, tracer=tracer)
    for bs in range(1, 12):
        proxy.monitor.record_upstream(bs, 0.05, now=0.0)
    t0 = time.perf_counter()
    t = 0.0
    for i in range(n_events):
        t += 0.001
        proxy.on_request(Request(arrival_time=t), now=t)
        if sink:
            batch = sink.pop()
            proxy.on_response(batch, 0.05, now=t + 0.05)
    dt = time.perf_counter() - t0
    return n_events / dt


def tracing_overhead(n_events: int, trials: int = 5) -> Tuple[float, float, float]:
    """(base/s, traced/s, overhead %) of span tracing on the decision loop.

    Sandwich design: each trial runs base, traced, base back-to-back and
    the per-trial overhead is the traced run against the *mean* of its
    two base neighbours — drift that is locally linear in time cancels.
    The reported overhead is the MINIMUM across trials: this is an
    upper-bound smoke gate, and interference from a shared machine (CI
    runners, co-tenant load) only ever adds time to whichever window it
    lands in, so the cleanest trial is the most faithful estimate of the
    instrumentation's intrinsic cost. The obs-smoke CI gate asserts the
    result <= 10%.
    """
    from repro.obs import Tracer

    best = None
    for _ in range(trials):
        b1 = scalar_proxy_throughput(n_events)
        t = scalar_proxy_throughput(n_events, tracer=Tracer())
        b2 = scalar_proxy_throughput(n_events)
        b = (b1 + b2) / 2.0
        ratio = 100.0 * (b - t) / b
        if best is None or ratio < best[2]:
            best = (b, t, ratio)
    return best


def latency_window_throughput(n_ops: int = 200_000) -> float:
    """add+percentile pairs/sec on one LatencyWindow — the scheduler does
    exactly this pair on every arrival (Algorithm 1's RT95 probe), so this
    is the unit cost the sorted-cache optimization targets."""
    from repro.core.monitor import LatencyWindow

    win = LatencyWindow(maxlen=256, horizon=120.0)
    lats = np.random.default_rng(0).random(n_ops) * 0.2
    t0 = time.perf_counter()
    t = 0.0
    for i in range(n_ops):
        t += 0.001
        win.add(t, float(lats[i]))
        win.percentile(95.0, now=t, outlier_mult=5.0)
    dt = time.perf_counter() - t0
    return n_ops / dt


def fleet_controller_throughput(n_endpoints: int = 4096,
                                iters: int = 50) -> float:
    state = jc.init_fleet(n_endpoints, n_buckets=16, window=64)
    slo = jnp.full((n_endpoints,), 0.5, jnp.float32)
    qlen = jnp.ones((n_endpoints,), jnp.int32)
    frt = jnp.zeros((n_endpoints,), jnp.float32)
    # warm up compile
    jc.timeout_step(state, qlen, frt, slo)[0].block_until_ready()
    s2 = jc.aimd_step(state, slo)
    jax.block_until_ready(s2.max_bs)
    t0 = time.perf_counter()
    for _ in range(iters):
        d, to = jc.timeout_step(state, qlen, frt, slo)
        state = jc.aimd_step(state, slo)
    jax.block_until_ready((d, to, state.max_bs))
    dt = time.perf_counter() - t0
    return n_endpoints * iters / dt


def run(quick: bool = False) -> List[Dict]:
    n = 20_000 if quick else 50_000
    base, traced, overhead_pct = tracing_overhead(n)
    rows = [
        {"metric": "scalar_proxy_decisions_per_s", "value": round(base)},
        {"metric": "latency_window_add_percentile_per_s",
         "value": round(latency_window_throughput(40_000 if quick else 200_000))},
        {"metric": "fleet_controller_endpoint_updates_per_s",
         "value": round(fleet_controller_throughput(1024 if quick else 4096,
                                                    10 if quick else 50))},
        {"metric": "scalar_proxy_decisions_per_s_traced",
         "value": round(traced)},
        {"metric": "tracing_overhead_pct", "value": round(overhead_pct, 2)},
    ]
    write_csv("proxy_overhead.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
