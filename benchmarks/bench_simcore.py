"""Event-core throughput: how fast the discrete-event simulator itself runs.

Headline numbers are requests/sec and events/sec of wall time on two
configurations:

* ``poisson_1m`` — a single-endpoint MLProxy pipeline fed 1M Poisson
  arrivals (the scale every policy x workload x SLO sweep cell runs at).
* ``multi_chaos`` — a multi-endpoint shared-fleet configuration with fault
  injection (crashes, stragglers, hedging), i.e. the chaos-suite hot path.

Every run ends by asserting the platform conservation invariant — the
speedups must never come at the cost of lost or duplicated work.
"""
from __future__ import annotations

import math
from typing import Dict, List

from benchmarks.common import Timer, write_csv

from repro.core import SLAConfig
from repro.serverless.latency import get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import MMPP2, PoissonProcess
from repro.simulation.simulator import (
    EndpointSpec,
    MultiEndpointSimulator,
    Simulator,
)

# ~2500 req/s for 400 s => 1M requests (quick: 50k)
POISSON_RATE = 2500.0
POISSON_DURATION = 400.0
POISSON_DURATION_QUICK = 20.0

CHAOS_PLATFORM = PlatformConfig(
    initial_scale=2,
    container_concurrency=4,
    ps_slowdown=0.25,
    failure_prob_per_batch=0.05,
    straggler_prob=0.05,
    straggler_mult=8.0,
    hedge_factor=3.0,
    max_hedges=1,
)


def _row(case: str, sim, completed: float, wall: float,
         lost: float, duplicates: float) -> Dict:
    events = float(getattr(sim, "events_processed", math.nan))
    return {
        "case": case,
        "requests": int(completed),
        "wall_s": round(wall, 3),
        "req_per_s": round(completed / wall, 1),
        "events": events if math.isnan(events) else int(events),
        "events_per_s": (math.nan if math.isnan(events)
                         else round(events / wall, 1)),
        "lost": int(lost),
        "duplicates": int(duplicates),
    }


def poisson_1m(quick: bool = False) -> Dict:
    duration = POISSON_DURATION_QUICK if quick else POISSON_DURATION
    sim = Simulator(
        policy="mlproxy",
        sla=SLAConfig(slo_target=0.5),
        workload=get_workload("sklearn-iris"),
        arrivals=PoissonProcess(rate=POISSON_RATE, duration=duration),
        platform_config=PlatformConfig(initial_scale=4),
        duration=duration,
        drain_grace=60.0,
        seed=42,
    )
    with Timer() as t:
        res = sim.run()
    sim.platform.assert_conserved(require_drained=True)
    s = res.summary
    return _row("poisson_1m", sim, s["completed"], t.seconds,
                s["lost_batches"], s["duplicate_completions"])


def multi_chaos(quick: bool = False) -> Dict:
    duration = 30.0 if quick else 120.0
    spec = dict(
        sla=SLAConfig(slo_target=0.5),
        platform="shared",
        platform_config=CHAOS_PLATFORM,
    )
    sim = MultiEndpointSimulator(
        {
            "iris": EndpointSpec(
                policy="mlproxy",
                workload=get_workload("sklearn-iris"),
                arrivals=PoissonProcess(rate=300.0, duration=duration),
                **spec,
            ),
            "toxic": EndpointSpec(
                policy="clipper",
                workload=get_workload("keras-toxic"),
                arrivals=MMPP2(rate_lo=40.0, rate_hi=160.0, mean_lo=20.0,
                               mean_hi=10.0, duration=duration),
                **spec,
            ),
        },
        duration=duration,
        drain_grace=120.0,
        seed=42,
    )
    with Timer() as t:
        res = sim.run()
    for plat in sim.platforms.values():
        plat.assert_conserved(require_drained=True)
    s = res.summary
    return _row("multi_chaos", sim, s["completed"], t.seconds,
                s["lost_batches"], s["duplicate_completions"])


def run(quick: bool = False) -> List[Dict]:
    rows = [poisson_1m(quick=quick), multi_chaos(quick=quick)]
    write_csv("simcore.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
