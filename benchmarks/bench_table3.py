"""Table 3: the paper's 12 experiments — trace × workload × max-RPS × SLO,
with MLProxy off (stock gateway) and on, reporting average containers
(cost), SLO-violation %, and average batch size, next to the paper's
published numbers for validation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import SLAConfig, ms
from repro.serverless.latency import get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import TraceModulatedPoisson
from repro.simulation.simulator import run_simulation
from repro.simulation.traces import synthetic_trace

from benchmarks.common import write_csv


@dataclasses.dataclass(frozen=True)
class Experiment:
    idx: int
    workload: str
    trace: str
    max_rps: float
    slo_ms: float
    # paper-reported values (Table 3) for validation
    paper_cont: float
    paper_cont_proxy: float
    paper_viol: float
    paper_viol_proxy: float
    paper_avg_bs: float


EXPERIMENTS = [
    Experiment(1, "pytorch-fashion-mnist", "wc", 30, 500, 2.73, 1.00, 1.2799, 0.1861, 4.93),
    Experiment(2, "pytorch-fashion-mnist", "wc", 100, 1000, 8.75, 1.01, 26.0048, 0.0767, 10.93),
    Experiment(3, "sklearn-iris", "wc", 50, 500, 1.61, 1.00, 0.8892, 0.0033, 5.01),
    Experiment(4, "sklearn-iris", "wc", 185, 200, 1.50, 1.01, 0.2862, 0.0395, 6.57),
    Experiment(5, "keras-toxic", "wc", 30, 500, 1.90, 1.00, 0.4181, 0.0811, 3.09),
    Experiment(6, "pytorch-fashion-mnist", "t5", 30, 500, 4.28, 1.00, 1.9688, 0.1002, 9.81),
    Experiment(7, "sklearn-iris", "t5", 185, 500, 3.01, 1.00, 0.6675, 0.0059, 18.95),
    Experiment(8, "sklearn-iris", "t5", 185, 200, 3.01, 1.00, 0.7064, 0.0019, 11.00),
    Experiment(9, "keras-toxic", "t5", 50, 500, 3.87, 1.00, 0.4771, 0.0553, 7.71),
    Experiment(10, "pytorch-fashion-mnist", "t4", 100, 1000, 13.34, 1.07, 39.9915, 0.0038, 13.34),
    Experiment(11, "sklearn-iris", "t4", 185, 200, 1.93, 1.00, 0.5361, 0.0295, 13.06),
    Experiment(12, "keras-toxic", "t4", 50, 500, 3.12, 1.00, 0.4737, 0.0405, 6.12),
]


def run_experiment(exp: Experiment, duration: float = 1800.0,
                   warmup: float = 300.0, seed: int = 0) -> Dict:
    sla = SLAConfig(slo_target=ms(exp.slo_ms))
    wl = get_workload(exp.workload)
    # paper cluster: 27 vCPUs for pods (Table 1); ML containers take ~10 s
    # to become ready (framework + model load)
    pc = PlatformConfig(initial_scale=1, max_scale=27, cold_start=10.0)
    out: Dict = {
        "exp": exp.idx, "workload": exp.workload, "trace": exp.trace,
        "max_rps": exp.max_rps, "slo_ms": exp.slo_ms,
    }
    for policy, tag in (("passthrough", ""), ("mlproxy", "_proxy")):
        trace = synthetic_trace(exp.trace, duration=duration, seed=seed
                                ).scaled(exp.max_rps)
        res = run_simulation(
            policy=policy, sla=sla, workload=wl,
            arrivals=TraceModulatedPoisson(trace), platform_config=pc,
            duration=duration, warmup=warmup, seed=seed + exp.idx,
        )
        s = res.summary
        out[f"containers{tag}"] = round(s["avg_containers"], 3)
        out[f"viol_pct{tag}"] = round(s["violation_pct"], 4)
        out[f"avg_bs{tag}"] = round(s["avg_batch_size"], 2)
        out[f"p95_ms{tag}"] = round(s["p95"] * 1000, 1)
    out["cont_reduction_pct"] = round(
        100 * (1 - out["containers_proxy"] / max(out["containers"], 1e-9)), 1)
    # violation reduction is only meaningful when the baseline violates
    out["viol_reduction_pct"] = (
        round(100 * (1 - out["viol_pct_proxy"] / out["viol_pct"]), 1)
        if out["viol_pct"] > 0.05 else "")
    out["paper_cont"] = exp.paper_cont
    out["paper_cont_proxy"] = exp.paper_cont_proxy
    out["paper_viol"] = exp.paper_viol
    out["paper_viol_proxy"] = exp.paper_viol_proxy
    out["paper_avg_bs"] = exp.paper_avg_bs
    return out


def run(quick: bool = False) -> List[Dict]:
    duration = 600.0 if quick else 1800.0
    warmup = 150.0 if quick else 300.0
    rows = [run_experiment(e, duration=duration, warmup=warmup)
            for e in EXPERIMENTS]
    write_csv("table3_experiments.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"#{r['exp']:2d} {r['workload']:22s} {r['trace']:3s} "
              f"cont {r['containers']:6.2f}→{r['containers_proxy']:5.2f} "
              f"(paper {r['paper_cont']:5.2f}→{r['paper_cont_proxy']:4.2f}) "
              f"viol% {r['viol_pct']:7.3f}→{r['viol_pct_proxy']:6.3f} "
              f"BS {r['avg_bs_proxy']:5.2f} (paper {r['paper_avg_bs']:5.2f})")
