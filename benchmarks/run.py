"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract:
``us_per_call`` is the wall-time of producing the artifact;
``derived`` is the benchmark's headline number.

Bench modules are imported lazily so ``--only <name>`` (e.g. the CI
perf-smoke step running ``--only simcore``) does not pay for unrelated
imports (the JAX-backed benches in particular).
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import sys

# name -> (module under benchmarks/ — optionally "module:attr" for an
# entry point other than ``run`` — and derive(rows) -> headline)
BENCHES = {
    "fig3_fig4_batch_scaling": (
        "bench_batch_scaling",
        lambda rows: min(r["relative_per_inference"] for r in rows
                         if r["batch_size"] == 16
                         and "linear" not in r["workload"])),
    "table3_experiments": (
        "bench_table3",
        lambda rows: sum(r["cont_reduction_pct"] for r in rows) / len(rows)),
    "fig6_ccdf": ("bench_ccdf", lambda rows: len(rows)),
    "fig7_timeseries": ("bench_timeseries", lambda rows: len(rows)),
    "policy_comparison": (
        "bench_policies",
        lambda rows: min(r["containers"] for r in rows if not r["faults"])),
    "proxy_overhead": (
        "bench_proxy_overhead", lambda rows: rows[0]["value"]),
    "multi_endpoint": (
        "bench_multi_endpoint",
        lambda rows: min(r["containers_total"] for r in rows
                         if r["policy"] == "mlproxy")),
    # derived = conservation violations across the whole sweep; any
    # value other than 0.0 means the platform lost or duplicated work
    "chaos_scenarios": (
        "bench_chaos",
        lambda rows: sum(r["lost"] + r["duplicates"] for r in rows)),
    # live-runtime half of the chaos sweep on its own (the CI
    # runtime-chaos-smoke job runs exactly this); derived = conservation
    # violations — anything other than 0.0 means the retry/breaker layer
    # lost or duplicated work under fault injection
    "chaos_live": (
        "bench_chaos:run_live",
        lambda rows: sum(r["lost"] + r["duplicates"] for r in rows)),
    # event-core throughput: derived = requests/sec on the 1M-request
    # Poisson configuration (the scale target every sweep cell runs at)
    "simcore": (
        "bench_simcore",
        lambda rows: max(r["req_per_s"] for r in rows
                         if r["case"] == "poisson_1m")),
    # parallel policy x scenario grid; derived = conservation violations
    # across every cell (0.0 or the sweep is broken)
    "policy_sweep": (
        "sweep",
        lambda rows: sum(r["lost"] + r["duplicates"] for r in rows)),
    # sim vs live-runtime agreement; derived = worst mlproxy delta (%)
    # across RT95 and the dispatched-batches cost proxy
    "live_parity": (
        "bench_live_parity",
        lambda rows: max(max(r["rt95_delta_pct"], r["batches_delta_pct"])
                         for r in rows
                         if r["kind"] == "parity" and r["policy"] == "mlproxy")),
    # deadline tightness x policy x hedge sweep in both worlds; derived =
    # conservation violations across every cell (0.0 or the deadline
    # ledger is broken somewhere)
    "deadlines": (
        "bench_deadlines",
        lambda rows: sum(r["violations"] for r in rows)),
    # observability plane: span waterfalls (both worlds), tracing-off
    # identity, tracing-on overhead, flight-recorder postmortem; derived =
    # tracing overhead % when every identity/flightrec gate passes, else -1
    "obs": (
        "bench_obs",
        lambda rows: (
            next(r["overhead_pct"] for r in rows if r["kind"] == "overhead")
            if (all(r["identical"] for r in rows if r["kind"] == "identity")
                and all(r["parseable"] for r in rows
                        if r["kind"] == "flightrec"))
            else -1.0)),
    # fleet tiers: cost at equal SLA for spillover routing vs a single
    # homogeneous fleet; derived = scenarios (of 5) where a spillover
    # fleet meets the single fleet's SLA at strictly lower weighted cost
    # (-1 if any cell lost work or a 1-tier run was not byte-identical
    # to the untiered fleet)
    "tiers": (
        "bench_tiers",
        lambda rows: __import__(
            "benchmarks.bench_tiers", fromlist=["spillover_wins"]
        ).spillover_wins(rows)),
    # JAX data plane: fused decode loop vs per-token reference + packing
    # cost at equal SLA; derived = fused speedup on the best
    # decode-dominated config (0 if ANY bucket's outputs diverge from the
    # per-token reference loop)
    "engine": (
        "bench_engine",
        lambda rows: (max(r["speedup"] for r in rows if r["kind"] == "decode")
                      if all(r["bit_identical"] for r in rows
                             if r["kind"] == "decode") else 0.0)),
}


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="shorter simulations (CI-scale)")
    p.add_argument("--only", default=None, help="run a single benchmark")
    p.add_argument("--jobs", type=int, default=1,
                   help="worker processes for benches that fan out "
                        "(currently: policy_sweep)")
    args = p.parse_args()

    from benchmarks.common import Timer

    print("name,us_per_call,derived")
    for name, (module, derive) in BENCHES.items():
        if args.only and args.only != name:
            continue
        mod_name, _, attr = module.partition(":")
        fn = getattr(importlib.import_module(f"benchmarks.{mod_name}"),
                     attr or "run")
        kwargs = {"quick": args.quick}
        if "jobs" in inspect.signature(fn).parameters:
            kwargs["jobs"] = args.jobs
        with Timer() as t:
            rows = fn(**kwargs)
        try:
            derived = derive(rows)
        except Exception:
            derived = float("nan")
        print(f"{name},{t.seconds*1e6:.0f},{derived}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
