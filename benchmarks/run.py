"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract:
``us_per_call`` is the wall-time of producing the artifact;
``derived`` is the benchmark's headline number.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="shorter simulations (CI-scale)")
    p.add_argument("--only", default=None, help="run a single benchmark")
    args = p.parse_args()

    from benchmarks.common import Timer
    from benchmarks import (bench_batch_scaling, bench_ccdf, bench_chaos,
                            bench_multi_endpoint, bench_policies,
                            bench_proxy_overhead, bench_table3,
                            bench_timeseries)

    benches = {
        "fig3_fig4_batch_scaling": (
            bench_batch_scaling.run,
            lambda rows: min(r["relative_per_inference"] for r in rows
                             if r["batch_size"] == 16
                             and "linear" not in r["workload"])),
        "table3_experiments": (
            bench_table3.run,
            lambda rows: sum(r["cont_reduction_pct"] for r in rows) / len(rows)),
        "fig6_ccdf": (bench_ccdf.run, lambda rows: len(rows)),
        "fig7_timeseries": (bench_timeseries.run, lambda rows: len(rows)),
        "policy_comparison": (
            bench_policies.run,
            lambda rows: min(r["containers"] for r in rows if not r["faults"])),
        "proxy_overhead": (
            bench_proxy_overhead.run, lambda rows: rows[0]["value"]),
        "multi_endpoint": (
            bench_multi_endpoint.run,
            lambda rows: min(r["containers_total"] for r in rows
                             if r["policy"] == "mlproxy")),
        # derived = conservation violations across the whole sweep; any
        # value other than 0.0 means the platform lost or duplicated work
        "chaos_scenarios": (
            bench_chaos.run,
            lambda rows: sum(r["lost"] + r["duplicates"] for r in rows)),
    }
    print("name,us_per_call,derived")
    for name, (fn, derive) in benches.items():
        if args.only and args.only != name:
            continue
        with Timer() as t:
            rows = fn(quick=args.quick)
        try:
            derived = derive(rows)
        except Exception:
            derived = float("nan")
        print(f"{name},{t.seconds*1e6:.0f},{derived}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
