"""Fleet-tier economics: cost at equal SLA for spillover routing.

For every chaos scenario the sweep runs three fleets through the
multi-endpoint simulator under the ``mlproxy`` policy:

* **single** — today's homogeneous fleet (weight-1.0 containers), the
  untiered reference;
* **cheap+fast** — a discounted slower instance family preferred by the
  :class:`~repro.core.frontend.SpilloverRouter`, spilling to full-price
  full-speed containers when the cheap tier's in-flight / queue-depth
  guards trip;
* **spot+od** — deeply discounted *preemptible* capacity (containers are
  reclaimed mid-batch with probability ``preempt_prob`` per attempt and
  the victims requeue through the attempt ledger) backed by on-demand
  containers.

Per cell the per-tier AND aggregate conservation ledgers are asserted
(zero lost, zero duplicated; ``violations`` must sum to 0 across the
sweep). ``kind="identity"`` rows check the degenerate case for every
policy: a 1-tier ``TieredPlatform`` run must be **byte-identical** to
the untiered single fleet (summary, per-endpoint stats, and every e2e
latency) — the tier layer must cost nothing when unused.

Headline (``spillover_wins``): in how many of the five scenarios does
the best spillover fleet meet the single fleet's SLA (violation rate
within ``SLA_EPS_PCT``) at strictly lower weighted cost.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from experiments.scenarios import (
    POLICIES,
    SCENARIOS,
    ChaosScenario,
    make_arrivals,
)
from repro.core import SLAConfig, ms
from repro.core.request import reset_request_ids
from repro.serverless.latency import get_workload
from repro.serverless.tiers import TierSpec
from repro.simulation.simulator import EndpointSpec, run_multi_simulation

from benchmarks.common import write_csv

#: Tolerance (percentage points of violation rate) within which a
#: spillover fleet counts as "meeting the same SLA" as the single fleet.
SLA_EPS_PCT = 0.5

#: Cheap-slow tier: a discounted instance family at ~55% of the on-demand
#: price running ~10% slower — the sub-linear price/perf gap real cloud
#: instance generations exhibit. Guards keep the tier from drowning:
#: spill once 16 batches are in flight or its backend queue backs up.
CHEAP_FAST: Tuple[TierSpec, ...] = (
    TierSpec(name="cheap", cost_weight=0.55, latency_scale=1.10,
             max_inflight=16, queue_depth_max=8),
    TierSpec(name="fast", cost_weight=1.0),
)

#: Spot + on-demand: spot at 40% of the on-demand price but preemptible
#: (3% of attempts lose their container mid-batch and requeue).
SPOT_OD: Tuple[TierSpec, ...] = (
    TierSpec(name="spot", cost_weight=0.4, preemptible=True,
             preempt_prob=0.03, max_inflight=16, queue_depth_max=8),
    TierSpec(name="ondemand", cost_weight=1.0),
)

FLEETS: Dict[str, Optional[Tuple[TierSpec, ...]]] = {
    "single": None,
    "cheap+fast": CHEAP_FAST,
    "spot+od": SPOT_OD,
}

#: Degenerate fleet for the identity rows: one weight-1.0 tier, no
#: guards, no preemption — must change *nothing*.
ONE_TIER: Tuple[TierSpec, ...] = (TierSpec(name="only"),)


def _run_cell(sc: ChaosScenario, policy: str,
              tiers: Optional[Tuple[TierSpec, ...]], quick: bool):
    duration = max(120.0, sc.duration * 0.25) if quick else sc.duration
    workload = get_workload(sc.workload)
    policy_kwargs: dict = {}
    if policy == "static":
        policy_kwargs = {"batch_size": 8, "timeout": 0.2}
    elif policy == "oracle":
        policy_kwargs = {
            "latency_model": lambda bs, _w=workload: _w.percentile(bs, 95)
        }
    reset_request_ids()
    return run_multi_simulation(
        {
            "ep": EndpointSpec(
                policy=policy,
                sla=SLAConfig(slo_target=ms(sc.slo_ms)),
                workload=workload,
                arrivals=make_arrivals(sc, duration),
                policy_kwargs=policy_kwargs,
                platform_config=sc.platform,
                tiers=tiers,
            )
        },
        duration=duration,
        drain_grace=sc.drain_grace,
        seed=sc.seed,
    )


def _violations(res) -> int:
    """Conservation violations in one cell: lost or duplicated batches,
    per tier and in aggregate, plus leaked router in-flight slots."""
    v = int(res.summary["lost_batches"] + res.summary["duplicate_completions"])
    for tiers in res.tiers.values():
        for t in tiers.values():
            v += int(t["submitted_batches"] - t["completed_batches"])
    for r in res.routers.values():
        v += int(sum(r["inflight"].values()))
    return v


def _identity_rows(quick: bool) -> List[Dict]:
    """1-tier TieredPlatform vs untiered fleet, every policy: byte-equal."""
    sc = SCENARIOS["crash-storm"]
    rows: List[Dict] = []
    for policy in POLICIES:
        plain = _run_cell(sc, policy, None, quick)
        tiered = _run_cell(sc, policy, ONE_TIER, quick)
        identical = (
            tiered.summary == plain.summary
            and tiered.endpoints == plain.endpoints
            and all(
                np.array_equal(tiered.e2e_latencies[k], plain.e2e_latencies[k])
                for k in plain.e2e_latencies
            )
        )
        rows.append({
            "kind": "identity",
            "scenario": sc.name,
            "policy": policy,
            "fleet": "1tier",
            "identical": identical,
            "completed": plain.summary["completed"],
            "violations": _violations(plain) + _violations(tiered),
            "viol_pct": round(tiered.summary["violation_pct"], 4),
            "weighted_cost": round(
                tiered.summary["weighted_cost"], 6),
            "cost_delta_pct": round(
                100.0 * (tiered.summary["weighted_cost"]
                         - plain.summary["weighted_cost"])
                / plain.summary["weighted_cost"]
                if plain.summary["weighted_cost"] else 0.0, 6),
            "spillover_pct": 0.0,
            "preemptions": int(tiered.summary["preemptions"]),
        })
    return rows


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = _identity_rows(quick)
    for name, sc in SCENARIOS.items():
        ref = None  # the single-fleet cell this scenario is judged against
        for fleet, tiers in FLEETS.items():
            res = _run_cell(sc, "mlproxy", tiers, quick)
            if fleet == "single":
                ref = res
            router = res.routers.get("ep", {})
            tier_break = next(iter(res.tiers.values()), {})
            rows.append({
                "kind": "sweep",
                "scenario": name,
                "policy": "mlproxy",
                "fleet": fleet,
                "identical": "",
                "completed": res.summary["completed"],
                "violations": _violations(res),
                "viol_pct": round(res.summary["violation_pct"], 4),
                "weighted_cost": round(res.summary["weighted_cost"], 6),
                "cost_delta_pct": round(
                    100.0 * (res.summary["weighted_cost"]
                             - ref.summary["weighted_cost"])
                    / ref.summary["weighted_cost"]
                    if ref.summary["weighted_cost"] else 0.0, 3),
                "spillover_pct": round(
                    100.0 * router.get("spillover_rate", 0.0), 2),
                "preemptions": int(res.summary["preemptions"]),
                # per-tier weighted-cost split (empty for the single fleet)
                "cost_by_tier": "|".join(
                    f"{tn}:{t['cost_integral']:.1f}"
                    for tn, t in tier_break.items()),
            })
    write_csv("tier_economics.csv", rows)
    return rows


def spillover_wins(rows: List[Dict]) -> float:
    """Scenarios where a spillover fleet meets the single fleet's SLA at
    strictly lower weighted cost — the headline ``derived`` value.
    Returns -1.0 if any identity row broke or any cell lost work."""
    if any(r["violations"] for r in rows):
        return -1.0
    if not all(r["identical"] for r in rows if r["kind"] == "identity"):
        return -1.0
    wins = 0
    for name in SCENARIOS:
        cells = {r["fleet"]: r for r in rows
                 if r["kind"] == "sweep" and r["scenario"] == name}
        single = cells["single"]
        if any(
            c["viol_pct"] <= single["viol_pct"] + SLA_EPS_PCT
            and c["weighted_cost"] < single["weighted_cost"]
            for f, c in cells.items() if f != "single"
        ):
            wins += 1
    return float(wins)


if __name__ == "__main__":
    out = run(quick=True)
    for r in out:
        print(r)
    print("spillover_wins:", spillover_wins(out))
