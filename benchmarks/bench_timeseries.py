"""Figure 7: time series for experiment #1 (Fashion MNIST, WC trace,
30 rps, SLO 500 ms): windowed P95, container count, SLO miss rate and
Max_BS over time, with and without MLProxy."""
from __future__ import annotations

from typing import Dict, List

from repro.core import SLAConfig, ms
from repro.serverless.latency import get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import TraceModulatedPoisson
from repro.simulation.simulator import run_simulation
from repro.simulation.traces import synthetic_trace

from benchmarks.common import write_csv
from benchmarks.bench_table3 import EXPERIMENTS


def run(quick: bool = False) -> List[Dict]:
    exp = EXPERIMENTS[0]
    duration = 600.0 if quick else 1800.0
    sla = SLAConfig(slo_target=ms(exp.slo_ms))
    wl = get_workload(exp.workload)
    rows: List[Dict] = []
    for policy in ("passthrough", "mlproxy"):
        trace = synthetic_trace(exp.trace, duration=duration, seed=0
                                ).scaled(exp.max_rps)
        res = run_simulation(
            policy=policy, sla=sla, workload=wl,
            arrivals=TraceModulatedPoisson(trace),
            platform_config=PlatformConfig(initial_scale=1),
            duration=duration, seed=1, sample_interval=5.0,
        )
        tl = res.timeline
        for i in range(len(tl["t"])):
            rows.append({
                "policy": policy,
                "t": float(tl["t"][i]),
                "p95_ms": round(float(tl["p95"][i]) * 1000, 2),
                "containers": float(tl["containers"][i]),
                "miss_rate": float(tl["miss_rate"][i]),
                "max_bs": float(tl["max_bs"][i]),
                "arrival_rate": trace.rate_at(float(tl["t"][i])),
            })
    write_csv("fig7_timeseries.csv", rows)
    return rows


if __name__ == "__main__":
    run()
    print("fig7_timeseries.csv written")
