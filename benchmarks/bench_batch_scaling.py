"""Figures 3 & 4: relative response time and relative time-per-inference
vs batch size, per workload, with the linear baseline.

Two data sources:
  * the calibrated Table-2 latency models (what the Table-3 simulations
    use) — deterministic means;
  * a REAL measurement: the JAX :class:`InferenceEngine` running a reduced
    qwen2 config on this host across batch buckets (the engine-measured
    curve is the serving-stack ground truth for batching sub-linearity).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.serverless.latency import PAPER_WORKLOADS

from benchmarks.common import write_csv

BATCH_SIZES = (1, 2, 4, 8, 12, 16, 20, 24, 32)


def model_curves() -> List[Dict]:
    rows = []
    for name, model in PAPER_WORKLOADS.items():
        base = model.mean(1)
        for bs in BATCH_SIZES:
            rt = model.mean(bs)
            rows.append({
                "workload": name,
                "batch_size": bs,
                "rt_ms": rt * 1000,
                "relative_rt": rt / base,            # Fig. 3
                "relative_per_inference": (rt / bs) / base,  # Fig. 4
                "linear_baseline_rt": float(bs),
                "linear_baseline_per_inference": 1.0,
            })
    return rows


def engine_curve(gen_len: int = 4, repeats: int = 3) -> List[Dict]:
    import jax

    from repro.configs import get_config
    from repro.serving.engine import EngineConfig, InferenceEngine

    cfg = get_config("qwen2-0.5b").reduced()
    ecfg = EngineConfig(batch_buckets=(1, 2, 4, 8, 16, 32),
                        prompt_buckets=(16,), max_len=32, gen_len=gen_len)
    eng = InferenceEngine(cfg, ecfg, rng=jax.random.PRNGKey(0))
    eng.warmup(plen=16)
    rng = np.random.default_rng(0)
    rows = []
    base = None
    for bs in ecfg.batch_buckets:
        times = []
        for _ in range(repeats):
            prompts = rng.integers(0, cfg.vocab_size, (bs, 16)).astype(np.int32)
            _, t = eng.generate(prompts, gen_len=gen_len)
            times.append(t["latency_s"])
        rt = float(np.median(times))
        base = rt if base is None else base
        rows.append({
            "workload": "jax-engine-qwen2-smoke",
            "batch_size": bs,
            "rt_ms": rt * 1000,
            "relative_rt": rt / base,
            "relative_per_inference": (rt / bs) / base,
            "linear_baseline_rt": float(bs),
            "linear_baseline_per_inference": 1.0,
        })
    return rows


def run(quick: bool = False) -> List[Dict]:
    rows = model_curves()
    rows += engine_curve(repeats=1 if quick else 3)
    write_csv("fig3_fig4_batch_scaling.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
