"""Data-plane microbenchmark: fused decode loop + bucket-aware packing.

Two halves, matching the two layers of the fast data plane:

* **decode** — tokens/s of the fused ``lax.scan`` decode loop vs the
  per-token reference loop on the same tiny model and SAME parameters
  (bit-identity is asserted before any timing). The gap is pure
  Python→XLA dispatch overhead: the per-token loop pays one device
  round-trip per generated token, the fused loop pays one per batch.
  ``per_token_dispatch_us`` is that overhead, measured as the per-step
  time difference between the two loops.
* **packing** — a ``bench_live_parity``-style run of the live runtime
  (FakeClock + :class:`SyntheticTarget` with engine-shaped
  ``batch_buckets``) at equal SLA, with and without bucket-aware packing
  (``pack=True``): the policy's full-trigger rounds its batch target up
  to the next bucket edge and dispatches exactly at it, so "full"
  batches execute with zero padding. Cost at equal SLA = dispatched
  upstream batches + padding waste (bucket slots burned on padding are
  paid compute on a fixed-shape engine).

Decode-half acceptance: fused ≥ 3x tokens/s on the decode-dominated
config (gen_len ≥ 32, small bucket) with bit-identical outputs — the
harness headline is the best bucket's speedup, gated to 0 if ANY bucket
diverges from the reference loop. Packing-half acceptance: mean padding
waste strictly drops at equal SLA.
"""
from __future__ import annotations

import statistics
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import write_csv

#: Engine-shaped bucket grid shared by both packing runs.
PACK_BUCKETS = (1, 2, 4, 8)


def _tiny_model_cfg():
    """1-layer model small enough that decode is dispatch-dominated —
    the regime the fused loop targets (any real model is *more* work per
    dispatch, so the fused win only grows with model size)."""
    from repro.configs.base import ModelConfig

    return ModelConfig(
        name="bench-engine-tiny", family="dense",
        num_layers=1, d_model=16, num_heads=1, num_kv_heads=1,
        head_dim=16, d_ff=32, vocab_size=64, max_seq_len=256,
        param_dtype="float32", compute_dtype="float32",
        remat=False, scan_layers=False,
    )


def _time_generate(engine, prompts, gen_len: int, budget_s: float) -> float:
    """Median wall seconds per generate() call over a time-budgeted loop
    (median, not mean: one scheduler hiccup must not skew a µs-scale
    dispatch-overhead measurement)."""
    engine.generate(prompts, gen_len=gen_len)  # ensure compiled
    samples: List[float] = []
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < budget_s or len(samples) < 5:
        t1 = time.perf_counter()
        engine.generate(prompts, gen_len=gen_len)
        samples.append(time.perf_counter() - t1)
    return statistics.median(samples)


def decode_rows(quick: bool) -> List[Dict]:
    import jax

    from repro.serving.engine import EngineConfig, InferenceEngine

    cfg = _tiny_model_cfg()
    gen_len = 64 if quick else 128
    plen = 8
    buckets = (1,) if quick else (1, 2, 4)
    budget = 0.5 if quick else 2.0
    max_len = plen + gen_len + 8

    # One set of parameters shared by every engine variant: the fused vs
    # per-token comparison is loop structure only.
    template = InferenceEngine(
        cfg, EngineConfig(batch_buckets=(max(buckets),), prompt_buckets=(plen,),
                          max_len=max_len, gen_len=gen_len),
        rng=jax.random.PRNGKey(0))
    params = template.params

    rows: List[Dict] = []
    rng = np.random.default_rng(0)
    for bucket in buckets:
        ecfg = dict(batch_buckets=(bucket,), prompt_buckets=(plen,),
                    max_len=max_len, gen_len=gen_len)
        fused = InferenceEngine(cfg, EngineConfig(**ecfg), params=params)
        unfused = InferenceEngine(
            cfg, EngineConfig(fused_decode=False, cache_pool=False, **ecfg),
            params=params)

        # Bit-identity gate: same params, same prompts, token-for-token
        # equal across several draws before any timing is trusted.
        identical = True
        for _ in range(3):
            prompts = rng.integers(0, cfg.vocab_size, (bucket, plen),
                                   dtype=np.int64).astype(np.int32)
            a, _ = fused.generate(prompts, gen_len=gen_len)
            b, _ = unfused.generate(prompts, gen_len=gen_len)
            identical = identical and bool(np.array_equal(a, b))

        prompts = rng.integers(0, cfg.vocab_size, (bucket, plen),
                               dtype=np.int64).astype(np.int32)
        fused_s = _time_generate(fused, prompts, gen_len, budget)
        unfused_s = _time_generate(unfused, prompts, gen_len, budget)
        speedup = unfused_s / fused_s
        # Per generated token (beyond the first, which both paths produce
        # from prefill logits), the per-token loop pays one extra
        # Python→XLA dispatch; the fused loop amortizes all of them.
        dispatch_us = (unfused_s - fused_s) / (gen_len - 1) * 1e6
        rows.append({
            "kind": "decode",
            "bucket": bucket,
            "gen_len": gen_len,
            "bit_identical": identical,
            "fused_tok_per_s": round(bucket * gen_len / fused_s, 1),
            "unfused_tok_per_s": round(bucket * gen_len / unfused_s, 1),
            "fused_ms_per_batch": round(fused_s * 1e3, 3),
            "unfused_ms_per_batch": round(unfused_s * 1e3, 3),
            "speedup": round(speedup, 2),
            "per_token_dispatch_us": round(dispatch_us, 1),
            "fused_compiles": fused.compile_count,
            "unfused_compiles": unfused.compile_count,
            "fused_cache_allocs": fused.cache_allocs,
            "unfused_cache_allocs": unfused.cache_allocs,
        })
    return rows


def packing_rows(quick: bool) -> List[Dict]:
    from repro.core import SLAConfig, ms
    from repro.runtime import FakeClock, SyntheticTarget, run_replay
    from repro.serverless.latency import get_workload
    from repro.simulation.arrivals import (PoissonProcess, Schedule,
                                           sample_schedule)

    duration = 120.0 if quick else 600.0
    wl = get_workload("pytorch-fashion-mnist")
    sla = SLAConfig(slo_target=ms(500))
    times = sample_schedule(PoissonProcess(rate=30.0, duration=duration),
                            7, duration)

    rows: List[Dict] = []
    for packed in (False, True):
        clk = FakeClock()
        target = SyntheticTarget(wl, clk,
                                 rng=np.random.default_rng(11),
                                 batch_buckets=PACK_BUCKETS)
        kwargs = {} if packed else {"bucketing": PACK_BUCKETS}
        res = run_replay(
            policy="mlproxy", sla=sla, workload=wl,
            arrivals=Schedule(times), duration=duration, seed=7,
            target=target, clock=clk, policy_kwargs=kwargs, pack=packed,
        )
        s = res.summary
        rows.append({
            "kind": "packing",
            "packed": packed,
            "requests": int(len(times)),
            "completed": s["completed"],
            "violation_pct": round(s["violation_pct"], 3),
            "padding_waste_pct": round(s["padding_waste"] * 100, 3),
            "dispatched_batches": s["dispatched_batches"],
            "avg_batch_size": round(s["avg_batch_size"], 3),
            "upstream_batches": target.batches,
        })
    return rows


def run(quick: bool = False) -> List[Dict]:
    rows = decode_rows(quick)
    rows += packing_rows(quick)
    write_csv("engine.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
