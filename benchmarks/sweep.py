"""Parallel policy × scenario sweep runner.

Fans a (scenario × policy × seed) grid across worker processes — the
shape of every conclusions table in the paper's evaluation (§3, Table 3)
and of related batching-system studies is exactly such a grid, and with
the vectorized event core one cell is seconds, so the grid, not the cell,
is the unit of scale.

Determinism contract: each cell is fully self-contained (fresh simulator,
per-cell seed), so ``--jobs N`` produces byte-identical rows to serial
execution in the same order — verified by ``tests/test_sweep.py``.

Usage:
    python -m benchmarks.sweep --jobs 8 --quick --seeds 11,12,13
"""
from __future__ import annotations

import argparse
import multiprocessing as mp
from typing import Dict, List, Sequence, Tuple

from benchmarks.common import write_csv

Cell = Tuple[str, str, int]  # (scenario, policy, seed)


def default_grid(seeds: Sequence[int] = (11,)) -> List[Cell]:
    """Every chaos scenario × every policy × every seed."""
    from experiments.scenarios import POLICIES, SCENARIOS

    return [
        (scenario, policy, seed)
        for scenario in sorted(SCENARIOS)
        for policy in POLICIES
        for seed in seeds
    ]


def run_cell(work: Tuple[Cell, bool]) -> Dict:
    """One grid cell: run the scenario, enforce conservation, summarize.

    Top-level (picklable) so worker processes can receive it; every input
    is a primitive, and the simulator is built fresh inside the worker.
    """
    (scenario, policy, seed), quick = work
    from experiments.scenarios import run_scenario

    res, _ = run_scenario(scenario, policy, quick=quick, seed=seed)
    s = res.summary
    return {
        "scenario": scenario,
        "policy": policy,
        "seed": seed,
        "completed": int(s["completed"]),
        "violation_pct": round(s["violation_pct"], 4),
        "containers": round(s["avg_containers"], 4),
        "avg_batch_size": round(s["avg_batch_size"], 4),
        "p95": round(s["p95"], 6),
        "requeued": int(s["requeued_batches"]),
        "hedged": int(s["hedged_dispatches"]),
        "lost": int(s["lost_batches"]),
        "duplicates": int(s["duplicate_completions"]),
    }


def run_sweep(cells: Sequence[Cell], *, quick: bool = False,
              jobs: int = 1) -> List[Dict]:
    """Run ``cells`` (serial or across ``jobs`` processes), rows in grid order."""
    work = [(cell, quick) for cell in cells]
    if jobs > 1:
        # spawn (not fork): workers re-import cleanly, so results cannot
        # depend on inherited interpreter state
        ctx = mp.get_context("spawn")
        with ctx.Pool(processes=jobs) as pool:
            rows = pool.map(run_cell, work)
    else:
        rows = [run_cell(w) for w in work]
    return rows


def run(quick: bool = False, jobs: int = 1) -> List[Dict]:
    """Benchmark-harness entry point (see benchmarks/run.py)."""
    rows = run_sweep(default_grid(), quick=quick, jobs=jobs)
    write_csv("policy_sweep.csv", rows)
    return rows


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--jobs", type=int, default=1,
                   help="worker processes (1 = serial)")
    p.add_argument("--quick", action="store_true",
                   help="shorter simulations (CI-scale)")
    p.add_argument("--seeds", default="11",
                   help="comma-separated per-cell seeds")
    args = p.parse_args()
    seeds = tuple(int(s) for s in args.seeds.split(","))
    rows = run_sweep(default_grid(seeds), quick=args.quick, jobs=args.jobs)
    path = write_csv("policy_sweep.csv", rows)
    for r in rows:
        print(r)
    print(f"wrote {len(rows)} rows to {path}")


if __name__ == "__main__":
    main()
