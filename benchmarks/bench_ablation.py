"""Ablation of the control-plane design decisions (EXPERIMENTS.md
§Paper-validation calibration notes): TO_thresh, winsorized estimation,
compliance factor — on the exp-1 scenario (fashion-mnist, WC trace,
30 rps, SLO 500 ms) where the knobs BIND; at high-rate/lenient-SLO
operating points (exp 2) they are inert (measured).

Standalone: ``PYTHONPATH=src python -m benchmarks.bench_ablation``.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import MonitorConfig, OptimizerConfig, ProxyConfig, SLAConfig, ms
from repro.serverless.latency import get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import TraceModulatedPoisson
from repro.simulation.simulator import run_simulation
from repro.simulation.traces import synthetic_trace

from benchmarks.common import write_csv

VARIANTS = [
    ("paper-faithful (to=0.5, raw P95)", dict(to_thresh=0.5), dict(outlier_mult=0.0)),
    ("to_thresh=0.9, raw P95", dict(to_thresh=0.9), dict(outlier_mult=0.0)),
    ("to_thresh=0.5, winsorized", dict(to_thresh=0.5), dict(outlier_mult=5.0)),
    ("default (to=0.9, winsorized)", dict(to_thresh=0.9), dict(outlier_mult=5.0)),
    ("compliance 0.7", dict(to_thresh=0.9), dict(outlier_mult=5.0), 0.7),
    ("compliance 0.9", dict(to_thresh=0.9), dict(outlier_mult=5.0), 0.9),
]


def run(quick: bool = False, rate: float = 30.0, slo_ms: float = 500.0) -> List[Dict]:
    duration = 600.0 if quick else 1800.0
    wl = get_workload("pytorch-fashion-mnist")
    pc = PlatformConfig(initial_scale=1, max_scale=27, cold_start=10.0)
    rows: List[Dict] = []
    for variant in VARIANTS:
        name, opt_kw, mon_kw = variant[0], variant[1], variant[2]
        compliance = variant[3] if len(variant) > 3 else 0.8
        sla = SLAConfig(slo_target=ms(slo_ms), compliance_factor=compliance)
        cfg = ProxyConfig(
            sla=sla,
            monitor=MonitorConfig(**mon_kw),
            optimizer=OptimizerConfig(**opt_kw),
        )
        tr = synthetic_trace("wc", duration=duration, seed=0).scaled(rate)
        s = run_simulation(
            policy="mlproxy", sla=sla, workload=wl,
            arrivals=TraceModulatedPoisson(tr), platform_config=pc,
            duration=duration, warmup=duration / 6, seed=2,
            policy_kwargs={"proxy_config": cfg},
        ).summary
        rows.append({
            "variant": name,
            "containers": round(s["avg_containers"], 3),
            "viol_pct": round(s["violation_pct"], 4),
            "avg_bs": round(s["avg_batch_size"], 2),
            "p95_ms": round(s["p95"] * 1000, 1),
        })
    write_csv("ablation_controller.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['variant']:34s} cont={r['containers']:6.2f} "
              f"viol%={r['viol_pct']:7.3f} BS={r['avg_bs']:5.2f} "
              f"p95={r['p95_ms']:6.0f}ms")
