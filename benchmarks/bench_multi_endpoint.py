"""Beyond-paper: heterogeneous multi-endpoint fleet through ONE proxy.

One :class:`~repro.core.frontend.ProxyFrontend` serves two SLA classes in a
single simulation — the scenario the per-endpoint paper deployment cannot
express:

* ``iris``   — small model (sklearn-iris), tight 200 ms SLO, high rate;
* ``resnet`` — large model (tfserving-resnet), loose 1.5 s SLO, low rate;

both driven by bursty MMPP2 arrivals. Reported per scenario: per-class
SLO-violation rate, per-class average batch size, and total container cost
across the fleet. Scenarios cross the batching policy (passthrough vs
per-endpoint MLProxy) with fleet topology (dedicated platform per endpoint
vs one shared multi-model platform).
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import SLAConfig, ms
from repro.serverless.latency import get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import MMPP2
from repro.simulation.simulator import EndpointSpec, run_multi_simulation

from benchmarks.common import write_csv


def _specs(policy: str, duration: float, shared: bool) -> Dict[str, EndpointSpec]:
    pc = PlatformConfig(initial_scale=1)
    return {
        "iris": EndpointSpec(
            policy=policy,
            sla=SLAConfig(slo_target=ms(200)),
            workload=get_workload("sklearn-iris"),
            arrivals=MMPP2(rate_lo=10.0, rate_hi=120.0, mean_lo=40.0,
                           mean_hi=15.0, duration=duration),
            platform="fleet" if shared else None,
            platform_config=pc,
        ),
        "resnet": EndpointSpec(
            policy=policy,
            sla=SLAConfig(slo_target=ms(1500)),
            workload=get_workload("tfserving-resnet"),
            arrivals=MMPP2(rate_lo=2.0, rate_hi=12.0, mean_lo=40.0,
                           mean_hi=20.0, duration=duration),
            platform="fleet" if shared else None,
            platform_config=pc,
        ),
    }


def run(quick: bool = False) -> List[Dict]:
    duration = 300.0 if quick else 1200.0
    warmup = duration / 5
    rows: List[Dict] = []
    for shared in (False, True):
        for policy in ("passthrough", "mlproxy"):
            res = run_multi_simulation(
                _specs(policy, duration, shared),
                duration=duration, warmup=warmup, seed=17,
            )
            row: Dict = {
                "policy": policy,
                "fleet": "shared" if shared else "dedicated",
                "containers_total": round(res.summary["avg_containers"], 3),
                "viol_pct_fleet": round(res.summary["violation_pct"], 4),
                "completed": res.summary["completed"],
            }
            for name, s in res.endpoints.items():
                row[f"viol_pct_{name}"] = round(s["violation_pct"], 4)
                row[f"avg_bs_{name}"] = round(s["avg_batch_size"], 2)
                row[f"p95_ms_{name}"] = round(s["p95"] * 1000, 1)
                row[f"max_bs_{name}"] = s["max_bs"]
            rows.append(row)
    write_csv("multi_endpoint.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['fleet']:9s} {r['policy']:11s} "
              f"cont {r['containers_total']:6.2f} "
              f"viol% iris {r['viol_pct_iris']:7.3f} "
              f"resnet {r['viol_pct_resnet']:7.3f} "
              f"BS {r['avg_bs_iris']:5.2f}/{r['avg_bs_resnet']:5.2f}")
