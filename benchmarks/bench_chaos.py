"""Chaos scenario sweep: every policy through every named fault regime.

Two worlds, one fault taxonomy (the ``world`` column tells rows apart):

* **sim** — for each (scenario, policy) pair the discrete-event platform
  runs the scenario twice — fault injection on, and the identical scaling
  regime with faults off — asserts the conservation invariant on both
  runs (every submitted batch completes exactly once; zero lost, zero
  duplicated, zero left outstanding), and reports the violation-rate /
  cost deltas the fault regime costs each policy.
* **live** — the wall-clock runtime replays each fault regime through a
  :class:`~repro.runtime.faults.FaultyTarget` under FakeClock, with the
  proxy-tier retry + circuit-breaker layer on. Each cell also runs the
  no-fault case twice — the scenario's fault-tolerance config through
  the zero-probability wrapper versus the plain pre-fault-tolerance
  runtime on the bare target — and reports whether the two are
  byte-identical (``nofault_identical``): the retry layer must be a
  strict no-op when nothing fails. ``recovered_pct`` is the headline the
  CI chaos smoke gates on (>= 90% of faulted batches recovered within
  deadline in the crash storm).

A policy that looks cheap in the fault-free sweep but collapses under
crash churn shows up here — in either world.
"""
from __future__ import annotations

from typing import Dict, List

from experiments.scenarios import (
    LIVE_SCENARIOS,
    POLICIES,
    SCENARIOS,
    run_live_scenario,
    run_scenario,
)
from repro.runtime import RuntimeConfig

from benchmarks.common import write_csv

#: Policies the live sweep runs (one deterministic, one adaptive — the
#: full five-policy grid lives in the sim world, which is much cheaper).
LIVE_POLICIES = ("static", "mlproxy")

#: Summary keys that must match exactly between the no-fault run under
#: the fault-tolerance config and the plain pre-fault-tolerance runtime.
_IDENTITY_KEYS = (
    "completed", "dispatched_batches", "p50", "p95", "p99", "mean_latency",
    "violation_pct", "timed_out", "rejected", "failed", "throughput",
)


def run_sim(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    for name, scenario in SCENARIOS.items():
        for policy in POLICIES:
            base, base_cons = run_scenario(
                scenario, policy, faults=False, quick=quick
            )
            chaos, cons = run_scenario(
                scenario, policy, faults=True, quick=quick
            )
            b, c = base.summary, chaos.summary
            rows.append({
                "world": "sim",
                "scenario": name,
                "policy": policy,
                "completed": c["completed_batches"],
                "submitted": c["submitted_batches"],
                "lost": c["lost_batches"] + b["lost_batches"],
                "duplicates": (
                    c["duplicate_completions"] + b["duplicate_completions"]
                ),
                "requeued": c["requeued_batches"],
                "hedged": c["hedged_dispatches"],
                "cancelled": c["cancelled_attempts"],
                "containers": round(c["avg_containers"], 3),
                "viol_pct": round(c["violation_pct"], 4),
                "p95_ms": round(c["p95"] * 1000, 1),
                # what the fault regime costs this policy vs faults-off
                "viol_pct_delta": round(
                    c["violation_pct"] - b["violation_pct"], 4
                ),
                "containers_delta": round(
                    c["avg_containers"] - b["avg_containers"], 3
                ),
            })
    return rows


def run_live(quick: bool = False) -> List[Dict]:
    """Live-runtime half of the sweep; also written to ``chaos_live.csv``
    on its own for the CI ``runtime-chaos-smoke`` job."""
    rows: List[Dict] = []
    for name, scenario in LIVE_SCENARIOS.items():
        for policy in LIVE_POLICIES:
            # PR-7-equivalent reference: no wrapper, no retries, no breaker
            plain = run_live_scenario(scenario, policy, faults=False,
                                      quick=quick, runtime=RuntimeConfig(),
                                      bare=True)
            base = run_live_scenario(scenario, policy, faults=False,
                                     quick=quick)
            chaos = run_live_scenario(scenario, policy, faults=True,
                                      quick=quick)
            identical = (
                base.dispatch_log == plain.dispatch_log
                and all(base.summary[k] == plain.summary[k]
                        for k in _IDENTITY_KEYS)
            )
            c = chaos.conservation
            faulted = c["faulted_batches"]
            recovered = c["recovered_batches"]
            rows.append({
                "world": "live",
                "scenario": name,
                "policy": policy,
                "completed": c["completed"],
                "submitted": c["submitted"],
                "lost": c["lost"],
                "duplicates": c["duplicate_completions"],
                "shed": c["shed"],
                "timed_out": c["timed_out"],
                "failed": c["failed"],
                "hedged": c["hedged_batches"],
                "retried": c["retried_batches"],
                "retry_exhausted": c["retry_exhausted"],
                "faulted": faulted,
                "recovered": recovered,
                "recovered_pct": round(
                    100.0 * recovered / faulted if faulted else 100.0, 2
                ),
                "viol_pct": round(chaos.summary["violation_pct"], 4),
                "p95_ms": round(chaos.summary["p95"] * 1000, 1),
                "nofault_identical": identical,
            })
    write_csv("chaos_live.csv", rows)
    return rows


def run(quick: bool = False) -> List[Dict]:
    rows = run_sim(quick=quick) + run_live(quick=quick)
    write_csv("chaos_scenarios.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
