"""Chaos scenario sweep: every policy through every named fault regime.

For each (scenario, policy) pair this runs the scenario twice — fault
injection on, and the identical scaling regime with faults off — asserts
the conservation invariant on both runs (every submitted batch completes
exactly once; zero lost, zero duplicated, zero left outstanding), and
reports the violation-rate / cost deltas the fault regime costs each
policy. A policy that looks cheap in the fault-free sweep but collapses
under crash churn shows up here.
"""
from __future__ import annotations

from typing import Dict, List

from experiments.scenarios import POLICIES, SCENARIOS, run_scenario

from benchmarks.common import write_csv


def run(quick: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    for name, scenario in SCENARIOS.items():
        for policy in POLICIES:
            base, base_cons = run_scenario(
                scenario, policy, faults=False, quick=quick
            )
            chaos, cons = run_scenario(
                scenario, policy, faults=True, quick=quick
            )
            b, c = base.summary, chaos.summary
            rows.append({
                "scenario": name,
                "policy": policy,
                "completed": c["completed_batches"],
                "submitted": c["submitted_batches"],
                "lost": c["lost_batches"] + b["lost_batches"],
                "duplicates": (
                    c["duplicate_completions"] + b["duplicate_completions"]
                ),
                "requeued": c["requeued_batches"],
                "hedged": c["hedged_dispatches"],
                "cancelled": c["cancelled_attempts"],
                "containers": round(c["avg_containers"], 3),
                "viol_pct": round(c["violation_pct"], 4),
                "p95_ms": round(c["p95"] * 1000, 1),
                # what the fault regime costs this policy vs faults-off
                "viol_pct_delta": round(
                    c["violation_pct"] - b["violation_pct"], 4
                ),
                "containers_delta": round(
                    c["avg_containers"] - b["avg_containers"], 3
                ),
            })
    write_csv("chaos_scenarios.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
