"""Figure 6: CCDF of response times with and without MLProxy (per
experiment), with the SLO marker and total miss rates."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import SLAConfig, ms
from repro.serverless.latency import get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import TraceModulatedPoisson
from repro.simulation.simulator import run_simulation
from repro.simulation.traces import synthetic_trace

from benchmarks.common import write_csv
from benchmarks.bench_table3 import EXPERIMENTS


def run(quick: bool = False, experiments=(1, 2, 7)) -> List[Dict]:
    duration = 600.0 if quick else 1800.0
    rows: List[Dict] = []
    for exp in EXPERIMENTS:
        if exp.idx not in experiments:
            continue
        sla = SLAConfig(slo_target=ms(exp.slo_ms))
        wl = get_workload(exp.workload)
        for policy in ("passthrough", "mlproxy"):
            trace = synthetic_trace(exp.trace, duration=duration, seed=0
                                    ).scaled(exp.max_rps)
            res = run_simulation(
                policy=policy, sla=sla, workload=wl,
                arrivals=TraceModulatedPoisson(trace),
                platform_config=PlatformConfig(initial_scale=1),
                duration=duration, warmup=duration / 6, seed=exp.idx,
            )
            lat, ccdf = res.ccdf()
            # subsample to ≤400 points per curve for the CSV
            idx = np.unique(np.linspace(0, len(lat) - 1, 400).astype(int))
            for i in idx:
                rows.append({
                    "exp": exp.idx, "policy": policy,
                    "latency_ms": round(float(lat[i]) * 1000, 3),
                    "ccdf": float(ccdf[i]),
                    "slo_ms": exp.slo_ms,
                })
    write_csv("fig6_ccdf.csv", rows)
    return rows


if __name__ == "__main__":
    run()
    print("fig6_ccdf.csv written")
