"""Deadline-tightness × policy × hedge sweep, in BOTH worlds.

Every cell runs the same pre-sampled Poisson schedule through the
discrete-event simulator (transparent platform) and the live asyncio
runtime (FakeClock + SyntheticTarget) with per-request deadlines derived
from the endpoint SLA (``deadline_factor`` × SLO) and proxy-tier
straggler hedging on or off.

What the sweep shows:

* **expiry semantics** — tighter deadlines shed more requests *before*
  dispatch (``timed_out``), so the upstream never burns container time on
  work whose SLO is already unmeetable;
* **hedging** — with hedging on, the straggler tail of the latency
  distribution is cut by re-issuing slow batches (visible in p99);
* **conservation** — every cell asserts the drained ledger in both
  worlds: ``submitted == completed + timed_out (+ rejected)`` with zero
  lost. The ``violations`` column (and the harness headline) must be 0.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import SLAConfig, ms
from repro.runtime import RuntimeConfig, run_replay
from repro.serverless.latency import get_workload
from repro.simulation.arrivals import PoissonProcess, Schedule, sample_schedule
from repro.simulation.simulator import run_simulation

from benchmarks.common import parity_policy_kwargs, transparent_platform, write_csv

POLICIES = ("passthrough", "static", "clipper", "oracle", "mlproxy")
#: deadline budget as a multiple of the SLO (None = deadlines off).
#: 0.25 (125 ms budget) sits below the static policy's 200 ms queue
#: timeout and the SLO-derived timeouts of oracle/mlproxy, so the tight
#: end of the sweep genuinely sheds queued work pre-dispatch.
TIGHTNESS = (None, 2.0, 1.0, 0.5, 0.25)
HEDGE_QUANTILE = 95.0


def sweep_rows(duration: float, seed: int) -> List[Dict]:
    wl = get_workload("pytorch-fashion-mnist")
    transparent = transparent_platform()
    times = sample_schedule(PoissonProcess(rate=30.0, duration=duration),
                            seed, duration)
    rows: List[Dict] = []
    for policy in POLICIES:
        kw = parity_policy_kwargs(policy, wl)
        for factor in TIGHTNESS:
            sla = SLAConfig(slo_target=ms(500), deadline_factor=factor)
            for hedge in (0.0, HEDGE_QUANTILE):
                sim = run_simulation(
                    policy=policy, sla=sla, workload=wl,
                    arrivals=Schedule(times),
                    platform_config=transparent,
                    duration=duration, seed=seed, policy_kwargs=dict(kw),
                    hedge_quantile=hedge,
                )
                live = run_replay(
                    policy=policy, sla=sla, workload=wl,
                    arrivals=Schedule(times), duration=duration, seed=seed,
                    policy_kwargs=dict(kw),
                    config=RuntimeConfig(hedge_quantile=hedge),
                )
                s, l = sim.summary, live.summary
                violations = 0
                # request conservation, sim world (drained by run())
                if s["submitted_requests"] != s["completed"] + s["timed_out"]:
                    violations += 1
                # live world: drain() already asserted its ledger; re-check
                c = live.conservation
                if (c["lost"] != 0
                        or c["submitted"] != c["completed"] + c["rejected"]
                        + c["timed_out"] + c["failed"]):
                    violations += 1
                rows.append({
                    "policy": policy,
                    "deadline_factor": factor if factor is not None else "",
                    "hedge_quantile": hedge,
                    "requests": int(len(times)),
                    "sim_completed": s["completed"],
                    "live_completed": l["completed"],
                    "sim_timed_out": s["timed_out"],
                    "live_timed_out": l["timed_out"],
                    "sim_hedged": s["hedged_batches"],
                    "live_hedged": l["hedged_batches"],
                    "sim_p95_ms": round(s["p95"] * 1000, 2),
                    "live_p95_ms": round(l["p95"] * 1000, 2),
                    "sim_viol_pct": round(s["violation_pct"], 3),
                    "live_viol_pct": round(l["violation_pct"], 3),
                    "live_lost": c["lost"],
                    "violations": violations,
                })
    return rows


def run(quick: bool = False) -> List[Dict]:
    duration = 40.0 if quick else 180.0
    rows = sweep_rows(duration, seed=11)
    write_csv("deadlines.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
