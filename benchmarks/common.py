"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, Iterable, List

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "results")


def out_path(name: str) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    return os.path.join(OUT_DIR, name)


def write_csv(name: str, rows: List[Dict], field_order: Iterable[str] = ()):
    path = out_path(name)
    if not rows:
        return path
    fields = list(field_order) or list(rows[0].keys())
    for r in rows:
        for k in r:
            if k not in fields:
                fields.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields)
        w.writeheader()
        for r in rows:
            w.writerow(r)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
