"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, Iterable, List

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "results")


def transparent_platform():
    """Platform config that makes the simulated upstream a pure
    service-time delay (the live SyntheticTarget's exact semantics): one
    always-warm container, effectively unlimited concurrency, no cold
    starts, no processor-sharing slowdown. Shared by every sim↔live
    comparison bench so both worlds model the same upstream.
    """
    from repro.serverless.platform import PlatformConfig

    return PlatformConfig(
        container_concurrency=10**6,
        cold_start=0.0,
        min_scale=1,
        max_scale=1,
        initial_scale=1,
        ps_slowdown=0.0,
        scale_to_zero_grace=1e12,
    )


def parity_policy_kwargs(policy: str, workload) -> dict:
    """The per-policy kwargs every parity-style bench uses (one shared
    definition so sim, live, and sweep cells stay workload-equivalent)."""
    if policy == "static":
        return {"batch_size": 8, "timeout": 0.2}
    if policy == "oracle":
        return {"latency_model": lambda bs: workload.percentile(bs, 95)}
    return {}


def out_path(name: str) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    return os.path.join(OUT_DIR, name)


def write_csv(name: str, rows: List[Dict], field_order: Iterable[str] = ()):
    path = out_path(name)
    if not rows:
        return path
    fields = list(field_order) or list(rows[0].keys())
    for r in rows:
        for k in r:
            if k not in fields:
                fields.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields)
        w.writeheader()
        for r in rows:
            w.writerow(r)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
