"""Sim↔live parity: the same trace + policy through both worlds.

Every policy runs the SAME pre-sampled Poisson schedule twice:

* **sim** — the discrete-event :class:`Simulator` with a *transparent*
  platform (1 always-warm container, effectively unlimited concurrency,
  no cold starts, no processor-sharing slowdown), so upstream latency is
  exactly one service-time draw;
* **live** — the asyncio runtime (:mod:`repro.runtime`) with a
  :class:`SyntheticTarget` on the same latency model, under a
  deterministic :class:`FakeClock`.

Both worlds make their own service-time draws from the same model, so the
comparison is distributional: per-policy RT95, violation rate, and the
cost proxies (dispatched upstream batches + average batch size — fewer,
fuller batches ⇔ lower serverless cost) must agree within tolerance
(documented in README: RT95 and dispatched-batches within ~10% at full
scale, ~20% on --quick runs). A systematic gap means the runtime's timer/
dispatch semantics diverged from the event-driven core.

The second half exercises the calibration bridge round-trip: a live run
with pow2 bucketing measures per-bucket batch latencies against a ground
truth model; ``Calibration.from_samples`` fits them; the fitted model's
simulated draws (the exact ``sample`` call the platform makes) must
reproduce the measured means within 10% per bucket, and a second live run
against the *fitted* model must land its bucket means within tolerance of
the original measurement.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import SLAConfig, ms
from repro.runtime import Calibration, RuntimeConfig, run_replay
from repro.serverless.latency import get_workload
from repro.simulation.arrivals import PoissonProcess, Schedule, sample_schedule
from repro.simulation.simulator import run_simulation

from benchmarks.common import (parity_policy_kwargs, transparent_platform,
                               write_csv)

POLICIES = ("passthrough", "static", "clipper", "oracle", "mlproxy")

#: Platform config that makes the simulated upstream a pure service-time
#: delay (the synthetic target's exact semantics) — the ONE shared
#: definition in benchmarks/common.py, also used by bench_deadlines.
TRANSPARENT_PLATFORM = transparent_platform()


def _rel_delta_pct(live: float, sim: float) -> float:
    denom = max(abs(sim), 1e-12)
    return 100.0 * abs(live - sim) / denom


def parity_rows(duration: float, seed: int) -> List[Dict]:
    wl = get_workload("pytorch-fashion-mnist")
    sla = SLAConfig(slo_target=ms(500))
    times = sample_schedule(PoissonProcess(rate=30.0, duration=duration),
                            seed, duration)
    rows: List[Dict] = []
    for policy in POLICIES:
        kw = parity_policy_kwargs(policy, wl)
        sim = run_simulation(
            policy=policy, sla=sla, workload=wl,
            arrivals=Schedule(times), platform_config=TRANSPARENT_PLATFORM,
            duration=duration, seed=seed, policy_kwargs=dict(kw),
        )
        live = run_replay(
            policy=policy, sla=sla, workload=wl, arrivals=Schedule(times),
            duration=duration, seed=seed, policy_kwargs=dict(kw),
        )
        s, l = sim.summary, live.summary
        sim_batches = sim.policy_stats.get("dispatched_batches", 0.0)
        rows.append({
            "kind": "parity",
            "policy": policy,
            "requests": int(len(times)),
            "sim_completed": s["completed"],
            "live_completed": l["completed"],
            "sim_p95_ms": round(s["p95"] * 1000, 2),
            "live_p95_ms": round(l["p95"] * 1000, 2),
            "rt95_delta_pct": round(_rel_delta_pct(l["p95"], s["p95"]), 2),
            "sim_viol_pct": round(s["violation_pct"], 3),
            "live_viol_pct": round(l["violation_pct"], 3),
            "viol_delta_abs_pct": round(
                abs(l["violation_pct"] - s["violation_pct"]), 3),
            "sim_batches": sim_batches,
            "live_batches": l["dispatched_batches"],
            "batches_delta_pct": round(
                _rel_delta_pct(l["dispatched_batches"], sim_batches), 2),
            "sim_avg_bs": round(s["avg_batch_size"], 3),
            "live_avg_bs": round(l["avg_batch_size"], 3),
            "live_rejected": l["rejected"],
            "live_lost": l["lost"],
        })
    return rows


def deadline_rows(duration: float, seed: int) -> List[Dict]:
    """Deadline + proxy-hedge parity: the same schedule with TIGHT
    per-request deadlines (budget = SLO/4, under the queue timeouts of
    static/oracle/mlproxy so expiry actually fires) and hedging at p95
    through both worlds.

    Acceptance: ``timed_out`` counts agree EXACTLY for the deterministic
    policies (passthrough / static / oracle — their dispatch decisions
    depend only on the shared schedule) and within 1% of submitted
    requests for mlproxy (whose timeout decisions depend on each world's
    own service-time draws); hedged-batch counts likewise.
    """
    wl = get_workload("pytorch-fashion-mnist")
    times = sample_schedule(PoissonProcess(rate=30.0, duration=duration),
                            seed, duration)
    rows: List[Dict] = []
    for policy in POLICIES:
        kw = parity_policy_kwargs(policy, wl)
        sla = SLAConfig(slo_target=ms(500), deadline_factor=0.25)
        sim = run_simulation(
            policy=policy, sla=sla, workload=wl,
            arrivals=Schedule(times), platform_config=TRANSPARENT_PLATFORM,
            duration=duration, seed=seed, policy_kwargs=dict(kw),
            hedge_quantile=95.0,
        )
        live = run_replay(
            policy=policy, sla=sla, workload=wl, arrivals=Schedule(times),
            duration=duration, seed=seed, policy_kwargs=dict(kw),
            config=RuntimeConfig(hedge_quantile=95.0),
        )
        s, l = sim.summary, live.summary
        n = max(1, len(times))
        rows.append({
            "kind": "deadline",
            "policy": policy,
            "requests": int(len(times)),
            "sim_timed_out": s["timed_out"],
            "live_timed_out": l["timed_out"],
            # deltas as a % of submitted requests/dispatches — the scale
            # the 1% acceptance tolerance is defined on
            "timed_out_delta_pct": round(
                100.0 * abs(l["timed_out"] - s["timed_out"]) / n, 3),
            "sim_hedged": s["hedged_batches"],
            "live_hedged": l["hedged_batches"],
            "hedged_delta_pct": round(
                100.0 * abs(l["hedged_batches"] - s["hedged_batches"])
                / max(1.0, sim.policy_stats.get("dispatched_batches", 1.0)),
                3),
            "sim_completed": s["completed"],
            "live_completed": l["completed"],
            "live_lost": live.conservation["lost"],
        })
    return rows


def calibration_rows(duration: float, seed: int) -> List[Dict]:
    """Measure (live) → fit → simulate round-trip, per bucket."""
    truth = get_workload("tfserving-mobilenet")
    sla = SLAConfig(slo_target=ms(1000))
    arrivals = PoissonProcess(rate=40.0, duration=duration)
    live = run_replay(
        policy="mlproxy", sla=sla, workload=truth, arrivals=arrivals,
        duration=duration, seed=seed,
        policy_kwargs={"bucketing": "pow2"},  # effective sizes = buckets
    )
    calib = Calibration.from_samples(live.bucket_samples, source="live:parity")
    sim_errors = calib.roundtrip_errors(seed=seed)

    # second live leg: replay against the FITTED model; its per-bucket
    # means must land back on the original measurement
    refit = run_replay(
        policy="mlproxy", sla=sla, workload=calib.measured_model(),
        arrivals=arrivals, duration=duration, seed=seed,
        policy_kwargs={"bucketing": "pow2"},
    )
    rows: List[Dict] = []
    for stat in calib.buckets:
        refit_samples = refit.bucket_samples.get(stat.bucket)
        refit_mean = (sum(refit_samples) / len(refit_samples)
                      if refit_samples else float("nan"))
        rows.append({
            "kind": "calibration",
            "bucket": stat.bucket,
            "n_samples": stat.n,
            "measured_mean_ms": round(stat.mean_s * 1000, 3),
            "truth_mean_ms": round(truth.mean(stat.bucket) * 1000, 3),
            "fit_affine_a_ms": round(calib.affine_a * 1000, 3),
            "fit_affine_c_ms": round(calib.affine_c * 1000, 3),
            "sim_roundtrip_err_pct": round(100 * sim_errors[stat.bucket], 2),
            "refit_live_mean_ms": round(refit_mean * 1000, 3),
        })
    return rows


def run(quick: bool = False) -> List[Dict]:
    duration = 120.0 if quick else 600.0
    rows = parity_rows(duration, seed=7)
    rows += deadline_rows(duration, seed=7)
    rows += calibration_rows(60.0 if quick else 300.0, seed=7)
    write_csv("live_parity.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
