"""Observability-plane bench: span waterfalls, identity, overhead, postmortem.

Four row kinds (the CI ``obs-smoke`` job gates on all of them):

* ``waterfall`` — an MMPP2 bursty-load chaos run in EACH world (discrete-
  event sim and FakeClock live runtime) with the tracer on; the full span
  log is exported as a Chrome ``trace_event`` JSON (load it in
  chrome://tracing or https://ui.perfetto.dev) plus a flat per-request
  CSV with the queue-wait / service / retry-overhead breakdown.
* ``identity`` — the same run with the tracer off must be byte-identical
  to the instrumented build's untraced path: dispatch, retry, and fault
  logs (live) and the summary dict (sim) are compared across a traced and
  an untraced run of the same seed. Any divergence means the tracing seam
  leaked into control flow.
* ``overhead`` — tracing-on cost on the scalar proxy decision loop
  (minimum over base/traced/base sandwich trials — same estimator as
  ``bench_proxy_overhead``, see ``tracing_overhead``); the CI gate
  asserts <= 10%.
* ``flightrec`` — a forced outage (crash_prob=1.0 through the breaker)
  must produce a parseable flight-recorder dump with a breaker_open
  reason and a non-empty event ring.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List

from benchmarks.common import OUT_DIR, write_csv

#: Where the waterfall artifacts and flight-recorder dumps land.
OBS_DIR = os.path.join(OUT_DIR, "obs")


def _obs_path(name: str) -> str:
    os.makedirs(OBS_DIR, exist_ok=True)
    return os.path.join(OBS_DIR, name)


def _mmpp(duration: float, rate: float):
    from repro.simulation.arrivals import MMPP2

    # bursty 2-state load: quiet floor at 20% of the target rate, bursts
    # at 180%, sojourns short enough that a 45 s quick run sees several
    return MMPP2(rate_lo=0.2 * rate, rate_hi=1.8 * rate,
                 mean_lo=8.0, mean_hi=4.0, duration=duration)


# ---------------------------------------------------------------- sim world
def _sim_run(duration: float, tracer=None, recorder=None):
    from repro.core import SLAConfig
    from repro.serverless.latency import get_workload
    from repro.serverless.platform import PlatformConfig
    from repro.simulation.simulator import Simulator

    workload = get_workload("pytorch-fashion-mnist")
    sim = Simulator(
        policy="mlproxy",
        sla=SLAConfig(slo_target=0.5),
        workload=workload,
        arrivals=_mmpp(duration, rate=25.0),
        platform_config=PlatformConfig(
            failure_prob_per_batch=0.05,
            straggler_prob=0.05,
            straggler_mult=4.0,
            hedge_factor=3.0,
        ),
        duration=duration,
        drain_grace=120.0,
        seed=11,
        tracer=tracer,
        recorder=recorder,
    )
    result = sim.run()
    sim.platform.assert_conserved(require_drained=True)
    return result


# --------------------------------------------------------------- live world
def _live_run(duration: float, tracer=None, recorder=None, *,
              crash_prob: float = 0.15):
    from experiments.scenarios import (
        LIVE_SCENARIOS,
        run_live_scenario,
    )
    from repro.runtime import FaultConfig

    sc = dataclasses.replace(
        LIVE_SCENARIOS["live-crash-storm"],
        faults=FaultConfig(crash_prob=crash_prob, crash_latency=0.01),
        duration=duration,
    )
    return run_live_scenario(sc, "mlproxy", faults=True,
                             tracer=tracer, recorder=recorder)


def _waterfall_row(world: str, tracer) -> Dict:
    from repro.obs import (
        build_batch_spans,
        build_request_spans,
        write_chrome_trace,
        write_request_csv,
    )

    events = tracer.events()
    trace_path = _obs_path(f"waterfall_{world}.trace.json")
    csv_path = _obs_path(f"waterfall_{world}.requests.csv")
    write_chrome_trace(trace_path, events)
    write_request_csv(csv_path, events)
    spans = build_request_spans(events)
    return {
        "kind": "waterfall",
        "world": world,
        "events": len(events),
        "requests": len(spans),
        "batches": len(build_batch_spans(events)),
        "completed_spans": sum(1 for s in spans
                               if s["outcome"] == "completed"),
        "dropped": tracer.dropped,
        "trace_json": os.path.relpath(trace_path, OUT_DIR),
        "request_csv": os.path.relpath(csv_path, OUT_DIR),
    }


def run(quick: bool = False) -> List[Dict]:
    from repro.obs import FlightRecorder, Tracer

    sim_dur = 60.0 if quick else 300.0
    live_dur = 30.0 if quick else 90.0
    rows: List[Dict] = []

    # -------- waterfalls: MMPP2 chaos run, tracer on, both worlds
    sim_tracer = Tracer()
    sim_traced = _sim_run(sim_dur, tracer=sim_tracer)
    rows.append(_waterfall_row("sim", sim_tracer))

    live_tracer = Tracer()
    live_traced = _live_run(live_dur, tracer=live_tracer)
    rows.append(_waterfall_row("live", live_tracer))

    # -------- identity: tracer off must not change a single decision
    sim_plain = _sim_run(sim_dur)
    live_plain = _live_run(live_dur)
    sim_identical = sim_plain.summary == sim_traced.summary
    live_identical = (
        live_plain.dispatch_log == live_traced.dispatch_log
        and live_plain.retry_log == live_traced.retry_log
        and live_plain.fault_log == live_traced.fault_log
        and live_plain.summary == live_traced.summary
    )
    rows.append({"kind": "identity", "world": "sim",
                 "identical": sim_identical})
    rows.append({"kind": "identity", "world": "live",
                 "identical": live_identical})

    # -------- overhead: tracing-on cost of the scalar decision loop
    from benchmarks.bench_proxy_overhead import tracing_overhead

    n = 20_000 if quick else 50_000
    base, traced, overhead_pct = tracing_overhead(n)
    rows.append({
        "kind": "overhead",
        "world": "core",
        "base_per_s": round(base),
        "traced_per_s": round(traced),
        "overhead_pct": round(overhead_pct, 2),
    })

    # -------- flight recorder: a forced outage must leave a postmortem
    recorder = FlightRecorder(out_dir=OBS_DIR)
    _live_run(15.0 if quick else 30.0, tracer=None, recorder=recorder,
              crash_prob=1.0)
    parseable = False
    dump_path = ""
    if recorder.dumps:
        dump_path = recorder.dumps[-1]
        with open(dump_path) as f:
            doc = json.load(f)
        parseable = (bool(doc.get("reason"))
                     and isinstance(doc.get("events"), list)
                     and len(doc["events"]) > 0)
    rows.append({
        "kind": "flightrec",
        "world": "live",
        "dumps": len(recorder.dumps),
        "parseable": parseable,
        "dump_path": (os.path.relpath(dump_path, OUT_DIR)
                      if dump_path else ""),
    })

    write_csv("bench_obs.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(r)
