"""Beyond-paper: policy shoot-out — MLProxy vs passthrough, static batching,
Clipper-style AIMD, and the profiled-oracle (BATCH-style) baseline, on the
same workload/trace, including a fault-injection variant (container crashes
+ stragglers with hedging) to exercise the reliability path."""
from __future__ import annotations

from typing import Dict, List

from repro.core import SLAConfig, ms
from repro.serverless.latency import get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import TraceModulatedPoisson
from repro.simulation.simulator import run_simulation
from repro.simulation.traces import synthetic_trace

from benchmarks.common import write_csv

POLICIES = ("passthrough", "static", "clipper", "oracle", "mlproxy")


def run(quick: bool = False) -> List[Dict]:
    duration = 600.0 if quick else 1500.0
    warmup = duration / 5
    wl = get_workload("pytorch-fashion-mnist")
    sla = SLAConfig(slo_target=ms(500))
    rows: List[Dict] = []
    for faults in (False, True):
        pc = PlatformConfig(
            initial_scale=1,
            failure_prob_per_batch=0.002 if faults else 0.0,
            straggler_prob=0.01 if faults else 0.0,
            straggler_mult=5.0,
            hedge_factor=3.0 if faults else 0.0,
        )
        for policy in POLICIES:
            kw = {}
            if policy == "static":
                kw = {"batch_size": 8, "timeout": 0.2}
            elif policy == "oracle":
                kw = {"latency_model": lambda bs: wl.percentile(bs, 95)}
            trace = synthetic_trace("wc", duration=duration, seed=3).scaled(30)
            res = run_simulation(
                policy=policy, sla=sla, workload=wl,
                arrivals=TraceModulatedPoisson(trace), platform_config=pc,
                duration=duration, warmup=warmup, seed=11,
                policy_kwargs=kw,
            )
            s = res.summary
            rows.append({
                "policy": policy,
                "faults": faults,
                "containers": round(s["avg_containers"], 3),
                "viol_pct": round(s["violation_pct"], 4),
                "avg_bs": round(s["avg_batch_size"], 2),
                "p95_ms": round(s["p95"] * 1000, 1),
                "failed_attempts": s["failed_attempts"],
                "hedged": s["hedged_dispatches"],
                "completed": s["completed"],
            })
    write_csv("policy_comparison.csv", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
