"""Tests for the deadline-aware request lifecycle (ISSUE 5).

Covers the acceptance points: BatchQueue expiry mechanics (sweep before
batch formation, timer wake-up for expiries, terminal ``timed_out``
state), deadline derivation at admission, expiry under all five policies
in BOTH worlds (discrete-event sim and FakeClock runtime) with the
extended conservation ledger (``submitted == completed + rejected +
timed_out + failed``), deadline propagation to dispatch targets,
proxy-tier straggler hedging (first completion wins, loser cancelled,
deterministic under FakeClock), and the duplicate-submit / drain-timeout
regressions.
"""
import asyncio

import numpy as np
import pytest

from repro.core import SLAConfig, ms
from repro.core.batch_queue import BatchQueue
from repro.core.frontend import ProxyFrontend
from repro.core.policies import make_policy
from repro.core.request import Batch, Request
from repro.runtime import (AsyncProxyServer, DeadlineExceeded, DrainTimeout,
                           FakeClock, RuntimeConfig, SyntheticTarget, run,
                           run_replay)
from repro.serverless.latency import AffineLatency, get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import PoissonProcess, Schedule, sample_schedule
from repro.simulation.simulator import (EndpointSpec, run_multi_simulation,
                                        run_simulation)

SLA = SLAConfig(slo_target=ms(500))
WL = get_workload("pytorch-fashion-mnist")
ALL_POLICIES = ("passthrough", "static", "clipper", "oracle", "mlproxy")

TRANSPARENT = PlatformConfig(
    container_concurrency=10**6, cold_start=0.0, min_scale=1, max_scale=1,
    initial_scale=1, ps_slowdown=0.0, scale_to_zero_grace=1e12,
)


def policy_kwargs(policy):
    if policy == "static":
        return {"batch_size": 8, "timeout": 0.2}
    if policy == "oracle":
        return {"latency_model": lambda bs: WL.percentile(bs, 95)}
    return {}


# ------------------------------------------------------------ core expiry
class TestBatchQueueExpiry:
    def _queue(self, dispatched, expired=None):
        return BatchQueue(
            dispatched.append,
            expire_fn=(lambda reqs, now: expired.extend(reqs))
            if expired is not None else None,
        )

    def test_expire_evicts_marks_and_counts(self):
        dispatched, expired = [], []
        q = self._queue(dispatched, expired)
        live = Request(arrival_time=0.0, deadline=10.0)
        dead = Request(arrival_time=0.0, deadline=1.0)
        q.append(dead, 0.0)
        q.append(live, 0.5)
        out = q.expire(2.0)
        assert out == [dead] and dead.timed_out
        assert expired == [dead]
        assert q.expired_requests == 1
        assert q.queue_len == 1 and not live.timed_out
        # FRT re-anchors on the surviving head's arrival
        assert q.first_arrival == live.arrival_time

    def test_expire_fast_path_without_deadlines(self):
        q = self._queue([])
        q.append(Request(arrival_time=0.0), 0.0)
        assert q.expire(1e9) == []
        assert q.queue_len == 1 and q.expired_requests == 0

    def test_dispatch_sweeps_before_batch_formation(self):
        dispatched = []
        q = self._queue(dispatched)
        q.append(Request(arrival_time=0.0, deadline=1.0), 0.0)
        q.append(Request(arrival_time=0.0, deadline=99.0), 0.0)
        batch = q._dispatch(2.0, "full")
        assert batch is not None and batch.size == 1
        assert dispatched[0].requests[0].deadline == 99.0
        assert q.expired_requests == 1

    def test_dispatch_returns_none_when_all_expired(self):
        dispatched = []
        q = self._queue(dispatched)
        q.append(Request(arrival_time=0.0, deadline=1.0), 0.0)
        assert q._dispatch(5.0, "timeout") is None
        assert dispatched == []
        assert q.queue_len == 0 and q.next_deadline is None
        assert q.dispatched_batches == 0

    def test_next_event_time_merges_expiry_and_deadline(self):
        q = self._queue([])
        q.append(Request(arrival_time=0.0, deadline=3.0), 0.0)
        q.next_deadline = 5.0
        assert q.next_expiry() == 3.0
        assert q.next_event_time() == 3.0
        q.next_deadline = 2.0
        assert q.next_event_time() == 2.0

    def test_snapshot_roundtrip_preserves_expiry_state(self):
        q = self._queue([])
        q.append(Request(arrival_time=0.0, deadline=1.0), 0.0)
        q.append(Request(arrival_time=0.0, deadline=4.0), 0.0)
        q.expire(2.0)
        state = q.snapshot()
        q2 = self._queue([])
        q2.restore(state)
        assert q2.expired_requests == 1
        assert q2.next_expiry() == 4.0
        # legacy snapshots (no expiry key) restore cleanly
        del state["expired_requests"]
        q3 = self._queue([])
        q3.restore(state)
        assert q3.expired_requests == 0 and q3.next_expiry() == 4.0

    def test_batch_tightest_deadline(self):
        reqs = [Request(arrival_time=0.0, deadline=d)
                for d in (None, 7.0, 3.0)]
        assert Batch(requests=reqs, dispatch_time=0.0,
                     cause="full").tightest_deadline == 3.0
        assert Batch(requests=[Request(arrival_time=0.0)], dispatch_time=0.0,
                     cause="full").tightest_deadline is None


class TestPolicyExpiryWakeup:
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_next_event_time_covers_earliest_expiry(self, policy):
        """The merged timer must wake for the earliest expiry, not only
        the dispatch deadline."""
        dispatched = []
        pol = make_policy(policy, SLA, dispatched.append,
                          **policy_kwargs(policy))
        if policy == "passthrough":
            pytest.skip("passthrough never queues")
        r = Request(arrival_time=0.0, deadline=0.01)  # expires almost now
        pol.on_request(r, 0.0)
        if dispatched:
            pytest.skip(f"{policy} dispatched immediately at this state")
        nxt = pol.next_event_time(0.0)
        assert nxt is not None and nxt <= 0.01
        pol.on_timer(0.02)
        assert r.timed_out and not dispatched
        assert pol.stats(0.02)["expired"] == 1

    def test_frontend_derives_deadline_at_admission(self):
        fe = ProxyFrontend()
        fe.add_endpoint("ep", sla=SLAConfig(slo_target=0.4, deadline_factor=2.0),
                        dispatch_fn=lambda b: None, policy="static",
                        policy_kwargs={"batch_size": 8, "timeout": 10.0})
        derived = Request(arrival_time=1.0)
        fe.on_request(derived, 1.0, endpoint="ep")
        assert derived.deadline == pytest.approx(1.0 + 0.8)
        # a client-supplied deadline is honored as-is
        client = Request(arrival_time=2.0, deadline=2.05)
        fe.on_request(client, 2.0, endpoint="ep")
        assert client.deadline == 2.05
        # aggregate expired accounting flows through frontend stats
        fe.on_timer(10.0)
        st = fe.stats(10.0)
        assert st["aggregate"]["expired"] == 2
        assert st["endpoints"]["ep"]["expired"] == 2


# ------------------------------------------------------------- simulation
class TestSimulatorExpiry:
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_expiry_conserves_under_all_policies(self, policy):
        sla = SLAConfig(slo_target=ms(500), deadline_factor=0.25)
        res = run_simulation(
            policy=policy, sla=sla, workload=WL,
            arrivals=PoissonProcess(rate=30.0, duration=30.0),
            platform_config=TRANSPARENT, duration=30.0, seed=3,
            policy_kwargs=policy_kwargs(policy),
        )
        s = res.summary
        assert s["submitted_requests"] == s["completed"] + s["timed_out"]
        if policy == "static":
            # budget (125ms) < static queue timeout (200ms): partial
            # batches MUST shed queued work pre-dispatch
            assert s["timed_out"] > 0

    def test_expired_never_dispatched_and_not_billed(self):
        """With a deadline tighter than the only dispatch path, every
        request times out and the upstream sees zero batches."""
        sla = SLAConfig(slo_target=ms(500), deadline_factor=0.1)  # 50ms
        res = run_simulation(
            policy="static", sla=sla, workload=WL,
            arrivals=PoissonProcess(rate=2.0, duration=20.0),
            platform_config=TRANSPARENT, duration=20.0, seed=0,
            policy_kwargs={"batch_size": 64, "timeout": 5.0},
        )
        s = res.summary
        assert s["completed"] == 0
        assert s["timed_out"] == s["submitted_requests"] > 0
        assert s["submitted_batches"] == 0  # platform never invoked

    def test_multi_endpoint_expiry_accounting(self):
        specs = {
            "tight": EndpointSpec(
                policy="static",
                sla=SLAConfig(slo_target=ms(400), deadline_factor=0.25),
                workload=WL,
                arrivals=PoissonProcess(rate=20.0, duration=20.0),
                policy_kwargs={"batch_size": 16, "timeout": 0.3},
                platform_config=TRANSPARENT,
            ),
            "loose": EndpointSpec(
                policy="static",
                sla=SLAConfig(slo_target=ms(400)),
                workload=WL,
                arrivals=PoissonProcess(rate=20.0, duration=20.0),
                policy_kwargs={"batch_size": 4, "timeout": 0.05},
                platform_config=TRANSPARENT,
            ),
        }
        res = run_multi_simulation(specs, duration=20.0, seed=1)
        for name, ep in res.endpoints.items():
            assert ep["submitted_requests"] == ep["completed"] + ep["timed_out"], name
        assert res.endpoints["tight"]["timed_out"] > 0
        assert res.endpoints["loose"]["timed_out"] == 0
        assert res.summary["timed_out"] == res.endpoints["tight"]["timed_out"]

    def test_no_deadline_is_bitwise_noop(self):
        """deadline_factor=None must not perturb the event stream."""
        kw = dict(policy="mlproxy", sla=SLA, workload=WL,
                  arrivals=PoissonProcess(rate=30.0, duration=30.0),
                  platform_config=TRANSPARENT, duration=30.0, seed=5)
        a = run_simulation(**kw)
        b = run_simulation(**kw)
        np.testing.assert_array_equal(a.e2e_latencies, b.e2e_latencies)
        assert a.summary["timed_out"] == 0


# ---------------------------------------------------------------- runtime
class TestRuntimeExpiry:
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_ledger_conserves_with_timed_out(self, policy):
        sla = SLAConfig(slo_target=ms(500), deadline_factor=0.25)
        res = run_replay(
            policy=policy, sla=sla, workload=WL,
            arrivals=PoissonProcess(rate=30.0, duration=30.0), duration=30.0,
            seed=3, policy_kwargs=policy_kwargs(policy),
        )
        c = res.conservation
        assert c["lost"] == 0 and c["outstanding"] == 0
        assert c["submitted"] == (c["completed"] + c["rejected"]
                                  + c["timed_out"] + c["failed"])
        if policy == "static":
            assert c["timed_out"] > 0

    def test_expired_ticket_resolves_with_deadline_exceeded(self):
        clock = FakeClock()
        server = AsyncProxyServer(clock=clock)
        server.add_endpoint(
            "ep", sla=SLAConfig(slo_target=ms(500), deadline_factor=0.2),
            target=SyntheticTarget(WL, clock, rng=np.random.default_rng(0)),
            policy="static", policy_kwargs={"batch_size": 64, "timeout": 60.0},
        )

        async def main():
            await server.start()
            ticket = server.submit(endpoint="ep")
            resolved = await ticket.future
            # queue timeout (60s) never fires before the 100ms deadline
            assert clock.now() == pytest.approx(0.1)
            return resolved

        ticket = run(clock, main())
        assert ticket.timed_out and not ticket.rejected
        assert isinstance(ticket.error, DeadlineExceeded)
        assert ticket.request.timed_out
        assert server.timed_out == 1 and server.completed == 0
        server.assert_conserved()

    def test_max_queue_does_not_count_dead_requests(self):
        """Regression: a submit arriving after queued requests' deadlines
        passed (but before the timer sweep) must not be rejected by a
        queue cap counting the dead requests."""
        clock = FakeClock()
        server = AsyncProxyServer(clock=clock,
                                  config=RuntimeConfig(max_queue=2))
        server.add_endpoint(
            "ep", sla=SLAConfig(slo_target=ms(500), deadline_factor=0.2),
            target=SyntheticTarget(WL, clock, rng=np.random.default_rng(0)),
            policy="static", policy_kwargs={"batch_size": 64, "timeout": 60.0},
        )

        async def main():
            await server.start()
            dead = [server.submit(endpoint="ep") for _ in range(2)]
            assert not any(t.rejected for t in dead)
            # jump past their 100ms deadline WITHOUT letting the timer
            # loop run its sweep first: advance behind the loop's back
            await clock.sleep(0.0999999)
            clock._now += 0.01
            fresh = server.submit(endpoint="ep")
            assert not fresh.rejected  # cap saw a swept (empty) queue
            assert all(t.timed_out for t in dead)
            await server.drain()
            return fresh

        fresh = run(clock, main())
        assert not fresh.timed_out
        server.assert_conserved(require_drained=True)

    def test_deadline_propagates_to_target(self):
        clock = FakeClock()
        target = SyntheticTarget(AffineLatency(a=0.01, c=0.0, noise_cv=0.0),
                                 clock, rng=np.random.default_rng(0))
        server = AsyncProxyServer(clock=clock)
        server.add_endpoint(
            "ep", sla=SLAConfig(slo_target=ms(500), deadline_factor=1.0),
            target=target, policy="static",
            policy_kwargs={"batch_size": 2, "timeout": 5.0},
        )

        async def main():
            await server.start()
            t0 = server.submit(endpoint="ep")
            await clock.sleep(0.05)
            t1 = server.submit(endpoint="ep")  # batch full -> dispatch
            await server.drain()
            return t0, t1

        run(clock, main())
        # tightest member deadline = first request's arrival + 500ms
        assert target.last_deadline == pytest.approx(0.5)

    def test_legacy_target_without_deadline_param_still_works(self):
        class LegacyTarget:
            max_batch = None

            def __init__(self, clock):
                self.clock = clock
                self.calls = 0

            async def __call__(self, batch):  # no deadline= parameter
                self.calls += 1
                await self.clock.sleep(0.01)

        clock = FakeClock()
        target = LegacyTarget(clock)
        server = AsyncProxyServer(clock=clock)
        server.add_endpoint(
            "ep", sla=SLAConfig(slo_target=ms(500), deadline_factor=1.0),
            target=target, policy="passthrough",
        )

        async def main():
            await server.start()
            server.submit(endpoint="ep")
            await server.drain()

        run(clock, main())
        assert target.calls == 1 and server.completed == 1


# ---------------------------------------------------------------- hedging
class _ScriptedTarget:
    """Deterministic target whose call latencies follow a script."""

    max_batch = None

    def __init__(self, clock, script):
        self.clock = clock
        self.script = list(script)
        self.calls = 0
        self.completed = 0
        self.cancelled = 0
        self.deadlines = []

    async def __call__(self, batch, deadline=None):
        self.deadlines.append(deadline)
        delay = self.script[min(self.calls, len(self.script) - 1)]
        self.calls += 1
        try:
            await self.clock.sleep(delay)
        except asyncio.CancelledError:
            self.cancelled += 1
            raise
        self.completed += 1


def _primed_server(clock, target, hedge_quantile=95.0):
    """Server with a passthrough endpoint whose bucket-1 window is warm
    (10 × 100ms samples → hedge threshold 0.1s)."""
    server = AsyncProxyServer(
        clock=clock, config=RuntimeConfig(hedge_quantile=hedge_quantile,
                                          hedge_min_samples=10))
    server.add_endpoint("ep", sla=SLA, target=target, policy="passthrough")
    monitor = server.frontend.endpoint("ep").policy.monitor
    for _ in range(10):
        monitor.record_upstream(1, 0.1, 0.0)
    return server


class TestProxyHedging:
    def test_hedge_fires_and_winner_cancels_loser(self):
        clock = FakeClock()
        target = _ScriptedTarget(clock, [10.0, 0.05])  # primary stuck
        server = _primed_server(clock, target)

        async def main():
            await server.start()
            ticket = server.submit(endpoint="ep")
            await ticket.future
            await server.drain()
            return ticket

        ticket = run(clock, main())
        assert not ticket.timed_out
        # hedge armed at 0.1 (p95 of primed window), wins at 0.1 + 0.05
        assert clock.now() == pytest.approx(0.15)
        assert server.hedged_batches == 1 and server.hedge_wins == 1
        assert target.calls == 2
        assert target.completed == 1 and target.cancelled == 1
        assert server.completed == 1
        server.assert_conserved(require_drained=True)

    def test_fast_primary_never_hedges(self):
        clock = FakeClock()
        target = _ScriptedTarget(clock, [0.05])
        server = _primed_server(clock, target)

        async def main():
            await server.start()
            server.submit(endpoint="ep")
            await server.drain()

        run(clock, main())
        assert server.hedged_batches == 0 and target.calls == 1

    def test_primary_beats_hedge(self):
        """Primary slower than the threshold but faster than the hedge:
        primary wins, hedge is the cancelled loser."""
        clock = FakeClock()
        target = _ScriptedTarget(clock, [0.2, 9.0])
        server = _primed_server(clock, target)

        async def main():
            await server.start()
            server.submit(endpoint="ep")
            await server.drain()

        run(clock, main())
        assert server.hedged_batches == 1 and server.hedge_wins == 0
        assert target.completed == 1 and target.cancelled == 1
        assert clock.now() == pytest.approx(0.2)

    def test_hedge_determinism_same_seed(self):
        kw = dict(policy="mlproxy", sla=SLA,
                  workload=AffineLatency(a=0.05, c=0.005, noise_cv=0.5),
                  arrivals=PoissonProcess(rate=30.0, duration=40.0),
                  duration=40.0, seed=9,
                  config=RuntimeConfig(hedge_quantile=90.0))
        a = run_replay(**kw)
        b = run_replay(**kw)
        assert a.summary["hedged_batches"] == b.summary["hedged_batches"] > 0
        assert a.dispatch_log == b.dispatch_log
        np.testing.assert_array_equal(a.e2e_latencies, b.e2e_latencies)

    def test_hedged_batch_counts_as_retry(self):
        """A won hedge stamps attempts=2, feeding the retry-aware stats."""
        clock = FakeClock()
        target = _ScriptedTarget(clock, [10.0, 0.05])
        server = _primed_server(clock, target)

        async def main():
            await server.start()
            server.submit(endpoint="ep")
            await server.drain()

        run(clock, main())
        st = server.frontend.stats(clock.now())["endpoints"]["ep"]
        assert st["retried_batches"] == 1

    def test_sim_live_hedge_counts_agree_exactly_for_static(self):
        duration = 60.0
        times = sample_schedule(PoissonProcess(rate=30.0, duration=duration),
                                7, duration)
        sla = SLAConfig(slo_target=ms(500), deadline_factor=1.0)
        kw = {"batch_size": 8, "timeout": 0.2}
        sim = run_simulation(
            policy="static", sla=sla, workload=WL, arrivals=Schedule(times),
            platform_config=TRANSPARENT, duration=duration, seed=7,
            policy_kwargs=dict(kw), hedge_quantile=95.0)
        live = run_replay(
            policy="static", sla=sla, workload=WL, arrivals=Schedule(times),
            duration=duration, seed=7, policy_kwargs=dict(kw),
            config=RuntimeConfig(hedge_quantile=95.0))
        assert live.summary["hedged_batches"] == sim.summary["hedged_batches"]
        assert live.summary["timed_out"] == sim.summary["timed_out"]
        assert live.summary["completed"] == sim.summary["completed"]


# ------------------------------------------------------------ regressions
class TestSubmitDuplicate:
    def test_duplicate_outstanding_req_id_raises(self):
        clock = FakeClock()
        server = AsyncProxyServer(clock=clock)
        server.add_endpoint(
            "ep", sla=SLA,
            target=SyntheticTarget(WL, clock, rng=np.random.default_rng(0)),
            policy="static", policy_kwargs={"batch_size": 8, "timeout": 60.0},
        )

        async def main():
            await server.start()
            req = Request(arrival_time=clock.now())
            server.submit(req, endpoint="ep")
            with pytest.raises(ValueError, match="already outstanding"):
                server.submit(req, endpoint="ep")
            await server.drain()

        run(clock, main())
        # the failed submit must not skew the ledger: one request in,
        # one completed, zero lost
        c = server.assert_conserved(require_drained=True)
        assert c["submitted"] == 1 and c["completed"] == 1

    def test_resubmit_after_completion_is_allowed(self):
        clock = FakeClock()
        server = AsyncProxyServer(clock=clock)
        server.add_endpoint(
            "ep", sla=SLA,
            target=SyntheticTarget(WL, clock, rng=np.random.default_rng(0)),
            policy="passthrough",
        )

        async def main():
            await server.start()
            req = Request(arrival_time=clock.now())
            await server.submit(req, endpoint="ep").future
            req.completion_time = None  # recycle the id after completion
            await server.submit(req, endpoint="ep").future
            await server.drain()

        run(clock, main())
        assert server.completed == 2


class _StuckTarget:
    max_batch = None

    def __init__(self):
        self.cancelled = 0

    async def __call__(self, batch, deadline=None):
        try:
            await asyncio.Event().wait()  # never completes
        except asyncio.CancelledError:
            self.cancelled += 1
            raise


class TestDrainTimeout:
    def test_drain_timeout_cancels_stuck_target(self):
        clock = FakeClock()
        target = _StuckTarget()
        server = AsyncProxyServer(clock=clock)
        server.add_endpoint("ep", sla=SLA, target=target, policy="passthrough")

        async def main():
            await server.start()
            tickets = [server.submit(endpoint="ep") for _ in range(3)]
            await server.drain(timeout=5.0)
            return tickets

        tickets = run(clock, main())
        assert clock.now() == pytest.approx(5.0)  # returned AT the bound
        assert server.failed == 3
        assert target.cancelled == 3
        for t in tickets:
            assert isinstance(t.future.exception(), DrainTimeout)
        c = server.assert_conserved(require_drained=True)
        assert c["lost"] == 0 and c["outstanding"] == 0

    def test_drain_timeout_noop_when_work_finishes_first(self):
        res = run_replay(
            policy="mlproxy", sla=SLA, workload=WL,
            arrivals=PoissonProcess(rate=30.0, duration=10.0), duration=10.0,
            seed=1,
        )
        assert res.conservation["failed"] == 0  # sanity: normal path

        clock = FakeClock()
        server = AsyncProxyServer(clock=clock)
        server.add_endpoint(
            "ep", sla=SLA,
            target=SyntheticTarget(WL, clock, rng=np.random.default_rng(0)),
            policy="passthrough",
        )

        async def main():
            await server.start()
            server.submit(endpoint="ep")
            await server.drain(timeout=60.0)

        run(clock, main())
        assert server.failed == 0 and server.completed == 1
        assert clock.now() < 1.0  # did not sit out the full timeout

    def test_midrun_target_failure_resolves_as_target_error(self):
        """A target that raises mid-run degrades ONE batch, not shutdown:
        its tickets resolve with a classified TargetError (original
        exception chained) and the drained conservation assert passes —
        the fault-tolerance reversal of the pre-PR-8 behaviour, where
        any mid-run failure tripped assert_conserved at drain."""
        from repro.runtime.server import TargetError

        class BrokenTarget:
            max_batch = None

            async def __call__(self, batch, deadline=None):
                raise RuntimeError("upstream bug")

        clock = FakeClock()
        server = AsyncProxyServer(clock=clock)
        server.add_endpoint("ep", sla=SLA, target=BrokenTarget(),
                            policy="passthrough")

        async def main():
            await server.start()
            ticket = server.submit(endpoint="ep")
            with pytest.raises(TargetError, match="upstream bug"):
                await ticket.future
            assert isinstance(ticket.future.exception().__cause__,
                              RuntimeError)
            await server.drain(timeout=10.0)  # drained assert passes

        run(clock, main())
        assert server.failed == 1 and server.drain_cancelled == 0
        assert server.target_failures == 1
        c = server.assert_conserved(require_drained=True)
        assert c["lost"] == 0 and c["retry_exhausted"] == 1

    def test_wall_clock_drain_timeout_returns(self):
        """Real wall-clock: a stuck upstream cannot hang drain()."""
        from repro.runtime import WallClock

        clock = WallClock()
        server = AsyncProxyServer(clock=clock)
        server.add_endpoint("ep", sla=SLA, target=_StuckTarget(),
                            policy="passthrough")

        async def main():
            await server.start()
            server.submit(endpoint="ep")
            await server.drain(timeout=0.2)

        run(clock, main())
        assert server.failed == 1
        server.assert_conserved(require_drained=True)


# ------------------------------------------------------- summary plumbing
class TestSummaryFixes:
    def test_throughput_uses_active_window(self):
        """A clock predating the server must not deflate throughput."""
        clock = FakeClock(start=1000.0)  # long-lived clock, late server
        server = AsyncProxyServer(clock=clock)
        server.add_endpoint(
            "ep", sla=SLA,
            target=SyntheticTarget(AffineLatency(a=0.1, c=0.0, noise_cv=0.0),
                                   clock, rng=np.random.default_rng(0)),
            policy="passthrough",
        )

        async def main():
            await server.start()
            for _ in range(10):
                server.submit(endpoint="ep")
                await clock.sleep(0.1)
            await server.drain()

        run(clock, main())
        s = server.summary()
        # active window ≈ 1.0s for 10 requests → ~10 rps, NOT 10/1001
        assert s["throughput"] == pytest.approx(10.0, rel=0.15)

    def test_summary_surfaces_deadline_and_hedge_keys(self):
        sla = SLAConfig(slo_target=ms(500), deadline_factor=0.25)
        res = run_replay(
            policy="static", sla=sla, workload=WL,
            arrivals=PoissonProcess(rate=30.0, duration=15.0), duration=15.0,
            seed=3, policy_kwargs={"batch_size": 8, "timeout": 0.2},
        )
        s = res.summary
        assert s["timed_out"] > 0
        assert s["endpoints"]["ep"]["timed_out"] == s["timed_out"]
        for key in ("failed", "hedged_batches", "hedge_wins"):
            assert key in s


# -------------------------------------- EngineTarget deadline translation
class _StubPoolTarget:
    """ReplicaPoolTarget stand-in: a measurement clock on its OWN epoch
    (raw monotonic starts at machine uptime, not at server start)."""

    class _Cfg:
        batch_buckets = (1, 2, 4)

    class _Pool:
        engine_cfg = None  # set below
        replicas = [object()]

    def __init__(self, epoch=1000.0):
        self.pool = self._Pool()
        self.pool.engine_cfg = self._Cfg()
        self._epoch = epoch
        self.seen = []

    def clock(self):
        return self._epoch

    def __call__(self, batch, deadline=None):
        self.seen.append(deadline)


class TestEngineTargetDeadlineDomains:
    """Regression for the clock-domain bug the reprolint `wallclock` rule
    surfaced: runtime-clock deadlines (small, epoch = server start) were
    forwarded raw to the pool target's monotonic clock (huge, epoch =
    machine boot), so every follow-up chunk aborted spuriously."""

    def test_deadline_translated_into_pool_clock_domain(self):
        from repro.runtime.targets import EngineTarget

        clock = FakeClock()
        stub = _StubPoolTarget(epoch=1000.0)
        target = EngineTarget(stub, clock=clock)
        batch = Batch(requests=[Request(arrival_time=0.0)],
                      dispatch_time=0.0, cause="full")
        asyncio.run(target(batch, deadline=0.75))
        # remaining budget 0.75s carried onto the pool's epoch
        assert stub.seen == [pytest.approx(1000.75)]

    def test_without_runtime_clock_forwards_none_not_wrong_epoch(self):
        from repro.runtime.targets import EngineTarget

        stub = _StubPoolTarget(epoch=1000.0)
        target = EngineTarget(stub)  # no runtime clock wired
        batch = Batch(requests=[Request(arrival_time=0.0)],
                      dispatch_time=0.0, cause="full")
        asyncio.run(target(batch, deadline=0.75))
        assert stub.seen == [None]

    def test_no_deadline_stays_none(self):
        from repro.runtime.targets import EngineTarget

        clock = FakeClock()
        stub = _StubPoolTarget()
        target = EngineTarget(stub, clock=clock)
        batch = Batch(requests=[Request(arrival_time=0.0)],
                      dispatch_time=0.0, cause="full")
        asyncio.run(target(batch))
        assert stub.seen == [None]
