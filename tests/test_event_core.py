"""Event-core regression tests: generation-stamped policy timers, the
vectorized arrival pump end-to-end, and per-seed determinism under the
named RNG stream split (arrivals / service / faults)."""
import numpy as np
import pytest

from repro.core import SLAConfig
from repro.serverless.latency import AffineLatency, get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import DeterministicProcess, MMPP2, PoissonProcess
from repro.simulation.events import EventQueue
from repro.simulation.simulator import (
    EndpointSpec,
    MultiEndpointSimulator,
    Simulator,
    _EventLoopDriver,
    run_multi_simulation,
    run_simulation,
)


# ------------------------------------------------- generation-stamped timers
class _ScriptedControl:
    """Policy-shaped stub whose deadline the test manipulates directly."""

    def __init__(self):
        self.deadline = None
        self.on_timer_calls = 0

    def on_timer(self, now):
        self.on_timer_calls += 1
        self.deadline = None

    def next_event_time(self, now):
        return self.deadline

    def flush(self, now):
        pass


class _Driver(_EventLoopDriver):
    def __init__(self, control, duration=100.0):
        self.events = EventQueue()
        self.now = 0.0
        self.duration = duration
        self.drain_grace = 0.0
        self._timer_scheduled_at = None
        self._timer_gen = 0
        self.events_processed = 0
        self.ctrl = control

    def _control(self):
        return self.ctrl


def test_superseded_timer_entries_do_not_fire():
    # Rapid reschedules to ever-earlier deadlines leave a stale heap entry
    # behind per reschedule; only the newest generation may invoke
    # on_timer. (Pre-fix, every stale entry fired: 10 calls, not 1.)
    ctrl = _ScriptedControl()
    drv = _Driver(ctrl)
    for deadline in range(10, 0, -1):  # 10, 9, ..., 1
        ctrl.deadline = float(deadline)
        drv._reschedule_policy_timer()
    assert len(drv.events) == 10  # one heap entry per reschedule
    drv._drive()
    assert ctrl.on_timer_calls == 1


def test_timer_refires_after_serving_a_deadline():
    class _Repeating(_ScriptedControl):
        def on_timer(self, now):
            self.on_timer_calls += 1
            # ask for one follow-up deadline after the first firing
            self.deadline = 5.0 if self.on_timer_calls == 1 else None

    ctrl = _Repeating()
    drv = _Driver(ctrl)
    ctrl.deadline = 2.0
    drv._reschedule_policy_timer()
    drv._drive()
    assert ctrl.on_timer_calls == 2  # t=2 then t=5


def test_later_deadline_does_not_duplicate_scheduled_timer():
    ctrl = _ScriptedControl()
    drv = _Driver(ctrl)
    ctrl.deadline = 5.0
    drv._reschedule_policy_timer()
    ctrl.deadline = 7.0  # later than what's scheduled: no new entry
    drv._reschedule_policy_timer()
    assert len(drv.events) == 1


def test_rapid_reschedules_in_simulation_fire_bounded_timers():
    # End-to-end: high-rate arrivals constantly cancel/recompute the
    # dispatch deadline. Timer firings must stay far below the number of
    # reschedules (stale entries dropped), and the run must still work.
    sla = SLAConfig(slo_target=0.5)
    sim = Simulator(
        policy="static", sla=sla, workload=get_workload("sklearn-iris"),
        arrivals=PoissonProcess(rate=500.0, duration=20.0),
        platform_config=PlatformConfig(initial_scale=2),
        policy_kwargs={"batch_size": 64, "timeout": 0.05},
        duration=20.0, seed=3,
    )
    res = sim.run()
    assert res.summary["completed"] > 9000
    assert res.summary["lost_batches"] == 0


# --------------------------------------------------------- pump end-to-end
def test_simulator_with_deterministic_pump_completes_every_arrival():
    sla = SLAConfig(slo_target=5.0)
    res = run_simulation(
        policy="static", sla=sla,
        workload=AffineLatency(a=0.05, c=0.0, noise_cv=0.0),
        arrivals=DeterministicProcess(gap=0.25, duration=30.0),
        platform_config=PlatformConfig(initial_scale=1, min_scale=1),
        policy_kwargs={"batch_size": 4, "timeout": 0.5},
        duration=30.0, seed=0,
    )
    # arrivals at 0.25, 0.5, ..., 29.75 -> 119 requests, all completed
    assert res.summary["completed"] == 119.0
    assert res.summary["lost_batches"] == 0.0


def test_events_processed_counter_advances():
    sla = SLAConfig(slo_target=0.5)
    sim = Simulator(
        policy="mlproxy", sla=sla, workload=get_workload("sklearn-iris"),
        arrivals=PoissonProcess(rate=50.0, duration=30.0),
        platform_config=PlatformConfig(initial_scale=1),
        duration=30.0, seed=1,
    )
    res = sim.run()
    # at least one event per arrival + one per completion callback
    assert sim.events_processed > res.summary["completed"]


# ------------------------------------------------------------- determinism
def _multi_kwargs(seed=5):
    spec = dict(
        sla=SLAConfig(slo_target=0.5),
        workload=get_workload("sklearn-iris"),
        platform="shared",
        platform_config=PlatformConfig(
            initial_scale=2, container_concurrency=2, ps_slowdown=0.25,
            failure_prob_per_batch=0.05, straggler_prob=0.05,
            straggler_mult=6.0, hedge_factor=3.0, max_hedges=1,
        ),
    )
    return dict(
        endpoints={
            "a": EndpointSpec(
                policy="mlproxy",
                arrivals=PoissonProcess(rate=25.0, duration=60.0), **spec),
            "b": EndpointSpec(
                policy="clipper",
                arrivals=MMPP2(rate_lo=5.0, rate_hi=40.0, mean_lo=10.0,
                               mean_hi=5.0, duration=60.0), **spec),
        },
        duration=60.0, drain_grace=120.0, seed=seed,
    )


def test_multi_endpoint_deterministic_given_seed():
    a = run_multi_simulation(**_multi_kwargs())
    b = run_multi_simulation(**_multi_kwargs())
    assert a.summary == b.summary
    assert a.endpoints == b.endpoints
    for name in a.e2e_latencies:
        np.testing.assert_array_equal(a.e2e_latencies[name],
                                      b.e2e_latencies[name])


def test_reused_stateful_arrival_process_is_reset_between_runs():
    # the pump must reset() the (stateful) MMPP2 chain, so reusing one
    # process object across two simulators yields identical summaries
    sla = SLAConfig(slo_target=0.5)
    proc = MMPP2(rate_lo=10.0, rate_hi=80.0, mean_lo=8.0, mean_hi=4.0,
                 duration=60.0)

    def one():
        return run_simulation(
            policy="mlproxy", sla=sla, workload=get_workload("sklearn-iris"),
            arrivals=proc,
            platform_config=PlatformConfig(initial_scale=1),
            duration=60.0, seed=2,
        ).summary

    assert one() == one()


def test_fault_stream_split_isolates_service_draws():
    # identical seeds with faults on/off must see the SAME arrival stream:
    # the completed counts can differ (retries change timing) but the
    # submitted *request* count — a pure function of arrivals + policy —
    # must stay equal batch-for-batch when batching is fixed-size.
    sla = SLAConfig(slo_target=2.0)
    kw = dict(
        policy="static", sla=sla,
        workload=AffineLatency(a=0.05, c=0.005, noise_cv=0.1),
        policy_kwargs={"batch_size": 4, "timeout": 0.1},
        duration=40.0, drain_grace=120.0, seed=17,
    )
    on = run_simulation(
        arrivals=PoissonProcess(rate=30.0, duration=40.0),
        platform_config=PlatformConfig(
            initial_scale=2, failure_prob_per_batch=0.1), **kw).summary
    off = run_simulation(
        arrivals=PoissonProcess(rate=30.0, duration=40.0),
        platform_config=PlatformConfig(initial_scale=2), **kw).summary
    assert on["completed"] == off["completed"]  # same arrivals either way
    assert on["failed_attempts"] > 0
    assert off["failed_attempts"] == 0
