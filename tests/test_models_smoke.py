"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, output shapes + no NaNs; plus prefill/decode
consistency where the family supports exact streaming."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, get_config
from repro.configs.base import ShapeCell
from repro.models.model import Model, input_specs, make_inputs

SMOKE_TRAIN = ShapeCell("smoke_train", seq_len=24, global_batch=2, kind="train")
SMOKE_PREFILL = ShapeCell("smoke_prefill", seq_len=16, global_batch=2, kind="prefill")
SMOKE_DECODE = ShapeCell("smoke_decode", seq_len=16, global_batch=2, kind="decode")


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _params(cfg, rng):
    return Model(cfg).init(rng)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = _params(cfg, rng)
    inputs = make_inputs(cfg, SMOKE_TRAIN, rng)
    loss, grads = jax.value_and_grad(lambda p: model.loss(p, inputs["batch"]))(params)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    leaves = jax.tree.leaves(grads)
    assert leaves, arch
    for g in leaves:
        assert jnp.all(jnp.isfinite(g)), f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = _params(cfg, rng)
    inputs = make_inputs(cfg, SMOKE_TRAIN, rng)["batch"]
    fwd_in = inputs if cfg.family == "encdec" else inputs.get(
        "inputs", inputs.get("tokens"))
    logits = model.forward(params, fwd_in)
    b, s = SMOKE_TRAIN.global_batch, SMOKE_TRAIN.seq_len
    assert logits.shape == (b, s, cfg.vocab_size), f"{arch}: {logits.shape}"
    assert logits.dtype == jnp.float32
    assert jnp.all(jnp.isfinite(logits)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch, rng):
    """prefill(S-1) + decode(1) must equal full forward at the last position.

    Exact for every family: transformer KV caches, SSM/hybrid states and
    enc-dec caches are all designed for exact streaming.
    """
    cfg = get_config(arch).reduced()
    if cfg.embed_inputs and cfg.family != "encdec":
        cfg = dataclasses.replace(cfg, embed_inputs=False)  # decode uses tokens
    model = Model(cfg)
    params = _params(cfg, rng)
    b, s = 2, 12
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size, dtype=jnp.int32)
    if cfg.family == "encdec":
        frames = jax.random.normal(rng, (b, 8, cfg.d_model)).astype(cfg.cdtype)
        full = model.forward(params, {"frames": frames, "tokens": tokens})
        cache = model.init_cache(b, 32)
        _, cache = model.prefill(params, {"frames": frames, "tokens": tokens[:, :-1]},
                                 cache)
        logits, cache = model.decode_step(params, tokens[:, -1:], cache)
    else:
        full = model.forward(params, tokens)
        cache = model.init_cache(b, 32)
        _, cache = model.prefill(params, tokens[:, :-1], cache)
        logits, cache = model.decode_step(params, tokens[:, -1:], cache)
    err = jnp.max(jnp.abs(full[:, -1:] - logits))
    assert err < 5e-3, f"{arch}: decode/forward mismatch {err}"
    assert int(cache["len"]) == s


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for name, shape in SHAPES_BY_NAME.items():
        if not cfg.supports_shape(shape):
            assert cfg.skip_reason(shape) == "full-attention@500k"
            continue
        specs = input_specs(cfg, shape)
        leaves = jax.tree.leaves(specs)
        assert all(hasattr(l, "shape") for l in leaves), (arch, name)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_sane(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "llama4-scout-17b-a16e": (80e9, 130e9),   # 16 experts × 8192 ffn
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        "starcoder2-15b": (12e9, 18e9),
        "qwen2-0.5b": (0.3e9, 0.7e9),
        "nemotron-4-340b": (300e9, 380e9),
        "yi-34b": (30e9, 40e9),
        "zamba2-1.2b": (0.9e9, 1.7e9),
        "xlstm-1.3b": (1.0e9, 2.1e9),
        "seamless-m4t-large-v2": (1.2e9, 2.8e9),
        "internvl2-76b": (65e9, 85e9),
    }[arch]
    assert expected[0] < n < expected[1], f"{arch}: {n/1e9:.1f}B params"
    assert cfg.active_param_count() <= n


def test_moe_active_params_much_smaller():
    cfg = get_config("kimi-k2-1t-a32b")
    assert cfg.active_param_count() < 0.06 * cfg.param_count()


def test_reduced_configs_are_small():
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        assert cfg.param_count() < 20e6, arch
        assert cfg.family == get_config(arch).family
