"""ReplicaPool bookkeeping tests (engine stubbed — no JAX compilation).

Regression coverage for the scale-down/re-grow bug: scaling down used to
mark tail replicas unhealthy without removing them, so a later scale-up
appended fresh replicas while the dead ones kept consuming round-robin
slots and ``n_healthy`` drifted from the pool size.
"""
import numpy as np
import pytest

import repro.serving.engine as engine_mod
from repro.serving.engine import ReplicaPool


class _StubEngine:
    """Stands in for InferenceEngine: records calls, no JAX."""

    def __init__(self, cfg, engine_cfg, params=None, rng=None):
        self.cfg = cfg
        self.engine_cfg = engine_cfg
        self.params = params if params is not None else object()
        self.calls = 0
        self.fail = False

    def generate(self, prompts, gen_len=None):
        if self.fail:
            raise RuntimeError("injected replica failure")
        self.calls += 1
        return prompts[:, :1], {"latency_s": 0.001, "bucket": len(prompts)}


@pytest.fixture
def pool(monkeypatch):
    monkeypatch.setattr(engine_mod, "InferenceEngine", _StubEngine)
    # jax.random.PRNGKey(0) default arg is evaluated at call time inside
    # __init__ only when rng is None; pass a dummy to stay JAX-free.
    return ReplicaPool(cfg=None, engine_cfg=None, n_replicas=4, rng=np.zeros(2))


def test_scale_down_removes_replicas(pool):
    pool.scale_to(2)
    assert len(pool.replicas) == 2
    assert len(pool.healthy) == 2
    assert pool.n_healthy == 2


def test_scale_down_then_up_regression(pool):
    """The seed bug: shrink left dead replicas in round-robin rotation."""
    pool.scale_to(2)
    pool.scale_to(4)
    assert len(pool.replicas) == 4
    assert pool.n_healthy == 4  # used to drift: dead slots never revived
    # every replica actually serves traffic again
    for _ in range(8):
        _, timing = pool.generate(np.zeros((1, 4), np.int32))
        assert 0 <= timing["replica"] < 4
    assert all(r.calls >= 1 for r in pool.replicas)


def test_scale_down_resets_round_robin_cursor(pool):
    pool._rr = 3
    pool.scale_to(1)
    _, timing = pool.generate(np.zeros((1, 4), np.int32))
    assert timing["replica"] == 0


def test_scale_to_zero_then_up(pool):
    pool.scale_to(0)
    assert pool.replicas == [] and pool.n_healthy == 0
    with pytest.raises(RuntimeError, match="no healthy replicas"):
        pool.generate(np.zeros((1, 4), np.int32))
    pool.scale_to(3)
    assert pool.n_healthy == 3


def test_scale_to_negative_rejected(pool):
    with pytest.raises(ValueError):
        pool.scale_to(-1)


def test_pool_target_keeps_trailing_prompt_context(pool):
    """Over-long payloads must keep the LAST prompt_len tokens: with
    left-padding the engine continues from the trailing context."""
    from repro.core.request import Batch, Request as Req
    from repro.serving.batcher import ReplicaPoolTarget

    target = ReplicaPoolTarget(pool, prompt_len=4)
    long_payload = np.arange(10, dtype=np.int32)  # tokens 0..9
    batch = Batch(requests=[Req(arrival_time=0.0, payload=long_payload)],
                  dispatch_time=0.0, cause="full")
    prompts = target._prompts(batch)
    assert prompts.tolist() == [[6, 7, 8, 9]]  # tail, not head
    short = Batch(requests=[Req(arrival_time=0.0,
                                payload=np.array([5, 6], np.int32))],
                  dispatch_time=0.0, cause="full")
    assert target._prompts(short).tolist() == [[0, 0, 5, 6]]  # left-padded


def test_failover_skips_failed_replica(pool):
    pool.replicas[1].fail = True
    seen = set()
    for _ in range(8):
        _, timing = pool.generate(np.zeros((1, 4), np.int32))
        seen.add(timing["replica"])
    assert 1 not in seen
    assert pool.n_healthy == 3
    assert pool.retries >= 1
