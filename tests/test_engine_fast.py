"""Fast data-plane tests: fused decode, KV-cache pool, warmup, seeding.

All on a 1-layer tiny model so compiles are cheap; the fused loop's
contract — bit-identical tokens to the per-token reference loop — is the
load-bearing invariant here, everything else builds on it.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.configs.base import ModelConfig
from repro.serving.batcher import EngineBackedLatency
from repro.serving.engine import EngineConfig, InferenceEngine

TINY = ModelConfig(
    name="tiny-fast", family="dense", num_layers=1, d_model=16,
    num_heads=1, num_kv_heads=1, head_dim=16, d_ff=32, vocab_size=64,
    max_seq_len=64, param_dtype="float32", compute_dtype="float32",
    remat=False, scan_layers=False)

BUCKETS = (1, 2, 4)
PLENS = (4, 8)


def _ecfg(**kw):
    base = dict(batch_buckets=BUCKETS, prompt_buckets=PLENS,
                max_len=24, gen_len=8)
    base.update(kw)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def shared_params():
    return InferenceEngine(TINY, _ecfg(), rng=jax.random.PRNGKey(0)).params


@pytest.fixture(scope="module")
def reference_engine(shared_params):
    """Per-token loop, no pool: the ground truth the fast path must match."""
    return InferenceEngine(TINY, _ecfg(fused_decode=False, cache_pool=False),
                           params=shared_params)


# ------------------------------------------------------------- fused decode
@pytest.mark.parametrize("n", [1, 2, 3, 4])
def test_fused_bit_identical_per_bucket(shared_params, reference_engine, n):
    fused = InferenceEngine(TINY, _ecfg(), params=shared_params)
    prompts = np.random.default_rng(n).integers(
        0, TINY.vocab_size, (n, 5)).astype(np.int32)
    a, ta = fused.generate(prompts, gen_len=8)
    b, tb = reference_engine.generate(prompts, gen_len=8)
    assert a.shape == b.shape == (n, 8)
    assert np.array_equal(a, b)
    assert ta["bucket"] == tb["bucket"]


def test_fused_single_token_and_repeat_calls(shared_params, reference_engine):
    fused = InferenceEngine(TINY, _ecfg(), params=shared_params)
    prompts = np.ones((2, 4), np.int32)
    out, _ = fused.generate(prompts, gen_len=1)  # gen_len=1: prefill only
    ref, _ = reference_engine.generate(prompts, gen_len=1)
    assert np.array_equal(out, ref)
    # repeat calls through the pooled cache keep matching the reference
    for seed in range(3):
        p = np.random.default_rng(seed).integers(
            0, TINY.vocab_size, (2, 4)).astype(np.int32)
        a, _ = fused.generate(p, gen_len=6)
        b, _ = reference_engine.generate(p, gen_len=6)
        assert np.array_equal(a, b)


def test_gen_bucket_rounding_is_prefix_stable(shared_params, reference_engine):
    """gen_buckets rounds the compiled step count up; the sliced output
    must equal the exact-length reference (greedy decoding is
    prefix-stable), and intermediate lengths must not add compiles."""
    eng = InferenceEngine(TINY, _ecfg(gen_buckets=(4, 8)),
                          params=shared_params)
    prompts = np.random.default_rng(7).integers(
        0, TINY.vocab_size, (2, 4)).astype(np.int32)
    out5, _ = eng.generate(prompts, gen_len=5)  # compiles (2, 8)
    before = eng.compile_count
    for gl in (6, 7, 8):
        out, _ = eng.generate(prompts, gen_len=gl)
        ref, _ = reference_engine.generate(prompts, gen_len=gl)
        assert np.array_equal(out, ref)
    assert eng.compile_count == before  # all lengths share the 8-step scan
    ref5, _ = reference_engine.generate(prompts, gen_len=5)
    assert out5.shape == (2, 5)
    assert np.array_equal(out5, ref5)


# ------------------------------------------------------------ kv-cache pool
def test_cache_pool_allocs_saturate_per_bucket(shared_params):
    eng = InferenceEngine(TINY, _ecfg(), params=shared_params)
    for _ in range(4):
        eng.generate(np.ones((4, 4), np.int32), gen_len=4)
    assert eng.cache_allocs == 1  # one alloc for bucket 4, then reuse
    eng.generate(np.ones((2, 4), np.int32), gen_len=4)
    eng.generate(np.ones((1, 4), np.int32), gen_len=4)
    assert eng.cache_allocs == 3  # one per touched bucket
    for _ in range(5):
        eng.generate(np.ones((3, 4), np.int32), gen_len=4)  # bucket 4 again
    assert eng.cache_allocs == 3


def test_cache_pool_disabled_allocates_per_call(shared_params):
    eng = InferenceEngine(TINY, _ecfg(cache_pool=False), params=shared_params)
    for _ in range(3):
        eng.generate(np.ones((4, 4), np.int32), gen_len=4)
    assert eng.cache_allocs == 3


def test_no_stale_row_leakage_across_batches(shared_params):
    """A reused cache still holds the previous batch's KV rows; prefill +
    the attention length mask must make them unreachable. A padded batch
    (n=3 in bucket 4) after a full batch is the sharpest case: row 3's
    stale history must not change row 0–2's tokens."""
    pooled = InferenceEngine(TINY, _ecfg(), params=shared_params)
    fresh = InferenceEngine(TINY, _ecfg(cache_pool=False),
                            params=shared_params)
    rng = np.random.default_rng(3)
    # poison the bucket-4 cache with a distinctive full batch
    poison = rng.integers(32, 64, (4, 8)).astype(np.int32)
    pooled.generate(poison, gen_len=8)
    # then a shorter, partially-filled batch through the SAME pooled cache
    probe = rng.integers(0, 32, (3, 4)).astype(np.int32)
    got, _ = pooled.generate(probe, gen_len=8)
    want, _ = fresh.generate(probe, gen_len=8)
    assert np.array_equal(got, want)


# ------------------------------------------------------------------- warmup
def test_warmup_covers_all_pairs_without_stats_pollution(shared_params):
    eng = InferenceEngine(TINY, _ecfg(), params=shared_params)
    timings = eng.warmup()
    assert set(timings) == {(b, p) for b in BUCKETS for p in PLENS}
    assert all(dt > 0 for dt in timings.values())
    # warmup traffic is synthetic: serving stats must stay untouched
    assert eng.stats == {"batches": 0, "requests": 0, "tokens": 0}
    # every serving-path shape is now compiled: no compile on first real call
    before = eng.compile_count
    for b in BUCKETS:
        for p in PLENS:
            eng.generate(np.ones((b, p), np.int32))
    assert eng.compile_count == before
    assert eng.stats["batches"] == len(BUCKETS) * len(PLENS)


def test_warmup_single_prompt_bucket(shared_params):
    eng = InferenceEngine(TINY, _ecfg(), params=shared_params)
    timings = eng.warmup(plen=3)  # rounds up to prompt bucket 4
    assert set(timings) == {(b, 4) for b in BUCKETS}


# -------------------------------------------------------- latency seeding
def test_engine_backed_latency_seeds_from_warmup(shared_params):
    eng = InferenceEngine(TINY, _ecfg(), params=shared_params)
    lat = EngineBackedLatency(eng, prompt_len=4, warmup=True)
    # seeded: no cold-0.0 window for any compiled bucket, and the
    # oversized probe scales off the largest seeded bucket instead of
    # promising a free batch
    for b in BUCKETS:
        assert lat.mean(b) > 0.0
    assert lat.mean(8) >= lat.mean(4)


def test_engine_backed_latency_seed_prefers_nearest_prompt_bucket():
    class _StubEngine:
        class ecfg:
            batch_buckets = (1, 2)
        cfg = None

    lat = EngineBackedLatency.__new__(EngineBackedLatency)
    lat.engine = _StubEngine()
    lat.prompt_len = 8
    lat._ema = {}
    lat.seed({(1, 4): 0.5, (1, 8): 0.1, (2, 8): 0.2})
    assert lat._ema == {1: 0.1, 2: 0.2}
