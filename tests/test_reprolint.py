"""reprolint: per-rule inline fixtures + whole-tree self-check (ISSUE 7).

Each rule gets a positive hit, a suppressed hit, and (where the rule has
one) a whitelisted-path case; the baseline round-trips through
save/load/apply; and the current tree must lint clean modulo the
checked-in baseline so a regression fails tier-1 locally, not just the
CI lint job.
"""
import json
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
if str(ROOT) not in sys.path:  # `pytest` invoked without repo root on path
    sys.path.insert(0, str(ROOT))

from tools.reprolint import engine as rl  # noqa: E402
from tools.reprolint.__main__ import main as rl_main  # noqa: E402
from tools.reprolint.engine import (  # noqa: E402
    LintConfig,
    apply_baseline,
    lint_paths,
    lint_sources,
    load_baseline,
    save_baseline,
)

SRC_PATH = "src/repro/somewhere/mod.py"


def lint(source, path=SRC_PATH, only=None, extra=None):
    sources = {path: source}
    if extra:
        sources.update(extra)
    return lint_sources(sources, only=only).findings


def rules_of(findings):
    return [f.rule for f in findings]


# ------------------------------------------------------------ determinism
class TestWallclock:
    def test_reference_flagged_even_as_default_arg(self):
        src = ("import time\n"
               "def f(clock=time.monotonic):\n"
               "    return clock()\n")
        (f,) = lint(src, only=["wallclock"])
        assert f.rule == "wallclock" and f.line == 2
        assert "time.monotonic" in f.message

    def test_from_import_and_aliased_use_flagged(self):
        src = ("from time import perf_counter as pc\n"
               "t0 = pc()\n")
        found = lint(src, only=["wallclock"])
        assert rules_of(found) == ["wallclock", "wallclock"]

    def test_datetime_now_flagged(self):
        src = ("import datetime\n"
               "stamp = datetime.datetime.now()\n")
        assert rules_of(lint(src, only=["wallclock"])) == ["wallclock"]

    def test_suppressed(self):
        src = ("import time\n"
               "t0 = time.monotonic()  # reprolint: disable=wallclock\n")
        assert lint(src, only=["wallclock"]) == []

    def test_whitelisted_seam_and_benchmarks(self):
        src = "import time\nt0 = time.perf_counter()\n"
        assert lint(src, path="src/repro/runtime/clock.py",
                    only=["wallclock"]) == []
        assert lint(src, path="benchmarks/common.py",
                    only=["wallclock"]) == []

    def test_injected_clock_call_not_flagged(self):
        src = ("class T:\n"
               "    def f(self):\n"
               "        return self.clock()\n")
        assert lint(src, only=["wallclock"]) == []


class TestSleepLiteral:
    def test_literal_sleep_flagged(self):
        src = ("import asyncio\n"
               "async def f():\n"
               "    await asyncio.sleep(0.5)\n")
        assert rules_of(lint(src, only=["sleep-literal"])) == ["sleep-literal"]

    def test_zero_yield_and_variable_ok(self):
        src = ("import asyncio\n"
               "async def f(d):\n"
               "    await asyncio.sleep(0)\n"
               "    await asyncio.sleep(d)\n")
        assert lint(src, only=["sleep-literal"]) == []

    def test_clock_seam_whitelisted(self):
        src = "import asyncio\nasync def f():\n    await asyncio.sleep(0.1)\n"
        assert lint(src, path="src/repro/runtime/clock.py",
                    only=["sleep-literal"]) == []

    def test_suppressed(self):
        src = ("import asyncio\n"
               "async def f():\n"
               "    await asyncio.sleep(1)"
               "  # reprolint: disable=sleep-literal\n")
        assert lint(src, only=["sleep-literal"]) == []


class TestUnseededRng:
    def test_stdlib_random_flagged(self):
        src = "import random\nx = random.random()\n"
        found = lint(src, only=["unseeded-rng"])
        assert found and all(f.rule == "unseeded-rng" for f in found)

    def test_unseeded_default_rng_flagged_seeded_ok(self):
        src = ("import numpy as np\n"
               "bad = np.random.default_rng()\n"
               "good = np.random.default_rng(1234)\n")
        (f,) = lint(src, only=["unseeded-rng"])
        assert f.line == 2

    def test_legacy_numpy_global_state_flagged(self):
        src = "import numpy as np\nnp.random.seed(0)\n"
        assert rules_of(lint(src, only=["unseeded-rng"])) == ["unseeded-rng"]

    def test_jax_random_and_generator_annotations_ok(self):
        src = ("import jax\nimport numpy as np\n"
               "def f(key, rng: np.random.Generator):\n"
               "    return jax.random.split(key), rng.random()\n")
        assert lint(src, only=["unseeded-rng"]) == []

    def test_out_of_scope_not_flagged(self):
        src = "import random\nx = random.random()\n"
        assert lint(src, path="benchmarks/noise.py",
                    only=["unseeded-rng"]) == []


# ----------------------------------------------------------- async-safety
class TestDroppedTask:
    def test_bare_create_task_flagged(self):
        src = ("import asyncio\n"
               "async def f(coro):\n"
               "    asyncio.create_task(coro)\n")
        assert rules_of(lint(src, only=["dropped-task"])) == ["dropped-task"]

    def test_loop_create_task_flagged(self):
        src = ("import asyncio\n"
               "async def f(coro):\n"
               "    asyncio.get_running_loop().create_task(coro)\n")
        assert rules_of(lint(src, only=["dropped-task"])) == ["dropped-task"]

    def test_kept_reference_ok(self):
        src = ("import asyncio\n"
               "async def f(self, coro):\n"
               "    t = asyncio.create_task(coro)\n"
               "    self._tasks.add(asyncio.create_task(coro))\n"
               "    return t\n")
        assert lint(src, only=["dropped-task"]) == []

    def test_suppressed(self):
        src = ("import asyncio\n"
               "async def f(coro):\n"
               "    asyncio.create_task(coro)"
               "  # reprolint: disable=dropped-task\n")
        assert lint(src, only=["dropped-task"]) == []


class TestBlockingInAsync:
    def test_time_sleep_in_async_flagged(self):
        src = ("import time\n"
               "async def f():\n"
               "    time.sleep(1.0)\n")
        found = lint(src, only=["blocking-in-async"])
        assert rules_of(found) == ["blocking-in-async"]

    def test_open_in_async_flagged(self):
        src = ("async def f(p):\n"
               "    with open(p) as fh:\n"
               "        return fh.read()\n")
        assert rules_of(lint(src, only=["blocking-in-async"])) == [
            "blocking-in-async"]

    def test_sync_def_ok_even_nested_in_async(self):
        src = ("import time\n"
               "def g():\n"
               "    time.sleep(1.0)\n"
               "async def f():\n"
               "    def inner():\n"
               "        time.sleep(0.5)\n"
               "    return inner\n")
        assert lint(src, only=["blocking-in-async"]) == []

    def test_suppressed(self):
        src = ("import time\n"
               "async def f():\n"
               "    time.sleep(1)  # reprolint: disable=blocking-in-async\n")
        assert lint(src, only=["blocking-in-async"]) == []


class TestAwaitInLock:
    def test_await_under_sync_lock_flagged(self):
        src = ("async def f(self):\n"
               "    with self._lock:\n"
               "        await self.g()\n")
        (f,) = lint(src, only=["await-in-lock"])
        assert f.rule == "await-in-lock" and f.line == 2

    def test_async_with_ok(self):
        src = ("async def f(self):\n"
               "    async with self._lock:\n"
               "        await self.g()\n")
        assert lint(src, only=["await-in-lock"]) == []

    def test_non_lock_context_ok(self):
        src = ("async def f(self, p):\n"
               "    with self.tracer.span(p):\n"
               "        await self.g()\n")
        assert lint(src, only=["await-in-lock"]) == []

    def test_await_in_nested_def_not_attributed_to_lock(self):
        src = ("async def f(self):\n"
               "    with self._lock:\n"
               "        async def inner():\n"
               "            await self.g()\n"
               "        self.k = inner\n")
        assert lint(src, only=["await-in-lock"]) == []

    def test_inline_threading_lock_flagged(self):
        src = ("import threading\n"
               "async def f(self, mu):\n"
               "    with threading.Lock():\n"
               "        await self.g()\n")
        assert rules_of(lint(src, only=["await-in-lock"])) == [
            "await-in-lock"]


# ------------------------------------------------ protocol & ledger rules
PROTO_SRC = (
    "from typing import Protocol\n"
    "class Policy(Protocol):\n"
    "    def on_request(self, req, now): ...\n"
    "    def on_timer(self, now): ...\n"
    "    def stats(self): ...\n")
REGISTRY_SRC = (
    "from mod import Complete, Missing, Derived\n"
    "def make_policy(name):\n"
    "    if name == 'complete':\n"
    "        return Complete()\n"
    "    if name == 'missing':\n"
    "        return Missing()\n"
    "    return Derived()\n")


class TestPolicyProtocol:
    def fixture(self, classes_src):
        return {
            "src/repro/core/batch_queue.py": PROTO_SRC,
            "src/repro/core/policies.py": REGISTRY_SRC,
            "src/repro/core/mod.py": classes_src,
        }

    def test_missing_member_flagged(self):
        classes = (
            "class Complete:\n"
            "    def on_request(self, req, now): ...\n"
            "    def on_timer(self, now): ...\n"
            "    def stats(self): ...\n"
            "class Missing:\n"
            "    def on_request(self, req, now): ...\n"
            "    def stats(self): ...\n"
            "class Derived(Complete):\n"
            "    def stats(self): ...\n")
        found = lint_sources(self.fixture(classes),
                             only=["policy-protocol"]).findings
        (f,) = found
        assert "Missing" in f.message and "on_timer" in f.message
        assert "Complete" not in f.message

    def test_inherited_members_count(self):
        classes = (
            "class Base:\n"
            "    def on_request(self, req, now): ...\n"
            "    def on_timer(self, now): ...\n"
            "class Complete(Base):\n"
            "    def stats(self): ...\n"
            "class Missing(Base):\n"
            "    def on_request(self, req, now): ...\n"
            "    def on_timer(self, now): ...\n"
            "    def stats(self): ...\n"
            "class Derived(Complete):\n"
            "    pass\n")
        assert lint_sources(self.fixture(classes),
                            only=["policy-protocol"]).findings == []

    def test_unresolvable_base_skipped(self):
        classes = (
            "from elsewhere import Mystery\n"
            "class Complete(Mystery):\n"
            "    pass\n"
            "class Missing(Mystery):\n"
            "    pass\n"
            "class Derived(Mystery):\n"
            "    pass\n")
        assert lint_sources(self.fixture(classes),
                            only=["policy-protocol"]).findings == []

    def test_real_tree_policies_conform(self):
        # the actual registry must satisfy the actual protocol
        result = lint_paths([str(ROOT / "src")], only=["policy-protocol"],
                            root=ROOT)
        assert result.findings == []


LEDGER_PATH = "src/repro/runtime/server.py"


class TestLedgerCounter:
    def test_unsurfaced_counter_flagged(self):
        src = ("class Server:\n"
               "    def work(self):\n"
               "        self.completed += 1\n"
               "        self.orphaned += 1\n"
               "        self.elapsed += self.dt\n"
               "    def summary(self):\n"
               "        return {'completed': self.completed}\n")
        (f,) = lint(src, path=LEDGER_PATH, only=["ledger-counter"])
        assert "orphaned" in f.message and "elapsed" not in f.message

    def test_gauge_with_decrement_exempt(self):
        src = ("class Server:\n"
               "    def work(self):\n"
               "        self.inflight += 1\n"
               "        self.inflight -= 1\n"
               "    def stats(self):\n"
               "        return {}\n")
        assert lint(src, path=LEDGER_PATH, only=["ledger-counter"]) == []

    def test_class_without_reporting_method_skipped(self):
        src = ("class Config:\n"
               "    def bump(self):\n"
               "        self.n += 1\n")
        assert lint(src, path=LEDGER_PATH, only=["ledger-counter"]) == []

    def test_non_ledger_module_not_checked(self):
        src = ("class T:\n"
               "    def work(self):\n"
               "        self.hidden += 1\n"
               "    def summary(self):\n"
               "        return {}\n")
        assert lint(src, path="src/repro/core/monitor.py",
                    only=["ledger-counter"]) == []

    def test_conservation_counts_as_surfacing(self):
        src = ("class Platform:\n"
               "    def work(self):\n"
               "        self.cold_starts += 1\n"
               "    def conservation(self):\n"
               "        return {'cold_starts': self.cold_starts}\n")
        assert lint(src, path="src/repro/serverless/platform.py",
                    only=["ledger-counter"]) == []


METRICS_PATH = "src/repro/runtime/breaker.py"


class TestUnregisteredCounter:
    def test_unbound_counter_flagged(self):
        src = ("class Breaker:\n"
               "    def trip(self):\n"
               "        self.opens += 1\n"
               "        self.probes += 1\n"
               "    def register_metrics(self, registry):\n"
               "        registry.bind('opens', lambda: self.opens)\n")
        (f,) = lint(src, path=METRICS_PATH, only=["unregistered-counter"])
        assert "probes" in f.message
        assert "never bound in register_metrics" in f.message

    def test_gauge_with_decrement_exempt(self):
        src = ("class Breaker:\n"
               "    def work(self):\n"
               "        self.inflight += 1\n"
               "        self.inflight -= 1\n"
               "    def register_metrics(self, registry):\n"
               "        pass\n")
        assert lint(src, path=METRICS_PATH,
                    only=["unregistered-counter"]) == []

    def test_private_attr_exempt(self):
        src = ("class Breaker:\n"
               "    def work(self):\n"
               "        self._seq += 1\n"
               "    def register_metrics(self, registry):\n"
               "        pass\n")
        assert lint(src, path=METRICS_PATH,
                    only=["unregistered-counter"]) == []

    def test_class_without_binding_method_flagged(self):
        src = ("class Breaker:\n"
               "    def trip(self):\n"
               "        self.opens += 1\n")
        (f,) = lint(src, path=METRICS_PATH, only=["unregistered-counter"])
        assert f.line == 1
        assert "defines no register_metrics" in f.message

    def test_counter_read_in_bind_lambda_passes(self):
        src = ("class Breaker:\n"
               "    def trip(self):\n"
               "        self.opens += 1\n"
               "    def register_metrics(self, registry):\n"
               "        registry.bind('opens', lambda: self.opens)\n")
        assert lint(src, path=METRICS_PATH,
                    only=["unregistered-counter"]) == []

    def test_non_metrics_module_not_checked(self):
        src = ("class T:\n"
               "    def work(self):\n"
               "        self.hidden += 1\n")
        assert lint(src, path=SRC_PATH,
                    only=["unregistered-counter"]) == []

    def test_suppression(self):
        src = ("class Breaker:\n"
               "    def trip(self):\n"
               "        self.opens += 1  "
               "# reprolint: disable=unregistered-counter\n"
               "    def register_metrics(self, registry):\n"
               "        pass\n")
        assert lint(src, path=METRICS_PATH,
                    only=["unregistered-counter"]) == []


class TestSlotsDataclass:
    def test_missing_slots_flagged(self):
        src = ("import dataclasses\n"
               "@dataclasses.dataclass\n"
               "class Event:\n"
               "    t: float\n")
        (f,) = lint(src, path="src/repro/simulation/events2.py",
                    only=["slots-dataclass"])
        assert "Event" in f.message

    def test_call_decorator_without_slots_flagged(self):
        src = ("from dataclasses import dataclass\n"
               "@dataclass(frozen=True)\n"
               "class Event:\n"
               "    t: float\n")
        assert rules_of(lint(src, path="src/repro/simulation/events2.py",
                             only=["slots-dataclass"])) == ["slots-dataclass"]

    def test_slots_true_ok(self):
        src = ("import dataclasses\n"
               "@dataclasses.dataclass(slots=True)\n"
               "class Event:\n"
               "    t: float\n")
        assert lint(src, path="src/repro/simulation/events2.py",
                    only=["slots-dataclass"]) == []

    def test_outside_simulation_not_checked(self):
        src = ("import dataclasses\n"
               "@dataclasses.dataclass\n"
               "class Endpoint:\n"
               "    name: str\n")
        assert lint(src, path="src/repro/core/frontend.py",
                    only=["slots-dataclass"]) == []


class TestUnboundedRetry:
    def test_while_true_retry_flagged(self):
        src = ("async def pump(target, batch):\n"
               "    while True:\n"
               "        try:\n"
               "            return await target(batch)\n"
               "        except RuntimeError:\n"
               "            continue\n")
        (f,) = lint(src, only=["unbounded-retry"])
        assert "target" in f.message and "while" in f.message

    def test_itertools_count_retry_flagged(self):
        src = ("import itertools\n"
               "async def pump(dispatch, batch):\n"
               "    for _ in itertools.count():\n"
               "        try:\n"
               "            return await dispatch(batch)\n"
               "        except RuntimeError:\n"
               "            continue\n")
        assert rules_of(lint(src, only=["unbounded-retry"])) == [
            "unbounded-retry"]

    def test_deadline_bound_ok(self):
        src = ("async def pump(clock, target, batch, deadline):\n"
               "    while True:\n"
               "        try:\n"
               "            return await target(batch)\n"
               "        except RuntimeError:\n"
               "            if clock.now() >= deadline:\n"
               "                raise\n")
        assert lint(src, only=["unbounded-retry"]) == []

    def test_attempt_cap_ok(self):
        src = ("async def pump(cfg, target, batch):\n"
               "    failures = 0\n"
               "    while True:\n"
               "        try:\n"
               "            return await target(batch)\n"
               "        except RuntimeError:\n"
               "            failures += 1\n"
               "            if failures > cfg.max_retries:\n"
               "                raise\n")
        assert lint(src, only=["unbounded-retry"]) == []

    def test_bounded_while_condition_not_flagged(self):
        src = ("async def pump(queue, target):\n"
               "    while queue:\n"
               "        await target(queue.pop())\n")
        assert lint(src, only=["unbounded-retry"]) == []

    def test_non_dispatch_loop_not_flagged(self):
        src = ("async def serve(handler):\n"
               "    while True:\n"
               "        await handler.step()\n")
        assert lint(src, only=["unbounded-retry"]) == []

    def test_bound_in_nested_def_does_not_count(self):
        src = ("async def pump(target, batch):\n"
               "    while True:\n"
               "        def helper(deadline):\n"
               "            return deadline\n"
               "        await target(batch)\n")
        assert rules_of(lint(src, only=["unbounded-retry"])) == [
            "unbounded-retry"]

    def test_suppressed(self):
        src = ("async def pump(target, batch):\n"
               "    while True:  # reprolint: disable=unbounded-retry\n"
               "        await target(batch)\n")
        assert lint(src, only=["unbounded-retry"]) == []


# ------------------------------------------------- engine-level behaviour
class TestEngineMechanics:
    def test_parse_error_reported_not_raised(self):
        (f,) = lint("def broken(:\n")
        assert f.rule == "parse-error"

    def test_disable_all_suppresses_any_rule(self):
        src = ("import time\n"
               "t = time.monotonic()  # reprolint: disable=all\n")
        assert lint(src, only=["wallclock"]) == []

    def test_suppression_counted(self):
        src = ("import time\n"
               "t = time.monotonic()  # reprolint: disable=wallclock\n")
        result = lint_sources({SRC_PATH: src}, only=["wallclock"])
        assert result.suppressed == 1 and result.findings == []

    def test_baseline_round_trip(self, tmp_path):
        src = "import time\nt = time.monotonic()\n"
        findings = lint(src, only=["wallclock"])
        path = tmp_path / "baseline.json"
        save_baseline(path, [{
            "rule": f.rule, "path": f.path, "message": f.message,
            "justification": "grandfathered for the test"}
            for f in findings])
        entries = load_baseline(path)
        fresh, baselined, stale = apply_baseline(findings, entries)
        assert fresh == [] and len(baselined) == 1 and stale == []
        # a fixed finding leaves its entry stale; a new finding is fresh
        fresh, baselined, stale = apply_baseline([], entries)
        assert fresh == [] and baselined == [] and len(stale) == 1

    def test_baseline_rejects_entry_without_justification(self, tmp_path):
        path = tmp_path / "baseline.json"
        path.write_text(json.dumps({"entries": [
            {"rule": "wallclock", "path": "x.py", "message": "m"}]}))
        with pytest.raises(ValueError, match="justification"):
            load_baseline(path)


class TestCli:
    def test_list_rules(self, capsys):
        assert rl_main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for name in rl.RULES:
            assert name in out

    def test_exit_codes_and_json_report(self, tmp_path, capsys):
        bad = tmp_path / "src" / "repro" / "mod.py"
        bad.parent.mkdir(parents=True)
        bad.write_text("import time\nt = time.monotonic()\n")
        report = tmp_path / "report.json"
        code = rl_main([str(bad), "--format", "json", "--no-baseline",
                        "--output", str(report)])
        capsys.readouterr()
        assert code == 1
        data = json.loads(report.read_text())
        assert data["findings"] and data["files_checked"] == 1
        # clean file exits 0
        good = tmp_path / "clean.py"
        good.write_text("x = 1\n")
        assert rl_main([str(good), "--no-baseline"]) == 0
        capsys.readouterr()

    def test_write_baseline_then_clean(self, tmp_path, capsys):
        bad = tmp_path / "mod.py"
        bad.write_text("import asyncio\n"
                       "async def f(c):\n"
                       "    asyncio.create_task(c)\n")
        baseline = tmp_path / "baseline.json"
        assert rl_main([str(bad), "--baseline", str(baseline),
                        "--write-baseline"]) == 0
        capsys.readouterr()
        entries = load_baseline(baseline)
        assert len(entries) == 1
        assert entries[0]["justification"].startswith("TODO")
        assert rl_main([str(bad), "--baseline", str(baseline)]) == 0
        capsys.readouterr()


def test_tree_is_clean_modulo_baseline():
    """The self-check: linting the real tree reproduces CI's lint job."""
    result = lint_paths(
        [str(ROOT / "src"), str(ROOT / "benchmarks"),
         str(ROOT / "experiments")], root=ROOT)
    entries = load_baseline(ROOT / "tools" / "reprolint" / "baseline.json")
    fresh, _, stale = apply_baseline(result.findings, entries)
    assert fresh == [], "\n".join(f.render() for f in fresh)
    assert stale == [], f"stale baseline entries: {stale}"
    assert result.files_checked > 50  # sanity: the walk saw the tree
