"""Bucket-edge hardening: oversized batches must clamp/chunk, not raise.

ISSUE-4 satellite — a policy whose batch cap exceeds the largest compiled
engine bucket used to blow up mid-dispatch (``next_bucket`` ValueError)
or mid-estimate (``EngineBackedLatency.mean``). These tests pin the
boundary behavior with a stubbed pool/engine (no JAX needed).
"""
import numpy as np
import pytest

from repro.core.request import Batch, Request
from repro.serving.batcher import ReplicaPoolTarget
from repro.serving.engine import next_bucket

BUCKETS = (1, 2, 4, 8, 16, 32)


class _StubEngineCfg:
    batch_buckets = BUCKETS


class _StubPool:
    """Duck-typed ReplicaPool: records call sizes, echoes token arrays."""

    def __init__(self):
        self.engine_cfg = _StubEngineCfg()
        self.calls = []

    def generate(self, prompts, gen_len=None):
        n = prompts.shape[0]
        if n > BUCKETS[-1]:
            raise ValueError(f"batch {n} exceeds largest bucket {BUCKETS[-1]}")
        self.calls.append(n)
        bucket = next_bucket(n, BUCKETS)
        return (np.arange(n, dtype=np.int32)[:, None],
                {"latency_s": 0.01, "bucket": bucket, "replica": 0})


def _batch(n):
    return Batch(requests=[Request(arrival_time=0.0) for _ in range(n)],
                 dispatch_time=0.0, cause="full")


class TestNextBucket:
    @pytest.mark.parametrize("n,expect", [
        (1, 1), (2, 2), (3, 4), (8, 8), (9, 16), (16, 16), (17, 32), (32, 32),
    ])
    def test_boundary_buckets(self, n, expect):
        assert next_bucket(n, BUCKETS) == expect

    def test_oversized_raises_strict(self):
        with pytest.raises(ValueError, match="exceeds largest bucket"):
            next_bucket(33, BUCKETS)

    @pytest.mark.parametrize("n", [33, 64, 1000])
    def test_oversized_clamps(self, n):
        assert next_bucket(n, BUCKETS, clamp=True) == 32

    def test_clamp_is_noop_in_range(self):
        for n in range(1, 33):
            assert next_bucket(n, BUCKETS, clamp=True) == next_bucket(n, BUCKETS)


class TestReplicaPoolTargetChunking:
    def test_exact_largest_bucket_single_call(self):
        pool = _StubPool()
        target = ReplicaPoolTarget(pool, prompt_len=4)
        target(_batch(32))
        assert pool.calls == [32]

    def test_oversized_batch_chunks_instead_of_raising(self):
        pool = _StubPool()
        target = ReplicaPoolTarget(pool, prompt_len=4)
        out, timing = target(_batch(70))
        assert pool.calls == [32, 32, 6]
        assert out.shape[0] == 70
        assert timing["chunks"] == 3
        assert target.requests == 70 and target.batches == 1

    def test_one_past_boundary(self):
        pool = _StubPool()
        target = ReplicaPoolTarget(pool, prompt_len=4)
        target(_batch(33))
        assert pool.calls == [32, 1]

    def test_payloads_assigned_across_chunks(self):
        pool = _StubPool()
        target = ReplicaPoolTarget(pool, prompt_len=4)
        batch = _batch(40)
        target(batch)
        assert all(r.payload is not None for r in batch.requests)

    def test_on_done_fires_once_for_chunked_batch(self):
        pool = _StubPool()
        done = []
        target = ReplicaPoolTarget(
            pool, prompt_len=4,
            on_done=lambda b, lat, now: done.append((b.size, lat)))
        target(_batch(50))
        assert len(done) == 1 and done[0][0] == 50


class TestEngineBackedLatencyClamp:
    def _stub_engine(self):
        class _Cfg:
            vocab_size = 100

        class _Eng:
            cfg = _Cfg()
            ecfg = _StubEngineCfg()

            def __init__(self):
                self.sizes = []

            def generate(self, prompts, gen_len=None):
                n = prompts.shape[0]
                if n > BUCKETS[-1]:
                    raise ValueError("oversized")
                self.sizes.append(n)
                return (np.zeros((n, 1), np.int32),
                        {"latency_s": 0.01 * next_bucket(n, BUCKETS),
                         "bucket": next_bucket(n, BUCKETS)})
        return _Eng()

    def test_mean_query_beyond_largest_bucket_is_total(self):
        from repro.serving.batcher import EngineBackedLatency

        lat = EngineBackedLatency(self._stub_engine(), prompt_len=4)
        assert lat.mean(100) == 0.0  # nothing measured yet, but no raise
        rng = np.random.default_rng(0)
        lat.sample(8, rng)
        # oversized estimate carries the same chunk factor sample() pays:
        # 100 requests = 4 sequential largest-bucket calls
        assert lat.mean(100) == pytest.approx(4 * lat.mean(32))
        assert lat.mean(33) == pytest.approx(2 * lat.mean(32))

    def test_sample_chunks_oversized_sizes(self):
        from repro.serving.batcher import EngineBackedLatency

        eng = self._stub_engine()
        lat = EngineBackedLatency(eng, prompt_len=4)
        total = lat.sample(70, np.random.default_rng(0))
        assert eng.sizes == [32, 32, 6]
        # 0.32 + 0.32 + 0.08 (bucket-8 latency for the 6-tail chunk)
        assert total == pytest.approx(0.72)
