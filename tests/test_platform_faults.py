"""Regression tests for the attempt-ledger fault path of the platform.

Covers the three bug classes the ledger fixes — lost co-resident batches
on crash, unbounded hedge storms, and phantom concurrency from completed
items stuck in the queue — plus the conservation invariant end-to-end
across every policy, and a fast slice of the chaos scenario suite.
"""
import numpy as np
import pytest

from repro.core import SLAConfig
from repro.core.request import Batch, Request
from repro.serverless.latency import AffineLatency, get_workload
from repro.serverless.platform import PlatformConfig, ServerlessPlatform
from repro.simulation.arrivals import PoissonProcess
from repro.simulation.simulator import (
    EndpointSpec,
    MultiEndpointSimulator,
    Simulator,
)

from experiments.scenarios import POLICIES, SCENARIOS, run_scenario


def _mk_platform(**cfg_kw):
    events_done = []
    from repro.simulation.events import EventQueue

    events = EventQueue()
    plat = ServerlessPlatform(
        config=PlatformConfig(**cfg_kw),
        latency_model=AffineLatency(a=0.1, c=0.0, noise_cv=0.0),
        events=events,
        rng=np.random.default_rng(0),
        on_batch_done=lambda b, lat, t: events_done.append((b, lat, t)),
    )
    return plat, events, events_done


def _drain(events, until=1e9):
    now = 0.0
    while events:
        t, fn = events.pop()
        if t > until:
            break
        now = t
        fn(t)
    return now


def _one_batch(t=0.0):
    return Batch(requests=[Request(arrival_time=t)], dispatch_time=t, cause="full")


# ----------------------------------------------------- crash: co-resident loss
def test_crash_requeues_all_coresident_batches():
    # Four batches share one container (concurrency 4); the container dies
    # mid-service. Pre-ledger, only the crashing batch was requeued and the
    # other three vanished (their completions early-returned on terminated).
    plat, events, done = _mk_platform(
        initial_scale=1, min_scale=1, max_scale=1,
        container_concurrency=4, ps_slowdown=0.0,
    )
    for _ in range(4):
        plat.submit(_one_batch(), 0.0)
    c = plat.containers[0]
    assert c.inflight == 4 and len(c.attempts) == 4
    plat._crash(c.attempts[0], 0.05)
    assert plat.failed_attempts == 1
    cons = plat.assert_conserved()
    assert cons["queued_batches"] == 4  # all four requeued, none lost
    assert cons["lost_batches"] == 0
    _drain(events, until=120.0)
    assert len(done) == 4
    plat.assert_conserved(require_drained=True)


def test_crash_requeue_preserves_fifo_order():
    plat, events, done = _mk_platform(
        initial_scale=1, min_scale=1, max_scale=1,
        container_concurrency=3, ps_slowdown=0.0,
    )
    batches = [_one_batch() for _ in range(3)]
    for b in batches:
        plat.submit(b, 0.0)
    c = plat.containers[0]
    started_order = [a.item.batch for a in c.attempts]
    plat._crash(c.attempts[0], 0.05)
    requeued = [it.batch for it in plat.pending if it.queued]
    assert requeued == started_order  # oldest attempt re-dispatches first


def test_stochastic_crashes_never_lose_work():
    plat, events, done = _mk_platform(
        initial_scale=2, min_scale=1, container_concurrency=4,
        ps_slowdown=0.25, failure_prob_per_batch=0.3,
    )
    for i in range(50):
        plat.submit(_one_batch(i * 0.05), i * 0.05)
    _drain(events, until=600.0)
    assert len(done) == 50
    assert plat.failed_attempts > 0  # the fault path actually fired
    cons = plat.assert_conserved(require_drained=True)
    assert cons["requeued_batches"] >= plat.failed_attempts


# -------------------------------------------------------------- hedge storms
def test_hedge_capped_and_anti_affine():
    # One guaranteed straggler; hedge timer fires long before it finishes.
    # The duplicate must land on a DIFFERENT container, and max_hedges=1
    # must keep one straggler from fanning out further.
    plat, events, done = _mk_platform(
        initial_scale=2, min_scale=2, container_concurrency=2,
        ps_slowdown=0.0, straggler_prob=1.0, straggler_mult=50.0,
        hedge_factor=2.0, max_hedges=1,
    )
    plat.submit(_one_batch(), 0.0)
    _drain(events, until=1.0)  # hedge fires at 0.2; service runs 5s
    assert plat.hedged_dispatches == 1
    (item,) = plat._open.values()
    assert len(item.live) == 2
    c0, c1 = (a.container for a in item.live)
    assert c0 is not c1  # anti-affinity: duplicate avoids the original's host
    _drain(events, until=60.0)
    assert len(done) == 1  # first finisher wins, exactly once
    assert plat.hedged_dispatches == 1  # capped: no storm off the duplicate
    assert plat.cancelled_attempts == 1  # loser cancelled on the spot
    plat.assert_conserved(require_drained=True)


def test_hedge_storm_bounded_by_max_hedges():
    # Pre-ledger, every hedged duplicate re-armed its own hedge timer, so a
    # slow item spawned duplicates without bound. Now: ≤ max_hedges each.
    plat, events, done = _mk_platform(
        initial_scale=4, min_scale=4, container_concurrency=2,
        ps_slowdown=0.0, straggler_prob=1.0, straggler_mult=100.0,
        hedge_factor=1.5, max_hedges=2,
    )
    n = 5
    for _ in range(n):
        plat.submit(_one_batch(), 0.0)
    _drain(events, until=300.0)
    assert len(done) == n
    assert plat.hedged_dispatches <= n * 2
    plat.assert_conserved(require_drained=True)


def test_winner_frees_sibling_slot_immediately():
    # Straggler on c0, hedge on c1 finishes first → c0's slot must free the
    # instant the winner completes, not when the straggler's timer fires.
    plat, events, done = _mk_platform(
        initial_scale=2, min_scale=2, container_concurrency=1,
        ps_slowdown=0.0, straggler_prob=0.5, straggler_mult=100.0,
        hedge_factor=2.0, max_hedges=1,
    )
    plat.submit(_one_batch(), 0.0)  # rng: first straggler draw hits (0.5)
    t = _drain(events, until=2.0)
    if plat.hedged_dispatches:  # hedge completed; straggler still "running"
        assert len(done) == 1
        total_inflight = sum(
            c.inflight for c in plat.containers if not c.terminated
        )
        assert total_inflight == 0  # straggler's slot already reclaimed
    plat.assert_conserved()


# ------------------------------------------------------- drain / scale-down
def test_drain_then_crash_requeues_inflight_work():
    plat, events, done = _mk_platform(
        initial_scale=2, min_scale=1, max_scale=2,
        container_concurrency=1, ps_slowdown=0.0,
    )
    plat.submit(_one_batch(), 0.0)
    plat.submit(_one_batch(), 0.0)
    plat._scale_to(1, 0.01)  # both busy → one container drains
    draining = [c for c in plat.containers if c.draining]
    assert len(draining) == 1
    plat._crash(draining[0].attempts[0], 0.05)  # dies before finishing drain
    _drain(events, until=60.0)
    assert len(done) == 2  # the draining container's batch was not lost
    plat.assert_conserved(require_drained=True)


def test_drain_completes_then_terminates():
    plat, events, done = _mk_platform(
        initial_scale=2, min_scale=1, max_scale=2,
        container_concurrency=1, ps_slowdown=0.0,
    )
    plat.submit(_one_batch(), 0.0)
    plat.submit(_one_batch(), 0.0)
    plat._scale_to(1, 0.01)
    draining = [c for c in plat.containers if c.draining]
    _drain(events, until=30.0)
    assert len(done) == 2
    assert all(c.terminated for c in draining)
    plat.assert_conserved(require_drained=True)


# ------------------------------------------------------ phantom concurrency
def test_completed_item_leaves_autoscaler_signal():
    # concurrency 1, one container: the hedge can never be placed (anti-
    # affine, no second host), so the item sits queued until the original
    # finishes. Pre-ledger the done item stayed in `pending` and kept
    # feeding concurrency=1 to the autoscaler forever.
    plat, events, done = _mk_platform(
        initial_scale=1, min_scale=1, max_scale=1,
        container_concurrency=1, ps_slowdown=0.0,
        straggler_prob=1.0, straggler_mult=30.0,
        hedge_factor=0.5, max_hedges=1,
    )
    plat.submit(_one_batch(), 0.0)
    _drain(events, until=10.0)
    assert len(done) == 1
    assert plat.hedged_dispatches == 1
    assert plat.queued_batches == 0
    assert plat._concurrency() == 0.0  # no phantom KPA signal
    plat.assert_conserved(require_drained=True)


def test_window_avg_ignores_stale_buffer():
    plat, _, _ = _mk_platform(initial_scale=0)
    plat._conc_samples.extend([(0.0, 0.0), (1.0, 5.0)])
    # every sample predates the window → fall back to the instantaneous
    # signal (0 here), not the average over the whole stale buffer (5.0)
    assert plat._window_avg(100.0, 5.0) == 0.0


# ------------------------------------------------- conservation, end to end
FAULT_PC = PlatformConfig(
    initial_scale=2, container_concurrency=4, ps_slowdown=0.25,
    failure_prob_per_batch=0.05, straggler_prob=0.05, straggler_mult=8.0,
    hedge_factor=3.0, max_hedges=1,
)


@pytest.mark.parametrize("policy", POLICIES)
def test_conservation_invariant_every_policy(policy):
    wl = get_workload("sklearn-iris")
    kw = {}
    if policy == "static":
        kw = {"batch_size": 8, "timeout": 0.2}
    elif policy == "oracle":
        kw = {"latency_model": lambda bs: wl.percentile(bs, 95)}
    sim = Simulator(
        policy=policy, sla=SLAConfig(slo_target=0.5), workload=wl,
        arrivals=PoissonProcess(rate=40.0, duration=120.0),
        platform_config=FAULT_PC, policy_kwargs=kw,
        duration=120.0, drain_grace=120.0, seed=7,
    )
    res = sim.run()
    cons = sim.platform.assert_conserved(require_drained=True)
    s = res.summary
    assert s["lost_batches"] == 0.0
    assert s["duplicate_completions"] == 0.0
    assert s["completed_batches"] == s["submitted_batches"]
    assert cons["completed_requests"] == cons["submitted_requests"]
    # every arrival came back out exactly once
    assert s["completed"] == cons["submitted_requests"]


def test_conservation_deterministic_given_seed():
    def one():
        sim = Simulator(
            policy="mlproxy", sla=SLAConfig(slo_target=0.5),
            workload=get_workload("sklearn-iris"),
            arrivals=PoissonProcess(rate=40.0, duration=90.0),
            platform_config=FAULT_PC,
            duration=90.0, drain_grace=120.0, seed=13,
        )
        sim.run()
        return sim.platform.conservation()

    assert one() == one()


def test_multi_endpoint_fleet_conserves_and_reports_retries():
    # shared fleet under faults: the frontend's aggregate stats must see the
    # platform-side retries (Batch.attempts plumbing) and the fleet summary
    # must balance
    spec = dict(
        sla=SLAConfig(slo_target=0.5),
        workload=get_workload("sklearn-iris"),
        platform="shared",
        platform_config=FAULT_PC,
    )
    sim = MultiEndpointSimulator(
        {
            "a": EndpointSpec(policy="mlproxy",
                              arrivals=PoissonProcess(rate=25.0, duration=90.0),
                              **spec),
            "b": EndpointSpec(policy="passthrough",
                              arrivals=PoissonProcess(rate=25.0, duration=90.0),
                              **spec),
        },
        duration=90.0, drain_grace=120.0, seed=5,
    )
    res = sim.run()
    for plat in sim.platforms.values():
        plat.assert_conserved(require_drained=True)
    s = res.summary
    assert s["lost_batches"] == 0.0
    assert s["duplicate_completions"] == 0.0
    assert s["completed_batches"] == s["submitted_batches"]
    agg = res.frontend_stats["aggregate"]
    assert agg["retried_batches"] > 0  # faults were visible to the proxy
    assert 0.0 < agg["retry_rate"] <= 1.0


# ------------------------------------------------------------ chaos suite
def test_chaos_scenario_fast_subset():
    # one scenario end-to-end through experiments.scenarios (CI-fast slice)
    res, cons = run_scenario("crash-storm", "mlproxy", quick=True)
    assert cons["lost_batches"] == 0
    assert cons["duplicate_completions"] == 0
    assert cons["completed_requests"] == cons["submitted_requests"]
    assert res.summary["requeued_batches"] > 0  # crashes actually happened


@pytest.mark.chaos
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_chaos_scenario_sweep(name):
    for policy in ("passthrough", "mlproxy"):
        res, cons = run_scenario(name, policy, quick=True)
        assert cons["lost_batches"] == 0
        assert cons["duplicate_completions"] == 0
        assert cons["completed_requests"] == cons["submitted_requests"]
