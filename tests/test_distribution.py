"""Distribution tests: sharding specs, small-mesh compilation, shard_map MoE
equivalence, collective parser, roofline math. Runs on 4 virtual host
devices (set before jax initializes — safe because this module is the only
one spawning its own subprocess-scoped device count)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_param_specs_basic():
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.distributed.sharding import param_spec
    from repro.launch.mesh import make_mesh

    # use a tiny mesh only for axis names; divisibility math is pure
    import jax
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("yi-34b")
    # attention projection: features over model, d_model over data
    sp = param_spec("layers/attn/wq", (60, 7168, 7168), mesh, cfg)
    assert sp[2] == "model" if mesh.shape["model"] > 1 else True
    # 1-D leaves replicated
    sp = param_spec("layers/attn_norm/scale", (60, 7168), mesh, cfg)
    assert all(s is None for s in sp)


def test_param_specs_on_real_mesh():
    code = """
import jax
from repro.configs import get_config
from repro.distributed.sharding import param_spec
from jax.sharding import PartitionSpec as P
mesh = jax.make_mesh((2, 2), ("data", "model"))
cfg = get_config("yi-34b")
assert param_spec("layers/attn/wq", (60, 7168, 7168), mesh, cfg) == P(None, "data", "model")
assert param_spec("layers/attn/wo", (60, 7168, 7168), mesh, cfg) == P(None, "model", "data")
assert param_spec("embed", (64000, 7168), mesh, cfg) == P("model", "data")
assert param_spec("lm_head", (7168, 64000), mesh, cfg) == P("data", "model")
cfg_moe = get_config("kimi-k2-1t-a32b")
sp = param_spec("layers/moe/wi", (61, 384, 7168, 2, 2048), mesh, cfg_moe)
assert sp[1] == "model" and sp[4] == "data", sp
sp = param_spec("layers/moe/wo", (61, 384, 2048, 7168), mesh, cfg_moe)
assert sp[1] == "model" and sp[2] == "data", sp
print("OK")
"""
    assert "OK" in run_py(code)


def test_small_mesh_train_compiles_and_runs():
    """Real (not abstract) train step on a 2x2 mesh with full sharding."""
    code = """
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.model import Model
from repro.distributed import sharding as shd
from repro.optim import adamw
cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(), num_layers=2)
model = Model(cfg)
mesh = jax.make_mesh((2, 2), ("data", "model"))
params = model.init(jax.random.PRNGKey(0))
psh = shd.shard_params(params, mesh, cfg)
params = jax.device_put(params, psh)
opt_cfg = adamw.AdamWConfig()
opt = adamw.init_state(opt_cfg, params)
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size, dtype=jnp.int32)
def step(p, o, batch):
    loss, g = jax.value_and_grad(model.loss)(p, batch)
    p, o, m = adamw.apply_updates(opt_cfg, p, g, o)
    return p, o, loss
with mesh:
    p2, o2, loss = jax.jit(step)(params, opt, {"tokens": tokens, "labels": tokens})
assert jnp.isfinite(loss), loss
print("loss", float(loss))
"""
    out = run_py(code)
    assert "loss" in out


def test_shard_map_moe_matches_global_on_mesh():
    """Both shard_map plans (token-route for small T, weight-gather for
    large T) must match the no-mesh oracle exactly (no capacity drops)."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.models.moe import init_moe, moe_ffn, _moe_global
key = jax.random.PRNGKey(0)
D,E,F = 32, 8, 64
p = init_moe(key, D, E, F, "silu", jnp.float32)
mesh = jax.make_mesh((2, 2), ("data", "model"))
for (B, S, tag) in [(4, 8, "token-route"), (8, 32, "weight-gather")]:
    x = jax.random.normal(jax.random.fold_in(key, B), (B, S, D))
    y_ref, _ = _moe_global(p, x, top_k=2, capacity_factor=8.0)
    with mesh:
        y_sm, _ = jax.jit(lambda p, x: moe_ffn(p, x, top_k=2, capacity_factor=8.0))(p, x)
    err = float(jnp.max(jnp.abs(y_ref - y_sm)))
    assert err < 1e-5, (tag, err)
    print("OK", tag, err)
"""
    out = run_py(code)
    assert out.count("OK") == 2


def test_multipod_mesh_axes():
    code = """
from repro.launch.mesh import make_production_mesh
import numpy as np
m = make_production_mesh(multi_pod=False)
assert m.axis_names == ("data", "model") and m.devices.shape == (16, 16)
print("OK-single")
"""
    env_code = code  # needs 256 devices
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", env_code], capture_output=True,
                         text=True, env=env, timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK-single" in out.stdout


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
  %ag = bf16[128,256]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[64]{0} all-reduce(%y), to_apply=%add
  %cp = (s32[8]{0}, s32[8]{0}) collective-permute(%a, %b), channel_id=3
  %nothing = f32[10]{0} add(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["counts"] == {"all-gather": 1, "all-reduce": 1,
                             "collective-permute": 1}
    assert out["bytes_by_op"]["all-gather"] == 128 * 256 * 2
    assert out["bytes_by_op"]["all-reduce"] == 64 * 4
    assert out["bytes_by_op"]["collective-permute"] == 2 * 8 * 4


def test_roofline_extrapolation_math():
    from repro.roofline.analysis import _extrapolate, RooflineRow

    pts = [{"depth": 2, "v": 10.0}, {"depth": 4, "v": 16.0}]
    assert _extrapolate(pts, 10, lambda p: p["v"]) == pytest.approx(34.0)
    row = RooflineRow(arch="a", shape="s", mesh="m", status="ok",
                      t_compute=1.0, t_memory=2.0, t_collective=0.5)
    assert row.dominant() == "memory"


def test_roofline_on_artifacts_if_present():
    from repro.roofline.analysis import ARTIFACT_DIR, roofline_table

    if not os.path.isdir(ARTIFACT_DIR) or not os.listdir(ARTIFACT_DIR):
        pytest.skip("no dry-run artifacts yet")
    rows = roofline_table("pod1")
    assert rows
    for r in rows:
        if r.status == "ok":
            assert r.t_compute >= 0 and r.t_memory >= 0
            assert r.bottleneck in ("compute", "memory", "collective")
