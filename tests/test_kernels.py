"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return {"float32": 2e-5, "bfloat16": 2e-2}[jnp.dtype(dtype).name]


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,hq,hkv,d", [
    (1, 128, 4, 4, 32),    # MHA, exact block fit
    (2, 200, 8, 2, 64),    # GQA 4:1, ragged block
    (1, 64, 6, 3, 16),     # GQA 2:1, small
    (2, 257, 4, 1, 32),    # MQA, off-by-one length
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_oracle(b, s, hq, hkv, d, dtype, causal):
    key = jax.random.PRNGKey(b * 1000 + s)
    q = _rand(key, (b, s, hq, d), dtype)
    k = _rand(jax.random.fold_in(key, 1), (b, s, hkv, d), dtype)
    v = _rand(jax.random.fold_in(key, 2), (b, s, hkv, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_attention_block_size_invariance():
    key = jax.random.PRNGKey(0)
    q = _rand(key, (1, 160, 4, 32), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (1, 160, 2, 32), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (1, 160, 2, 32), jnp.float32)
    a = ops.flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    b = ops.flash_attention(q, k, v, block_q=128, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_flash_attention_two_oracles_agree():
    key = jax.random.PRNGKey(3)
    q = _rand(key, (2, 96, 4, 16), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (2, 96, 2, 16), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (2, 96, 2, 16), jnp.float32)
    a = ref.flash_attention(q, k, v)
    b = ref.flash_attention_chunked(q, k, v, q_chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ------------------------------------------------------------ decode attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,hq,hkv,d,valid", [
    (2, 128, 8, 2, 64, 128),   # full cache
    (2, 128, 8, 2, 64, 77),    # partial cache
    (1, 640, 4, 4, 32, 501),   # multi-block, MHA
    (4, 96, 4, 1, 16, 33),     # MQA
])
def test_decode_attention_matches_oracle(b, s, hq, hkv, d, valid, dtype):
    key = jax.random.PRNGKey(s + valid)
    q = _rand(key, (b, 1, hq, d), dtype)
    kc = _rand(jax.random.fold_in(key, 1), (b, s, hkv, d), dtype)
    vc = _rand(jax.random.fold_in(key, 2), (b, s, hkv, d), dtype)
    out = ops.decode_attention(q, kc, vc, jnp.asarray(valid), block_k=128,
                               interpret=True)
    want = ref.decode_attention(q, kc, vc, jnp.asarray(valid))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


def test_decode_attention_ignores_padding_content():
    """Anything beyond cache_len must not affect the output."""
    key = jax.random.PRNGKey(0)
    b, s, hq, hkv, d, valid = 2, 64, 4, 2, 32, 40
    q = _rand(key, (b, 1, hq, d), jnp.float32)
    kc = _rand(jax.random.fold_in(key, 1), (b, s, hkv, d), jnp.float32)
    vc = _rand(jax.random.fold_in(key, 2), (b, s, hkv, d), jnp.float32)
    out1 = ops.decode_attention(q, kc, vc, jnp.asarray(valid), interpret=True)
    kc2 = kc.at[:, valid:].set(999.0)
    vc2 = vc.at[:, valid:].set(-999.0)
    out2 = ops.decode_attention(q, kc2, vc2, jnp.asarray(valid), interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


# -------------------------------------------------------------------- SSD scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 16, 16, 16),
    (2, 100, 4, 8, 32, 32),   # ragged chunks
    (1, 33, 1, 32, 8, 16),    # off-by-one
])
def test_ssd_scan_matches_oracle(b, s, h, p, n, chunk, dtype):
    key = jax.random.PRNGKey(s * 10 + h)
    x = _rand(key, (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(
        jax.random.fold_in(key, 1), (b, s, h))).astype(jnp.float32)
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
    bb = _rand(jax.random.fold_in(key, 3), (b, s, n), dtype)
    cc = _rand(jax.random.fold_in(key, 4), (b, s, n), dtype)
    out = ops.ssd_scan(x, dt, a, bb, cc, chunk=chunk, interpret=True)
    want = ref.ssd_scan(x, dt, a, bb, cc, chunk=chunk)
    tol = _tol(dtype) * 4  # long products of decays amplify rounding
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


def test_ssd_scan_matches_sequential_oracle():
    key = jax.random.PRNGKey(9)
    b, s, h, p, n = 1, 48, 2, 8, 16
    x = _rand(key, (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
    bb = _rand(jax.random.fold_in(key, 3), (b, s, n), jnp.float32)
    cc = _rand(jax.random.fold_in(key, 4), (b, s, n), jnp.float32)
    out = ops.ssd_scan(x, dt, a, bb, cc, chunk=16, interpret=True)
    want = ref.ssd_scan_sequential(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_ssd_scan_chunk_invariance():
    key = jax.random.PRNGKey(4)
    b, s, h, p, n = 2, 64, 2, 8, 8
    x = _rand(key, (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, s, h)))
    a = -jnp.exp(jnp.zeros((h,)))
    bb = _rand(jax.random.fold_in(key, 3), (b, s, n), jnp.float32)
    cc = _rand(jax.random.fold_in(key, 4), (b, s, n), jnp.float32)
    o1 = ops.ssd_scan(x, dt, a, bb, cc, chunk=8, interpret=True)
    o2 = ops.ssd_scan(x, dt, a, bb, cc, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


# -------------------------------------------------------------------- mLSTM
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,d,bq,bk", [
    (2, 128, 2, 32, 64, 64),   # exact block fit
    (1, 200, 4, 16, 64, 32),   # ragged blocks
    (2, 65, 3, 64, 128, 128),  # single padded block
])
def test_mlstm_attention_matches_oracle(b, s, h, d, bq, bk, dtype):
    key = jax.random.PRNGKey(s + d)
    q = _rand(key, (b, s, h, d), dtype)
    k = _rand(jax.random.fold_in(key, 1), (b, s, h, d), dtype)
    v = _rand(jax.random.fold_in(key, 2), (b, s, h, d), dtype)
    log_i = (jax.random.normal(jax.random.fold_in(key, 3), (b, s, h)) * 0.5
             ).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        jax.random.normal(jax.random.fold_in(key, 4), (b, s, h)) + 2.0)
    out = ops.mlstm_attention(q, k, v, log_i, log_f, block_q=bq, block_k=bk,
                              interpret=True)
    want = ref.mlstm_attention(q, k, v, log_i, log_f)
    tol = _tol(dtype) * 2  # signed-denominator normalizer amplifies rounding
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


def test_mlstm_attention_block_invariance():
    key = jax.random.PRNGKey(11)
    b, s, h, d = 1, 160, 2, 32
    q = _rand(key, (b, s, h, d), jnp.float32)
    k = _rand(jax.random.fold_in(key, 1), (b, s, h, d), jnp.float32)
    v = _rand(jax.random.fold_in(key, 2), (b, s, h, d), jnp.float32)
    log_i = jnp.zeros((b, s, h))
    log_f = jax.nn.log_sigmoid(jnp.full((b, s, h), 2.0))
    a = ops.mlstm_attention(q, k, v, log_i, log_f, block_q=32, block_k=32,
                            interpret=True)
    c = ops.mlstm_attention(q, k, v, log_i, log_f, block_q=128, block_k=64,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)


# ------------------------------------------------- model integration (pallas)
def test_model_with_pallas_attention_matches_jnp():
    import dataclasses

    from repro.configs import get_config
    from repro.models.model import Model

    cfg = get_config("yi-34b").reduced()
    cfg_pl = dataclasses.replace(cfg, use_pallas=True)
    model, model_pl = Model(cfg), Model(cfg_pl)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    a = model.forward(params, tokens)
    b = model_pl.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)
