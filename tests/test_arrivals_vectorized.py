"""Statistical and exactness tests for the vectorized arrival paths.

The block-sampling ``next_arrivals`` API must (a) reproduce each process's
analytic rate within confidence bounds, (b) produce a strictly ordered
in-range stream across window boundaries (including boundary-aligned
lattices), and (c) stay bit-deterministic for a fixed seed.
"""
import numpy as np
import pytest

from repro.simulation.arrivals import (
    ArrivalProcess,
    DeterministicProcess,
    MMPP2,
    PoissonProcess,
    TraceModulatedPoisson,
)
from repro.simulation.traces import Trace


def sweep(proc, rng, duration, horizon):
    """Drive contiguous (clock, clock+h] windows over [0, duration)."""
    proc.reset()
    out = []
    clock = 0.0
    while clock < duration:
        h = min(horizon, duration - clock)
        out.append(proc.next_arrivals(clock, rng, h))
        clock += h
    return np.concatenate(out) if out else np.empty(0)


def scalar_chain(proc, rng):
    proc.reset()
    out = []
    t = 0.0
    while True:
        t = proc.next_arrival(t, rng)
        if t is None:
            return np.asarray(out)
        out.append(t)


# ------------------------------------------------------------------ poisson
def test_poisson_vectorized_rate_within_ci():
    rate, duration = 50.0, 400.0
    times = sweep(PoissonProcess(rate=rate, duration=duration),
                  np.random.default_rng(0), duration, horizon=8.0)
    expected = rate * duration
    # 5-sigma band on a Poisson count
    assert abs(len(times) - expected) < 5 * np.sqrt(expected)
    assert np.all(np.diff(times) > 0)
    assert times[0] > 0 and times[-1] < duration


def test_poisson_rate_invariant_to_horizon():
    rate, duration = 80.0, 200.0
    for horizon in (0.5, 7.0, 200.0):
        times = sweep(PoissonProcess(rate=rate, duration=duration),
                      np.random.default_rng(1), duration, horizon)
        expected = rate * duration
        assert abs(len(times) - expected) < 5 * np.sqrt(expected)


def test_poisson_deterministic_given_seed():
    p = PoissonProcess(rate=40.0, duration=100.0)
    a = sweep(p, np.random.default_rng(9), 100.0, 4.0)
    b = sweep(p, np.random.default_rng(9), 100.0, 4.0)
    np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------ deterministic
def test_deterministic_vectorized_matches_scalar_chain():
    proc = DeterministicProcess(gap=0.3, duration=10.0)
    ref = scalar_chain(proc, np.random.default_rng(0))
    for horizon in (1.0, 2.7, 10.0):
        times = sweep(proc, np.random.default_rng(0), 10.0, horizon)
        assert len(times) == len(ref)
        np.testing.assert_allclose(times, ref, atol=1e-9)


def test_deterministic_duration_boundary_is_exclusive():
    # duration an exact multiple of gap: the arrival at k*gap == duration
    # must be excluded (analytic count), even though the scalar chain's
    # accumulated rounding may sneak its last arrival in a few ulps early
    times = sweep(DeterministicProcess(gap=0.1, duration=10.0),
                  np.random.default_rng(0), 10.0, horizon=4.0)
    assert len(times) == 99  # 0.1 .. 9.9
    assert times[-1] < 10.0


def test_deterministic_boundary_aligned_window_keeps_arrival():
    # gap divides the horizon: the arrival landing exactly on a window
    # boundary must appear exactly once (half-open (now, now+h] windows)
    times = sweep(DeterministicProcess(gap=0.5, duration=10.25),
                  np.random.default_rng(0), 10.25, horizon=8.0)
    assert len(times) == 20  # 0.5 .. 10.0
    assert np.all(np.diff(times) > 0)
    assert 8.0 in times.tolist()


# -------------------------------------------------------------------- mmpp2
def test_mmpp2_vectorized_rate_within_band():
    duration = 400.0
    proc = MMPP2(rate_lo=1.0, rate_hi=100.0, mean_lo=10.0, mean_hi=10.0,
                 duration=duration)
    times = sweep(proc, np.random.default_rng(0), duration, horizon=16.0)
    # stationary mean rate = (1+100)/2; generous band (few sojourn cycles)
    expected = 50.5 * duration
    assert 0.6 * expected < len(times) < 1.4 * expected
    assert np.all(np.diff(times) > 0)


def test_mmpp2_reset_makes_sweeps_reproducible():
    proc = MMPP2(rate_lo=5.0, rate_hi=50.0, mean_lo=5.0, mean_hi=5.0,
                 duration=100.0)
    a = sweep(proc, np.random.default_rng(3), 100.0, 8.0)
    b = sweep(proc, np.random.default_rng(3), 100.0, 8.0)  # reset() in sweep
    np.testing.assert_array_equal(a, b)


# ----------------------------------------------------------------- thinning
def test_thinning_vectorized_follows_trace():
    tr = Trace(times=np.array([0.0, 100.0, 200.0]), rates=np.array([5.0, 50.0]))
    times = sweep(TraceModulatedPoisson(tr), np.random.default_rng(0),
                  200.0, horizon=16.0)
    lo = int(np.count_nonzero(times < 100.0))
    hi = len(times) - lo
    assert lo == pytest.approx(500, rel=0.2)
    assert hi == pytest.approx(5000, rel=0.1)
    assert np.all(np.diff(times) > 0)


def test_rate_at_many_matches_scalar():
    tr = Trace(times=np.array([0.0, 10.0, 20.0]), rates=np.array([1.0, 3.0]))
    ts = np.array([-1.0, 0.0, 5.0, 10.0, 15.0, 19.999, 20.0, 25.0])
    np.testing.assert_array_equal(
        tr.rate_at_many(ts), [tr.rate_at(float(t)) for t in ts]
    )


# --------------------------------------------------------- generic fallback
class _ScalarOnly(ArrivalProcess):
    """Process that implements only the scalar API (third-party shape)."""

    def __init__(self, gap, duration):
        self.gap, self.duration = gap, duration

    def next_arrival(self, now, rng):
        t = now + self.gap
        return t if t < self.duration else None


def test_generic_fallback_buffers_overshoot_across_windows():
    proc = _ScalarOnly(gap=1.3, duration=20.0)
    ref = scalar_chain(proc, np.random.default_rng(0))
    times = sweep(proc, np.random.default_rng(0), 20.0, horizon=1.0)
    np.testing.assert_allclose(times, ref, atol=1e-12)


def test_generic_fallback_reset_clears_pending():
    proc = _ScalarOnly(gap=1.5, duration=10.0)
    first = sweep(proc, np.random.default_rng(0), 10.0, horizon=1.0)
    second = sweep(proc, np.random.default_rng(0), 10.0, horizon=1.0)
    np.testing.assert_array_equal(first, second)
