"""Gradient compression: quantization error bounds + error-feedback
convergence property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (
    TopKCompressor,
    int8_compress,
    int8_decompress,
)


def test_int8_roundtrip_error_bound():
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (64, 32)),
            "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (128,))}}
    c = int8_compress(tree)
    back = int8_decompress(c, tree)
    for orig, rec in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        scale = float(jnp.max(jnp.abs(orig))) / 127.0
        assert float(jnp.max(jnp.abs(orig - rec))) <= scale * 0.5 + 1e-7


def test_int8_traffic_reduction():
    tree = {"w": jnp.zeros((1000,), jnp.float32)}
    c = int8_compress(tree)
    q, scale = c["w"]
    assert q.dtype == jnp.int8  # 4× fewer bytes than f32
    assert scale.shape == ()


def test_topk_error_feedback_transmits_everything_eventually():
    """With error feedback, the sum of decompressed gradients over steps
    converges to the sum of true gradients (nothing is lost, only delayed)."""
    comp = TopKCompressor(fraction=0.1)
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(100,)),
                          jnp.float32)}
    state = comp.init(g)
    total_sent = jnp.zeros((100,), jnp.float32)
    steps = 60
    for _ in range(steps):
        payload, state = comp.compress(g, state)
        total_sent = total_sent + comp.decompress(payload, g)["w"]
    total_true = g["w"] * steps
    # residual is bounded → per-step average converges to the true gradient
    err = float(jnp.max(jnp.abs(total_sent / steps - g["w"])))
    assert err < 0.12 * float(jnp.max(jnp.abs(g["w"])))


def test_topk_sparsity_and_bytes():
    comp = TopKCompressor(fraction=0.05)
    g = {"w": jnp.ones((1000,), jnp.float32)}
    state = comp.init(g)
    payload, state = comp.compress(g, state)
    vals, idx, shape = payload["w"]
    assert vals.shape[0] == 50
    assert comp.compressed_bytes(g) == 50 * 8
    dense = comp.decompress(payload, g)
    assert float(jnp.sum(dense["w"] != 0)) == 50


def test_topk_selects_largest_magnitudes():
    comp = TopKCompressor(fraction=0.02)
    x = jnp.zeros((100,)).at[7].set(10.0).at[42].set(-9.0)
    payload, _ = comp.compress({"w": x}, comp.init({"w": x}))
    vals, idx, _ = payload["w"]
    assert set(np.asarray(idx).tolist()) == {7, 42}
