"""Unit tests for the Smart Monitor (windows, percentiles, fallbacks)."""
import math
import random

import pytest

from repro.core import MonitorConfig, SLAConfig, SmartMonitor
from repro.core.monitor import LatencyWindow, P2Quantile, _theil_sen_fit

SLA = SLAConfig(slo_target=0.5)


def test_latency_window_percentile_nearest_rank():
    w = LatencyWindow(maxlen=100, horizon=1e9)
    for i, v in enumerate([10.0, 20.0, 30.0, 40.0]):
        w.add(float(i), v)
    assert w.percentile(50) == 20.0
    assert w.percentile(95) == 40.0
    assert w.percentile(100) == 40.0


def test_latency_window_horizon_eviction():
    w = LatencyWindow(maxlen=100, horizon=10.0)
    w.add(0.0, 1.0)
    w.add(5.0, 2.0)
    w.add(20.0, 3.0)
    assert w.values(now=21.0) == [2.0, 3.0][1:] or w.values(now=21.0) == [3.0]
    # at t=21, cutoff=11: sample at t=5 evicted too
    assert w.values(now=21.0) == [3.0]


def test_latency_window_maxlen():
    w = LatencyWindow(maxlen=8, horizon=1e9)
    for i in range(100):
        w.add(float(i), float(i))
    assert len(w) == 8
    assert w.values() == [float(i) for i in range(92, 100)]


def test_p2_quantile_converges_to_empirical():
    rng = random.Random(0)
    est = P2Quantile(0.95)
    xs = [rng.expovariate(1.0) for _ in range(5000)]
    for x in xs:
        est.add(x)
    emp = sorted(xs)[int(0.95 * len(xs))]
    assert est.value() == pytest.approx(emp, rel=0.15)


def test_p2_quantile_few_samples():
    est = P2Quantile(0.95)
    for x in [1.0, 2.0, 3.0]:
        est.add(x)
    assert est.value() == 3.0


def test_theil_sen_fit_recovers_line():
    pts = [(1.0, 0.1 + 0.02 * 1), (2.0, 0.1 + 0.02 * 2), (4.0, 0.1 + 0.02 * 4),
           (8.0, 0.1 + 0.02 * 8)]
    a, b = _theil_sen_fit(pts)
    assert a == pytest.approx(0.1, abs=1e-9)
    assert b == pytest.approx(0.02, abs=1e-9)


def test_monitor_exact_window_path():
    mon = SmartMonitor(MonitorConfig(min_samples=3), SLA)
    for i in range(10):
        mon.record_upstream(4, 0.1 + 0.001 * i, now=float(i))
    est = mon.upstream_percentile(4, now=10.0)
    assert 0.1 <= est <= 0.11


def test_monitor_regression_fallback_for_unseen_size():
    mon = SmartMonitor(MonitorConfig(min_samples=1), SLA)
    # populate sizes 1 and 2 with a linear curve lat = 0.05 + 0.01*bs
    for bs in (1, 2, 4):
        for i in range(5):
            mon.record_upstream(bs, 0.05 + 0.01 * bs, now=float(i))
    est8 = mon.upstream_percentile(8, now=10.0)
    assert est8 == pytest.approx(0.05 + 0.01 * 8, rel=0.05)


def test_monitor_extrapolation_floor():
    # A downhill fit (big batches measured cheaper, e.g. during a cold-start
    # storm at bs=8) extrapolated far past the data must floor at half the
    # cheapest observed percentile — never go to zero or negative.
    mon = SmartMonitor(MonitorConfig(min_samples=1), SLA)
    for i in range(5):
        mon.record_upstream(8, 1.0, now=float(i))
        mon.record_upstream(16, 0.5, now=float(i))
    # fit: slope -0.0625, intercept 1.5 → raw estimate at bs=40 is -1.0
    est = mon.upstream_percentile(40, now=10.0)
    assert est == pytest.approx(0.5 * 0.5)  # 0.5 × min observed percentile
    # interpolation between the observed sizes is untouched by the floor
    assert mon.upstream_percentile(12, now=10.0) == pytest.approx(0.75)


def test_monitor_retry_accounting():
    mon = SmartMonitor(MonitorConfig(), SLA)
    mon.record_upstream(2, 0.1, now=0.0)                 # clean
    mon.record_upstream(2, 0.3, now=1.0, attempts=3)     # crash-retried
    assert mon.lifetime_upstream_batches == 2
    assert mon.lifetime_upstream_attempts == 4
    assert mon.lifetime_retried_batches == 1
    assert mon.retry_rate() == pytest.approx(0.5)
    state = mon.snapshot()
    mon2 = SmartMonitor(MonitorConfig(), SLA)
    mon2.restore(state)
    assert mon2.retry_rate() == pytest.approx(0.5)


def test_monitor_optimistic_default_before_any_data():
    mon = SmartMonitor(MonitorConfig(optimistic_default=0.0), SLA)
    assert mon.upstream_percentile(5, now=0.0) == 0.0


def test_monitor_timeout_ratio_and_reset():
    mon = SmartMonitor(MonitorConfig(), SLA)
    mon.record_dispatch(2, "timeout")
    mon.record_dispatch(4, "full")
    mon.record_dispatch(4, "full")
    assert mon.timeout_ratio() == pytest.approx(1 / 3)
    mon.reset_interval()
    assert mon.timeout_ratio() == 0.0


def test_monitor_violation_accounting():
    mon = SmartMonitor(MonitorConfig(), SLA)
    mon.record_e2e(0.4, now=0.0)   # ok
    mon.record_e2e(0.6, now=0.0)   # violation (slo=0.5)
    assert mon.violation_rate() == pytest.approx(0.5)


def test_monitor_snapshot_restore_roundtrip():
    mon = SmartMonitor(MonitorConfig(estimator="p2"), SLA)
    for i in range(20):
        mon.record_upstream(2, 0.1 + 0.01 * (i % 5), now=float(i))
        mon.record_e2e(0.2, now=float(i))
    mon.record_dispatch(2, "timeout")
    state = mon.snapshot()
    mon2 = SmartMonitor(MonitorConfig(estimator="p2"), SLA)
    mon2.restore(state)
    assert mon2.upstream_percentile(2, now=20.0) == mon.upstream_percentile(2, now=20.0)
    assert mon2.timeout_ratio() == mon.timeout_ratio()
    assert mon2.violation_rate() == mon.violation_rate()


def test_p2_estimator_backend():
    mon = SmartMonitor(MonitorConfig(estimator="p2", min_samples=5), SLA)
    rng = random.Random(1)
    xs = [0.1 + 0.02 * rng.random() for _ in range(500)]
    for i, x in enumerate(xs):
        mon.record_upstream(3, x, now=float(i))
    emp = sorted(xs)[int(0.95 * len(xs))]
    assert mon.upstream_percentile(3, now=600.0) == pytest.approx(emp, rel=0.1)


# --------------------------------------------------- sorted-cache coherence
def _reference_percentile(pairs, q, now, horizon, outlier_mult=0.0):
    """The pre-cache implementation: evict by horizon, full sort per call."""
    vals = sorted(v for (t, v) in pairs if t >= now - horizon)
    if not vals:
        return None
    if outlier_mult > 0 and len(vals) >= 4:
        med = vals[len(vals) // 2]
        kept = [v for v in vals if v <= outlier_mult * med]
        if kept:
            vals = kept
    rank = min(len(vals) - 1, max(0, math.ceil(q / 100.0 * len(vals)) - 1))
    return vals[rank]


def test_latency_window_cache_matches_reference_under_churn():
    # interleaved add / horizon-evict / maxlen-evict / winsorized queries
    # must agree with a from-scratch sort every time
    rng = random.Random(7)
    w = LatencyWindow(maxlen=16, horizon=5.0)
    pairs = []
    t = 0.0
    for _ in range(3000):
        t += rng.random() * 0.8
        v = rng.random() * (10.0 if rng.random() < 0.1 else 1.0)
        w.add(t, v)
        pairs.append((t, v))
        pairs = pairs[-16:]  # mirror maxlen
        q = rng.choice([50.0, 90.0, 95.0, 99.0])
        mult = rng.choice([0.0, 3.0, 5.0])
        assert w.percentile(q, now=t, outlier_mult=mult) == \
            _reference_percentile(pairs, q, t, 5.0, mult)


def test_latency_window_cache_survives_maxlen_eviction():
    w = LatencyWindow(maxlen=4, horizon=1e9)
    for i in range(4):
        w.add(float(i), float(i))
    assert w.percentile(100) == 3.0  # builds the cache
    w.add(4.0, 10.0)  # deque evicts value 0.0; cache must drop it too
    assert w.percentile(1) == 1.0
    assert w.percentile(100) == 10.0
    assert sorted(w.values()) == [1.0, 2.0, 3.0, 10.0]


def test_latency_window_count_evicts_like_values():
    w = LatencyWindow(maxlen=100, horizon=10.0)
    w.add(0.0, 1.0)
    w.add(5.0, 2.0)
    w.add(20.0, 3.0)
    assert w.count(21.0) == 1
    assert len(w) == 1
