"""Fault-tolerant live runtime (ISSUE 8): chaos injection, retries,
circuit breaking, and brownout shedding.

Covers the acceptance points: deterministic FaultyTarget injection for
all five fault kinds (byte-identical fault schedule / retry log /
summary under the same seed + FakeClock), deadline-aware proxy-tier
retries (backoff never scheduled past the batch deadline; leftover
budget resolves ``timed_out``, not ``failed``), the circuit-breaker
state machine (closed→open→half-open with a single probe slot),
brownout shedding at admission and on the open transition (lowest slack
first, the distinct ``shed`` ledger class), the no-fault byte-identity
guarantee of the retry layer, and the ``drain(timeout=)`` regressions —
parked backoff sleepers and breaker-gate waiters are cancelled and
resolved through the existing DrainTimeout path.
"""
import asyncio

import numpy as np
import pytest

from experiments.scenarios import LIVE_SCENARIOS, run_live_scenario
from repro.core import SLAConfig, ms
from repro.core.batch_queue import BatchQueue
from repro.core.request import Batch, Request
from repro.runtime import (AsyncProxyServer, BreakerConfig, BrownoutShed,
                           CircuitBreaker, CrashFault, DrainTimeout,
                           FakeClock, FaultConfig, FaultyTarget,
                           PartialBatchFault, PreemptedFault, RuntimeConfig,
                           SyntheticTarget, TargetError, UpstreamTimeout,
                           fault_rng, run)
from repro.runtime.breaker import CLOSED, HALF_OPEN, OPEN
from repro.serverless.latency import AffineLatency

SLA = SLAConfig(slo_target=ms(500))
#: deterministic 50 ms upstream, so fault timing asserts are exact
DET = AffineLatency(a=0.05, c=0.0, noise_cv=0.0)


def one_batch(n=1, t=0.0, deadline=None):
    return Batch(requests=[Request(arrival_time=t, deadline=deadline)
                           for _ in range(n)],
                 dispatch_time=t, cause="full")


class FlakyTarget(SyntheticTarget):
    """Fails the first ``fail_first`` dispatch attempts, then succeeds."""

    def __init__(self, *args, fail_first=2, fail_delay=0.0, **kw):
        super().__init__(*args, **kw)
        self.fail_first = fail_first
        self.fail_delay = fail_delay
        self.attempts_seen = 0

    async def __call__(self, batch, deadline=None):
        self.attempts_seen += 1
        if self.attempts_seen <= self.fail_first:
            if self.fail_delay > 0:
                await self.clock.sleep(self.fail_delay)
            raise RuntimeError(f"flaky attempt {self.attempts_seen}")
        return await super().__call__(batch, deadline=deadline)


class _PoisonRng:
    """Sentinel RNG that fails the test if the fault stream is touched."""

    def random(self):
        raise AssertionError("fault RNG touched on a zero-fault config")


# ---------------------------------------------------------- FaultyTarget
class TestFaultyTarget:
    def _pair(self, clock, cfg):
        inner = SyntheticTarget(DET, clock, rng=np.random.default_rng(1))
        return inner, FaultyTarget(inner, clock, cfg)

    def test_crash_surfaces_after_latency_inner_untouched(self):
        clock = FakeClock()
        inner, target = self._pair(
            clock, FaultConfig(crash_prob=1.0, crash_latency=0.25))

        async def main():
            with pytest.raises(CrashFault):
                await target(one_batch())

        run(clock, main())
        assert inner.started == 0
        assert clock.now() == 0.25
        assert target.injected["crash"] == 1
        assert target.fault_log == [(0, 0.0, "crash")]

    def test_timeout_burns_stall_budget(self):
        clock = FakeClock()
        inner, target = self._pair(
            clock, FaultConfig(timeout_prob=1.0, timeout_stall=0.5))

        async def main():
            with pytest.raises(UpstreamTimeout):
                await target(one_batch())

        run(clock, main())
        assert inner.started == 0
        assert clock.now() == 0.5

    def test_straggler_delays_then_completes_normally(self):
        clock = FakeClock()
        inner, target = self._pair(
            clock, FaultConfig(straggler_prob=1.0, straggler_delay=0.4))

        async def main():
            await target(one_batch())

        run(clock, main())
        assert inner.batches == 1
        assert clock.now() == pytest.approx(0.45)  # 0.4 extra + 50ms work

    def test_partial_runs_inner_to_completion_then_fails(self):
        clock = FakeClock()
        inner, target = self._pair(clock, FaultConfig(partial_prob=1.0))

        async def main():
            with pytest.raises(PartialBatchFault):
                await target(one_batch(n=4))

        run(clock, main())
        assert inner.batches == 1  # the work WAS done; results discarded
        assert clock.now() > 0.0

    def test_preempt_cancels_slow_inner(self):
        clock = FakeClock()
        inner, target = self._pair(
            clock, FaultConfig(preempt_prob=1.0, preempt_after=0.01))

        async def main():
            with pytest.raises(PreemptedFault):
                await target(one_batch())

        run(clock, main())
        assert inner.started == 1 and inner.batches == 0  # begun, reclaimed
        assert clock.now() == pytest.approx(0.01)

    def test_preempt_timer_loses_to_fast_inner(self):
        clock = FakeClock()
        fast = SyntheticTarget(AffineLatency(a=0.001, c=0.0, noise_cv=0.0),
                               clock, rng=np.random.default_rng(1))
        target = FaultyTarget(
            fast, clock, FaultConfig(preempt_prob=1.0, preempt_after=0.05))

        async def main():
            await target(one_batch())

        run(clock, main())
        assert fast.batches == 1
        assert target.injected["preempt"] == 1  # drawn, but the work won

    def test_mirrors_inner_shape_contract(self):
        clock = FakeClock()
        inner = SyntheticTarget(DET, clock, rng=np.random.default_rng(0),
                                batch_buckets=(4, 8, 16))
        target = FaultyTarget(inner, clock, FaultConfig())
        assert target.max_batch == inner.max_batch
        assert target.batch_buckets == (4, 8, 16)

    def test_zero_fault_config_never_touches_rng(self):
        clock = FakeClock()
        inner = SyntheticTarget(DET, clock, rng=np.random.default_rng(1))
        target = FaultyTarget(inner, clock, FaultConfig(),
                              rng=_PoisonRng())

        async def main():
            await target(one_batch())

        run(clock, main())
        assert target.fault_log == [(0, 0.0, "ok")]

    def test_probabilities_must_sum_to_at_most_one(self):
        with pytest.raises(ValueError, match="sum"):
            FaultConfig(crash_prob=0.7, timeout_prob=0.4)
        with pytest.raises(ValueError, match=">= 0"):
            FaultConfig(crash_prob=0.1, crash_latency=-1.0)

    def test_same_seed_same_fault_schedule(self):
        cfg = FaultConfig(crash_prob=0.2, timeout_prob=0.1,
                          straggler_prob=0.1, partial_prob=0.1,
                          preempt_prob=0.1, seed=7)
        clock = FakeClock()
        a = FaultyTarget(SyntheticTarget(DET, clock), clock, cfg)
        b = FaultyTarget(SyntheticTarget(DET, clock), clock, cfg)
        kinds_a = [a._draw() for _ in range(200)]
        kinds_b = [b._draw() for _ in range(200)]
        assert kinds_a == kinds_b
        assert len(set(kinds_a)) == 6  # all five kinds + "ok" appear

    def test_fault_stream_is_third_seed_sequence_child(self):
        streams = np.random.SeedSequence(3).spawn(3)
        expect = np.random.default_rng(streams[2]).random(8)
        np.testing.assert_array_equal(fault_rng(3).random(8), expect)


# --------------------------------------------------------------- retries
class TestRetries:
    def _server(self, clock, target, config, sla=SLA):
        server = AsyncProxyServer(clock=clock, config=config)
        server.add_endpoint("ep", sla=sla, target=target,
                            policy="passthrough")
        return server

    def test_flaky_target_recovers_within_budget(self):
        clock = FakeClock()
        target = FlakyTarget(DET, clock, rng=np.random.default_rng(0),
                             fail_first=2)
        server = self._server(
            clock, target,
            RuntimeConfig(max_retries=4, retry_backoff=0.02,
                          retry_jitter=0.0))

        async def main():
            await server.start()
            ticket = server.submit(endpoint="ep")
            await ticket.future
            await server.drain()
            return ticket

        ticket = run(clock, main())
        assert ticket.error is None and not ticket.timed_out
        c = server.conservation()
        assert c["completed"] == 1 and c["failed"] == 0
        assert c["retried_batches"] == 1
        assert c["faulted_batches"] == 1 and c["recovered_batches"] == 1
        assert len(server.retry_log) == 2
        # failed attempts feed the monitor's retry stats, not its latency
        stats = server.frontend.endpoint("ep").policy.stats(clock.now())
        assert stats["failed_attempts"] == 2
        assert 0.0 < stats["failure_rate"] < 1.0

    def test_exhausted_budget_resolves_target_error(self):
        clock = FakeClock()
        target = FlakyTarget(DET, clock, rng=np.random.default_rng(0),
                             fail_first=10**9)
        server = self._server(
            clock, target,
            RuntimeConfig(max_retries=2, retry_backoff=0.02,
                          retry_jitter=0.0))

        async def main():
            await server.start()
            ticket = server.submit(endpoint="ep")
            with pytest.raises(TargetError) as err:
                await ticket.future
            await server.drain()
            return err.value

        err = run(clock, main())
        assert err.attempts == 3  # first try + 2 retries
        assert isinstance(err.__cause__, RuntimeError)
        c = server.conservation()
        assert c["failed"] == 1 and c["target_failures"] == 1
        assert c["retry_exhausted"] == 1 and c["lost"] == 0

    def test_backoff_never_scheduled_past_deadline(self):
        """Leftover deadline budget < backoff → ``timed_out``, not failed."""
        clock = FakeClock()
        target = FlakyTarget(DET, clock, rng=np.random.default_rng(0),
                             fail_first=10**9)
        server = self._server(
            clock, target,
            RuntimeConfig(max_retries=5, retry_backoff=0.2,
                          retry_jitter=0.0),
            sla=SLAConfig(slo_target=ms(100), deadline_factor=1.0))

        async def main():
            await server.start()
            ticket = server.submit(endpoint="ep")
            await ticket.future
            await server.drain()
            return ticket

        ticket = run(clock, main())
        assert ticket.timed_out and not ticket.rejected
        c = server.conservation()
        assert c["timed_out"] == 1 and c["failed"] == 0
        assert c["retry_exhausted"] == 0  # deadline won, not the budget
        assert server.retry_log == []  # the retry was never scheduled

    def test_backoff_growth_is_capped(self):
        clock = FakeClock()
        server = AsyncProxyServer(
            clock=clock,
            config=RuntimeConfig(max_retries=4, retry_backoff=0.05,
                                 retry_backoff_cap=0.2, retry_jitter=0.0))
        assert [server._backoff(k) for k in (1, 2, 3, 4)] == \
            [0.05, 0.1, 0.2, 0.2]

    def test_jitter_stream_untouched_without_failures(self):
        """The no-fault byte-identity guarantee at the unit level: a run
        with the retry layer armed but nothing failing draws zero jitter."""
        clock = FakeClock()
        target = SyntheticTarget(DET, clock, rng=np.random.default_rng(0))
        server = self._server(
            clock, target, RuntimeConfig(max_retries=4, retry_jitter=0.5))
        state_before = server._retry_rng.bit_generator.state

        async def main():
            await server.start()
            tickets = [server.submit(endpoint="ep") for _ in range(5)]
            await asyncio.gather(*(t.future for t in tickets))
            await server.drain()

        run(clock, main())
        assert server.completed == 5
        assert server._retry_rng.bit_generator.state == state_before


# -------------------------------------------------------- circuit breaker
class TestCircuitBreaker:
    CFG = BreakerConfig(window=10, min_samples=5, failure_threshold=0.5,
                        open_duration=1.0)

    def test_opens_only_past_min_samples_and_threshold(self):
        br = CircuitBreaker(self.CFG)
        assert not br.record_failure(0.0)  # rate 1.0 but 1 sample < 5
        assert not br.record_failure(0.1)
        br.record_success(0.2)
        br.record_success(0.3)
        assert br.state(0.3) == CLOSED
        assert br.record_failure(0.4)  # 3/5 = 0.6 >= 0.5, samples ok
        assert br.state(0.4) == OPEN and br.opened == 1

    def test_open_blocks_until_lazy_half_open(self):
        br = CircuitBreaker(self.CFG)
        for t in range(5):
            br.record_failure(float(t))
        assert br.state(4.0) == OPEN
        assert br.blocked_until(4.0) == 5.0  # opened_at 4.0 + 1.0
        assert not br.try_probe(4.5)
        assert br.state(5.0) == HALF_OPEN  # no timer task: lazy promote

    def test_half_open_admits_single_probe(self):
        br = CircuitBreaker(self.CFG)
        for t in range(5):
            br.record_failure(float(t))
        assert br.try_probe(5.0)       # the one probe slot
        assert not br.try_probe(5.0)   # the herd keeps waiting
        br.record_success(5.1)
        assert br.state(5.1) == CLOSED and br.closed == 1
        # the outage's window was cleared: a single fresh failure must
        # not re-trip the recovered endpoint
        assert br.failure_rate() == 0.0
        assert not br.record_failure(5.2)
        assert br.state(5.2) == CLOSED

    def test_probe_failure_reopens_full_interval(self):
        br = CircuitBreaker(self.CFG)
        for t in range(5):
            br.record_failure(float(t))
        assert br.try_probe(5.0)
        assert br.record_failure(5.3)  # probe verdict: still down
        assert br.reopened == 1
        assert br.blocked_until(5.3) == 6.3

    def test_close_after_two_releases_probe_slot_between(self):
        br = CircuitBreaker(BreakerConfig(
            window=10, min_samples=5, failure_threshold=0.5,
            open_duration=1.0, close_after=2))
        for t in range(5):
            br.record_failure(float(t))
        assert br.try_probe(5.0)
        br.record_success(5.1)
        assert br.state(5.1) == HALF_OPEN  # one success of the two
        assert br.try_probe(5.1)           # slot released for probe #2
        br.record_success(5.2)
        assert br.state(5.2) == CLOSED

    def test_config_validation(self):
        with pytest.raises(ValueError, match="min_samples"):
            BreakerConfig(window=4, min_samples=5)
        with pytest.raises(ValueError, match="failure_threshold"):
            BreakerConfig(failure_threshold=0.0)
        with pytest.raises(ValueError, match="probe_interval"):
            BreakerConfig(probe_interval=0.0)


# ------------------------------------------------------ brownout shedding
class TestBrownoutShedding:
    def test_queue_shed_orders_lowest_slack_first(self):
        q = BatchQueue(lambda batch: None)
        reqs = [Request(arrival_time=0.0, deadline=d)
                for d in (5.0, 1.0, 3.0)]
        reqs.append(Request(arrival_time=0.0))  # deadline-free: sheds last
        for r in reqs:
            q.append(r, 0.0)
        victims = q.shed(0.0, keep=2)
        assert [r.deadline for r in victims] == [1.0, 3.0]
        assert q.queue_len == 2 and q.shed_requests == 2
        assert q._min_deadline == 5.0

    def test_open_transition_sheds_and_admission_sheds_while_open(self):
        clock = FakeClock()
        # upstream that stalls 0.2s then dies — long enough for queued
        # arrivals to pile up behind the in-flight batch before the
        # breaker sees the failure
        target = FlakyTarget(DET, clock, rng=np.random.default_rng(0),
                             fail_first=10**9, fail_delay=0.2)
        server = AsyncProxyServer(clock=clock, config=RuntimeConfig(
            max_retries=0,
            breaker=BreakerConfig(window=4, min_samples=1,
                                  failure_threshold=0.5, open_duration=5.0),
            brownout_queue=2,
        ))
        server.add_endpoint(
            "ep", sla=SLAConfig(slo_target=ms(500), deadline_factor=8.0),
            target=target, policy="static",
            policy_kwargs={"batch_size": 10, "timeout": 300.0})

        async def main():
            await server.start()
            inflight = [server.submit(endpoint="ep") for _ in range(10)]
            queued = []
            for _ in range(5):
                await clock.sleep(0.02)  # distinct deadlines => slack order
                queued.append(server.submit(endpoint="ep"))
            await clock.sleep(0.15)  # failure at t=0.2 opens the breaker
            late = server.submit(endpoint="ep")
            for t in inflight:
                with pytest.raises(TargetError):
                    await t.future
            await server.drain(timeout=1.0)
            return queued, late

        queued, late = run(clock, main())
        # open transition shed the queue down to brownout_queue=2,
        # lowest slack (earliest deadline = earliest arrival) first
        assert [t.shed for t in queued] == [True, True, True, False, False]
        assert all(isinstance(t.error, BrownoutShed)
                   for t in queued if t.shed)
        # admission while the breaker is open sheds, not rejects
        assert late.shed and not late.rejected
        c = server.conservation()
        assert c["shed"] == 4 and c["failed"] == 10 and c["lost"] == 0
        # the two survivors were flush-dispatched into an open breaker
        # whose probe instant lies past their deadline → timed_out
        assert c["timed_out"] == 2
        per = server.summary()["endpoints"]["ep"]
        assert per["breaker"]["state"] == OPEN
        assert per["breaker"]["opened"] == 1


# ------------------------------------------------------- drain(timeout=)
class TestDrainCancelsParkedSleepers:
    def test_drain_cancels_backoff_sleeper(self):
        """Satellite regression: a batch parked on a 100s retry backoff
        must not hang ``drain(timeout=)`` — it resolves via DrainTimeout."""
        clock = FakeClock()
        target = FlakyTarget(DET, clock, rng=np.random.default_rng(0),
                             fail_first=10**9)
        server = AsyncProxyServer(clock=clock, config=RuntimeConfig(
            max_retries=3, retry_backoff=100.0, retry_jitter=0.0))
        server.add_endpoint("ep", sla=SLA, target=target,
                            policy="passthrough")

        async def main():
            await server.start()
            ticket = server.submit(endpoint="ep")
            await clock.sleep(0.01)  # first attempt fails; backoff parks
            t0 = clock.now()
            await server.drain(timeout=1.0)
            assert clock.now() == pytest.approx(t0 + 1.0)
            with pytest.raises(DrainTimeout):
                await ticket.future

        run(clock, main())
        c = server.conservation()
        assert c["drain_cancelled"] == 1 and c["failed"] == 1
        assert c["retried_batches"] == 1  # the retry WAS scheduled
        assert c["lost"] == 0

    def test_drain_cancels_breaker_gate_waiter(self):
        """Satellite regression: a batch parked on an open breaker's
        probe instant is cancelled by the drain timeout, not awaited."""
        clock = FakeClock()
        target = FlakyTarget(DET, clock, rng=np.random.default_rng(0),
                             fail_first=10**9, fail_delay=0.01)
        server = AsyncProxyServer(clock=clock, config=RuntimeConfig(
            max_retries=0,
            breaker=BreakerConfig(window=4, min_samples=1,
                                  failure_threshold=0.5,
                                  open_duration=100.0),
            brownout_queue=0,  # no queue brownout: let the batch dispatch
        ))
        server.add_endpoint("ep", sla=SLA, target=target,
                            policy="passthrough")

        async def main():
            await server.start()
            first = server.submit(endpoint="ep")
            await clock.sleep(0.02)  # fails → breaker opens for 100s
            parked = server.submit(endpoint="ep")  # gate-parked dispatch
            await clock.sleep(0.01)
            await server.drain(timeout=1.0)
            with pytest.raises(TargetError):
                await first.future
            with pytest.raises(DrainTimeout):
                await parked.future

        run(clock, main())
        c = server.conservation()
        assert c["drain_cancelled"] == 1 and c["failed"] == 2
        assert c["lost"] == 0


# ------------------------------------------------- scenario determinism
class TestChaosDeterminism:
    @pytest.mark.parametrize("name", sorted(LIVE_SCENARIOS))
    def test_same_seed_byte_identical_run(self, name):
        """Same seed + FakeClock ⇒ identical fault schedule, retry log,
        dispatch log, and summary counters — for every fault kind."""
        a = run_live_scenario(name, "static", quick=True)
        b = run_live_scenario(name, "static", quick=True)
        assert a.fault_log == b.fault_log
        assert len(a.fault_log) > 0
        assert a.retry_log == b.retry_log
        assert a.dispatch_log == b.dispatch_log
        assert a.conservation == b.conservation
        assert a.summary == b.summary
        assert a.conservation["lost"] == 0
        assert a.conservation["duplicate_completions"] == 0

    def test_different_seed_differs(self):
        a = run_live_scenario("live-crash-storm", "static", quick=True)
        b = run_live_scenario("live-crash-storm", "static", quick=True,
                              seed=99)
        assert a.fault_log != b.fault_log

    def test_no_fault_runs_byte_identical_to_bare_runtime(self):
        """Zero-probability wrapper + retry/breaker config ⇒ the exact
        dispatch schedule of the plain pre-fault-tolerance runtime."""
        plain = run_live_scenario("live-crash-storm", "static", faults=False,
                                  quick=True, runtime=RuntimeConfig(),
                                  bare=True)
        base = run_live_scenario("live-crash-storm", "static", faults=False,
                                 quick=True)
        assert base.dispatch_log == plain.dispatch_log
        assert base.retry_log == [] and base.conservation["shed"] == 0
        for key in ("completed", "p50", "p95", "p99", "violation_pct",
                    "timed_out", "rejected", "failed", "throughput"):
            assert base.summary[key] == plain.summary[key], key

    def test_bare_cannot_inject_faults(self):
        with pytest.raises(ValueError, match="bare"):
            run_live_scenario("live-crash-storm", "static", faults=True,
                              quick=True, bare=True)
