"""The parallel sweep runner must be a pure speedup: identical rows, in
identical order, for serial and multi-process execution of the same grid
with the same per-cell seeds."""
import pytest

from benchmarks.sweep import default_grid, run_sweep


GRID = [
    ("crash-storm", "mlproxy", 11),
    ("crash-storm", "passthrough", 11),
    ("straggler-heavy", "mlproxy", 12),
    ("drain-under-load", "static", 13),
]


def test_default_grid_covers_policy_times_scenario():
    from experiments.scenarios import POLICIES, SCENARIOS

    grid = default_grid(seeds=(11, 12))
    assert len(grid) == len(POLICIES) * len(SCENARIOS) * 2
    assert len(set(grid)) == len(grid)


def test_parallel_sweep_matches_serial():
    serial = run_sweep(GRID, quick=True, jobs=1)
    parallel = run_sweep(GRID, quick=True, jobs=2)
    assert serial == parallel


def test_sweep_rows_conserve_work():
    rows = run_sweep(GRID[:2], quick=True, jobs=1)
    for r in rows:
        assert r["lost"] == 0
        assert r["duplicates"] == 0
        assert r["completed"] > 0
