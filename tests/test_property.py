"""Property-based tests (hypothesis) on the system's invariants."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install test extras: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    AIMDBatchOptimizer,
    MonitorConfig,
    OptimizerConfig,
    ProxyConfig,
    Request,
    SLAConfig,
    SmartMonitor,
    bucket_of,
)
from repro.core.monitor import P2Quantile
from repro.core.scheduler import QueueScheduler
from repro.models.moe import expert_capacity
from repro.serverless.latency import AffineLatency, PowerLawLatency
from repro.simulation.events import EventQueue
from repro.simulation.traces import Trace, synthetic_trace


# ------------------------------------------------------------- Algorithm 1
@settings(max_examples=60, deadline=None)
@given(
    slo=st.floats(0.05, 5.0),
    est=st.floats(0.0, 6.0),
    frt=st.floats(0.0, 3.0),
)
def test_timeout_never_exceeds_slo_budget(slo, est, frt):
    """TO = (SLO − RT95) − FRT: the scheduled deadline never allows the
    oldest request to pass SLO − RT95 waiting time."""
    sla = SLAConfig(slo_target=slo)
    cfg = ProxyConfig(sla=sla, monitor=MonitorConfig(min_samples=1))
    mon = SmartMonitor(cfg.monitor, sla)
    for _ in range(3):
        mon.record_upstream(2, est, now=0.0)
    out = []
    sched = QueueScheduler(cfg, mon, dispatch_fn=out.append, max_bs_fn=lambda: 100)
    t0 = 100.0
    sched.on_arrival(Request(arrival_time=t0 - frt), now=t0 - frt)
    sched.on_arrival(Request(arrival_time=t0), now=t0)
    if sched.next_deadline is not None:
        # deadline - oldest_arrival + est <= slo (+ float slack)
        oldest = t0 - frt
        assert sched.next_deadline - oldest + est <= slo + 1e-6
    else:
        # dispatched immediately because budget was already exhausted
        assert out and out[-1].cause in ("timeout", "full")


@settings(max_examples=40, deadline=None)
@given(
    arrivals=st.lists(st.floats(0.001, 0.2), min_size=1, max_size=60),
    max_bs=st.integers(1, 16),
)
def test_scheduler_conserves_requests(arrivals, max_bs):
    """Every arrived request is dispatched exactly once (after flush)."""
    sla = SLAConfig(slo_target=0.5)
    cfg = ProxyConfig(sla=sla, monitor=MonitorConfig(min_samples=1))
    mon = SmartMonitor(cfg.monitor, sla)
    mon.record_upstream(1, 0.1, now=0.0)
    out = []
    sched = QueueScheduler(cfg, mon, dispatch_fn=out.append,
                           max_bs_fn=lambda: max_bs)
    t = 0.0
    for gap in arrivals:
        t += gap
        if sched.next_deadline is not None and sched.next_deadline <= t:
            sched.on_timer(sched.next_deadline)
        sched.on_arrival(Request(arrival_time=t), now=t)
    sched.flush(t + 10)
    ids = [r.req_id for b in out for r in b.requests]
    assert len(ids) == len(arrivals)
    assert len(set(ids)) == len(ids)
    assert all(b.size <= max_bs for b in out)


# ------------------------------------------------------------- Algorithm 2
@settings(max_examples=40, deadline=None)
@given(violations=st.lists(st.booleans(), min_size=1, max_size=60))
def test_aimd_stays_in_bounds(violations):
    sla = SLAConfig(slo_target=1.0)
    mon = SmartMonitor(MonitorConfig(), sla)
    opt = AIMDBatchOptimizer(OptimizerConfig(max_bs_cap=64), sla, mon)
    t = 0.0
    for v in violations:
        if v:
            mon.record_e2e(10.0, now=t)  # force violation
        else:
            mon.reset_interval()
        opt.update(now=t)
        # clear the e2e window effect by advancing beyond the horizon
        t += 1000.0
        assert 1 <= opt.max_bs <= 64
        assert opt.max_bs_raw >= 1.0


# ---------------------------------------------------------------- monitor
@settings(max_examples=30, deadline=None)
@given(xs=st.lists(st.floats(1e-4, 100.0), min_size=1, max_size=300))
def test_window_percentile_bounds(xs):
    sla = SLAConfig(slo_target=1.0)
    mon = SmartMonitor(MonitorConfig(min_samples=1, window_size=512,
                                     window_horizon=1e9), sla)
    for i, x in enumerate(xs):
        mon.record_upstream(3, x, now=float(i))
    est = mon.upstream_percentile(3, now=float(len(xs)))
    tail = xs[-512:]
    assert min(tail) <= est <= max(tail)


@settings(max_examples=30, deadline=None)
@given(xs=st.lists(st.floats(0.001, 10.0), min_size=6, max_size=500))
def test_p2_quantile_within_range(xs):
    est = P2Quantile(0.95)
    for x in xs:
        est.add(x)
    v = est.value()
    assert min(xs) - 1e-9 <= v <= max(xs) + 1e-9


# ----------------------------------------------------------------- buckets
@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 10_000))
def test_pow2_bucket_properties(n):
    b = bucket_of(n, "pow2")
    assert b >= n
    assert b & (b - 1) == 0  # power of two
    assert b < 2 * n  # tight


@settings(max_examples=60, deadline=None)
@given(t=st.integers(1, 100_000), e=st.integers(1, 512),
       k=st.integers(1, 8), cf=st.floats(1.0, 4.0))
def test_expert_capacity_properties(t, e, k, cf):
    cap = expert_capacity(t, e, k, cf)
    assert cap % 8 == 0
    assert cap * e >= min(t * k, int(t * k * cf))  # enough slots in total


# ----------------------------------------------------------------- latency
@settings(max_examples=40, deadline=None)
@given(a=st.floats(0.001, 1.0), c=st.floats(0.0001, 0.1),
       b1=st.integers(1, 64), b2=st.integers(1, 64))
def test_affine_latency_monotone_and_subadditive(a, c, b1, b2):
    m = AffineLatency(a=a, c=c, noise_cv=0.0)
    lo, hi = min(b1, b2), max(b1, b2)
    assert m.mean(lo) <= m.mean(hi)
    # batching two groups together is never slower than serial execution
    assert m.mean(b1 + b2) <= m.mean(b1) + m.mean(b2)


# ------------------------------------------------------------------ events
@settings(max_examples=30, deadline=None)
@given(times=st.lists(st.floats(0.0, 100.0), min_size=1, max_size=100))
def test_event_queue_orders_by_time(times):
    q = EventQueue()
    fired = []
    for t in times:
        q.push(t, lambda now, t=t: fired.append(now))
    while q:
        t, fn = q.pop()
        fn(t)
    assert fired == sorted(fired)


# ------------------------------------------------------------------ traces
@settings(max_examples=20, deadline=None)
@given(max_rps=st.floats(0.1, 500.0),
       kind=st.sampled_from(["wc", "t4", "t5", "constant"]))
def test_trace_scaling_invariants(max_rps, kind):
    tr = synthetic_trace(kind, duration=100.0, seed=1).scaled(max_rps)
    assert math.isclose(tr.max_rate, max_rps, rel_tol=1e-9)
    assert tr.rates.min() >= 0
    # rate_at within [0, max]
    for t in (0.0, 10.0, 50.0, 99.9):
        assert 0 <= tr.rate_at(t) <= max_rps + 1e-9
