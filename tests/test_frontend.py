"""Tests for the three refactored control-plane layers.

* Queue layer — every policy (MLProxy + four baselines) conforms to the
  :class:`~repro.core.batch_queue.Policy` protocol, dispatches through the
  one shared :class:`~repro.core.batch_queue.BatchQueue`, and survives a
  snapshot/restore round-trip.
* Routing layer — :class:`~repro.core.frontend.ProxyFrontend` routes by
  endpoint key, stamps batches, merges timers, and two endpoints with
  different SLOs converge to different ``max_bs``.
* Scenario layer — :class:`MultiEndpointSimulator` runs N endpoints with
  per-endpoint arrivals over dedicated or shared platforms.
"""
import pytest

from repro.core import (
    BatchQueue,
    MLProxy,
    MonitorConfig,
    OptimizerConfig,
    Policy,
    ProxyFrontend,
    Request,
    SLAConfig,
)
from repro.core.policies import make_policy
from repro.serverless.latency import EndpointRoutedLatency, get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import PoissonProcess
from repro.simulation.simulator import (EndpointSpec, MultiEndpointSimulator,
                                        run_multi_simulation)

SLA = SLAConfig(slo_target=1.0)

POLICY_SPECS = [
    ("mlproxy", {"monitor": MonitorConfig(min_samples=1)}),
    ("passthrough", {}),
    ("static", {"batch_size": 4, "timeout": 0.2}),
    ("clipper", {}),
    # step model leaves real timeout slack (0.9 − 0.3) after picking bs=4
    ("oracle", {"latency_model": lambda bs: 0.3 if bs <= 4 else 10.0}),
]


def _make(name, kwargs, sink):
    return make_policy(name, SLA, sink.append, **kwargs)


# ------------------------------------------------------------ protocol layer
@pytest.mark.parametrize("name,kwargs", POLICY_SPECS, ids=[p[0] for p in POLICY_SPECS])
def test_policy_protocol_conformance(name, kwargs):
    pol = _make(name, kwargs, [])
    assert isinstance(pol, Policy)
    assert isinstance(pol.max_bs, int)


@pytest.mark.parametrize("name,kwargs", POLICY_SPECS, ids=[p[0] for p in POLICY_SPECS])
def test_policy_dispatch_causes_through_shared_queue(name, kwargs):
    """Every policy dispatches via BatchQueue: full-batch, timeout, flush."""
    out = []
    pol = _make(name, kwargs, out)

    # cause="full": saturate the current target batch size in one instant
    bs = max(1, pol.max_bs)
    for _ in range(bs):
        pol.on_request(Request(arrival_time=0.0), now=0.0)
    assert out and out[0].cause in ("full", "timeout")
    assert out[0].size == bs

    # cause="timeout"/"flush": a lone request must eventually leave
    out.clear()
    pol.on_request(Request(arrival_time=10.0), now=10.0)
    if not out:  # not dispatched synchronously → a deadline must exist
        t = pol.next_event_time(10.0)
        assert t is not None and t >= 10.0
        pol.on_timer(t)
    if not out:  # e.g. clipper's AIMD tick fired first — flush drains it
        pol.flush(now=50.0)
        assert out and out[-1].cause == "flush"
    total = sum(b.size for b in out)
    assert total == 1


@pytest.mark.parametrize("name,kwargs", POLICY_SPECS, ids=[p[0] for p in POLICY_SPECS])
def test_policy_snapshot_restore_roundtrip(name, kwargs):
    """Queued requests and counters survive restore into a fresh policy."""
    out = []
    pol = _make(name, kwargs, out)
    # complete one batch so monitors/counters hold state
    pol.on_request(Request(arrival_time=0.0), now=0.0)
    pol.flush(now=0.1)
    assert out
    pol.on_response(out[0], upstream_latency=0.05, now=0.2)
    # leave one request queued across the snapshot (passthrough never queues)
    queued_before = 0
    if pol.max_bs > 1:
        pol.on_request(Request(arrival_time=1.0), now=1.0)
        queued_before = pol.stats(1.0)["queue_len"]
    state = pol.snapshot()

    out2 = []
    pol2 = _make(name, kwargs, out2)
    pol2.restore(state)
    s1, s2 = pol.stats(1.0), pol2.stats(1.0)
    assert s2["dispatched_batches"] == s1["dispatched_batches"]
    assert s2["dispatched_requests"] == s1["dispatched_requests"]
    assert s2["queue_len"] == queued_before
    assert pol2.max_bs == pol.max_bs
    # the restored queue drains through the restored policy's dispatcher
    pol2.flush(now=2.0)
    assert sum(b.size for b in out2) == queued_before


def test_batch_queue_is_the_single_dispatcher():
    q = BatchQueue(dispatch_fn=(out := []).append)
    q.append(Request(arrival_time=0.0), now=0.0)
    q.append(Request(arrival_time=0.3), now=0.3)
    assert q.first_arrival == 0.0
    assert q.frt(1.0) == pytest.approx(1.0)
    batch = q._dispatch(1.0, "flush")
    assert batch.size == 2 and out == [batch]
    assert q.queue_len == 0 and q.first_arrival is None
    assert (q.dispatched_batches, q.dispatched_requests) == (1, 2)
    assert q.avg_batch_size == pytest.approx(2.0)


def test_static_policy_timeout_anchors_on_first_arrival_at_t0():
    """first_arrival == 0.0 is falsy; the deadline must still anchor there
    instead of re-anchoring on every later arrival (which would starve the
    oldest request under a steady trickle)."""
    out = []
    pol = make_policy("static", SLA, out.append, batch_size=8, timeout=0.1)
    pol.on_request(Request(arrival_time=0.0), now=0.0)
    assert pol.next_deadline == pytest.approx(0.1)
    pol.on_request(Request(arrival_time=0.05), now=0.05)
    assert pol.next_deadline == pytest.approx(0.1)  # not 0.15


def test_batching_policy_restores_pre_refactor_snapshot():
    """Checkpoints written before the BatchQueue refactor (flat keys +
    `counts` tuple) still restore — the warm-restart path in launch/serve.py
    loads JSON snapshots from older runs."""
    out = []
    pol = make_policy("static", SLA, out.append, batch_size=8, timeout=0.1)
    legacy = {
        "monitor": pol.monitor.snapshot(),
        "queue": [Request(arrival_time=1.0)],
        "first_arrival": 1.0,
        "next_deadline": 1.1,
        "counts": (3, 12),
    }
    pol.restore(legacy)
    assert pol.dispatched_batches == 3 and pol.dispatched_requests == 12
    assert pol.next_deadline == 1.1
    pol.flush(2.0)
    assert out[-1].size == 1


def test_batch_queue_bucketing():
    q = BatchQueue(dispatch_fn=(out := []).append, bucketing="pow2")
    for i in range(5):
        q.append(Request(arrival_time=0.0), now=0.0)
    q._dispatch(0.0, "full")
    assert out[0].size == 5 and out[0].bucket_size == 8


# ------------------------------------------------------------- routing layer
def _frontend_two_endpoints(sinks):
    # initial Max_BS > 1 so arrivals queue instead of dispatching instantly
    kw = {
        "monitor": MonitorConfig(min_samples=1),
        "optimizer": OptimizerConfig(initial_max_bs=8),
    }
    fe = ProxyFrontend()
    fe.add_endpoint("tight", sla=SLAConfig(slo_target=0.3),
                    dispatch_fn=sinks["tight"].append, policy_kwargs=dict(kw))
    fe.add_endpoint("loose", sla=SLAConfig(slo_target=5.0),
                    dispatch_fn=sinks["loose"].append, policy_kwargs=dict(kw))
    return fe


def test_frontend_routes_and_stamps_batches():
    sinks = {"tight": [], "loose": []}
    fe = _frontend_two_endpoints(sinks)
    fe.on_request(Request(arrival_time=0.0, endpoint="tight"), now=0.0)
    fe.on_request(Request(arrival_time=0.0), now=0.0, endpoint="loose")
    fe.flush(now=0.1)
    assert len(sinks["tight"]) == 1 and len(sinks["loose"]) == 1
    assert sinks["tight"][0].endpoint == "tight"
    assert sinks["loose"][0].endpoint == "loose"
    # responses route back by the batch stamp
    fe.on_response(sinks["tight"][0], upstream_latency=0.05, now=0.2)
    stats = fe.stats(0.2)
    assert stats["endpoints"]["tight"]["dispatched_requests"] == 1
    assert stats["aggregate"]["dispatched_requests"] == 2


def test_frontend_rejects_unroutable_requests():
    fe = _frontend_two_endpoints({"tight": [], "loose": []})
    with pytest.raises(KeyError):
        fe.on_request(Request(arrival_time=0.0), now=0.0)  # ambiguous
    with pytest.raises(KeyError):
        fe.on_request(Request(arrival_time=0.0, endpoint="nope"), now=0.0)
    with pytest.raises(ValueError):
        fe.add_endpoint("tight", sla=SLA, dispatch_fn=lambda b: None)


def test_frontend_merges_timers_across_endpoints():
    sinks = {"tight": [], "loose": []}
    fe = _frontend_two_endpoints(sinks)
    fe.on_request(Request(arrival_time=0.0, endpoint="tight"), now=0.0)
    fe.on_request(Request(arrival_time=0.0, endpoint="loose"), now=0.0)
    t_tight = fe.endpoint("tight").policy.next_event_time(0.0)
    t_loose = fe.endpoint("loose").policy.next_event_time(0.0)
    assert fe.next_event_time(0.0) == min(t_tight, t_loose) == t_tight
    # firing the merged timer dispatches only the due endpoint
    fe.on_timer(t_tight)
    assert len(sinks["tight"]) == 1 and len(sinks["loose"]) == 0


def test_frontend_endpoints_converge_to_different_max_bs():
    """Two SLO classes behind one frontend: the loose endpoint's AIMD grows
    Max_BS while the tight endpoint (upstream barely fits its SLO) stays
    pinned at 1 — per-endpoint SLA awareness through a single proxy."""
    sinks = {"tight": [], "loose": []}
    fe = _frontend_two_endpoints(sinks)
    lat = {"tight": 0.28, "loose": 0.05}  # tight: > 0.8 × 0.3 compliance cut
    for k in range(12):
        t = 30.0 * k
        for name in ("tight", "loose"):
            fe.on_request(Request(arrival_time=t, endpoint=name), now=t)
        fe.flush(t + 0.01)
        for name in ("tight", "loose"):
            fe.on_response(sinks[name][-1], upstream_latency=lat[name],
                           now=t + 0.01 + lat[name])
        fe.on_timer(t + 29.0)  # AIMD interval tick (30 s default)
    stats = fe.stats(360.0)["endpoints"]
    assert stats["tight"]["max_bs"] == 1
    assert stats["loose"]["max_bs"] >= 5
    assert stats["loose"]["max_bs"] > stats["tight"]["max_bs"]


def test_frontend_snapshot_restore_roundtrip():
    sinks = {"tight": [], "loose": []}
    fe = _frontend_two_endpoints(sinks)
    fe.on_request(Request(arrival_time=0.0, endpoint="loose"), now=0.0)
    fe.flush(0.1)
    fe.on_response(sinks["loose"][0], upstream_latency=0.05, now=0.2)
    state = fe.snapshot()
    fe2 = _frontend_two_endpoints({"tight": [], "loose": []})
    fe2.restore(state)
    assert (fe2.stats(0.2)["endpoints"]["loose"]["dispatched_requests"]
            == fe.stats(0.2)["endpoints"]["loose"]["dispatched_requests"])


# ------------------------------------------------------------ scenario layer
def _two_endpoint_specs(shared):
    return {
        "iris": EndpointSpec(
            policy="mlproxy", sla=SLAConfig(slo_target=0.2),
            workload=get_workload("sklearn-iris"),
            arrivals=PoissonProcess(rate=40.0, duration=240.0),
            platform="fleet" if shared else None,
            platform_config=PlatformConfig(initial_scale=1),
        ),
        "resnet": EndpointSpec(
            policy="mlproxy", sla=SLAConfig(slo_target=1.5),
            workload=get_workload("tfserving-resnet"),
            arrivals=PoissonProcess(rate=8.0, duration=240.0),
            platform="fleet" if shared else None,
            platform_config=PlatformConfig(initial_scale=1),
        ),
    }


def test_multi_sim_dedicated_platforms():
    res = run_multi_simulation(_two_endpoint_specs(shared=False),
                               duration=240.0, warmup=60.0, seed=2)
    assert res.summary["n_platforms"] == 2.0
    assert set(res.endpoints) == {"iris", "resnet"}
    for name, s in res.endpoints.items():
        assert s["completed"] > 100, name
        assert s["violation_pct"] < 10.0, name
    # each class is judged against its OWN SLO
    assert res.endpoints["iris"]["slo_target"] == 0.2
    assert res.endpoints["resnet"]["slo_target"] == 1.5
    assert res.summary["avg_containers"] > 0


def test_multi_sim_shared_platform_routes_latency_per_endpoint():
    res = run_multi_simulation(_two_endpoint_specs(shared=True),
                               duration=240.0, warmup=60.0, seed=2)
    assert res.summary["n_platforms"] == 1.0
    for name, s in res.endpoints.items():
        assert s["completed"] > 100, name
    # the small model must still be far faster than the big one — i.e. the
    # shared fleet sampled each endpoint's own latency model
    assert res.endpoints["iris"]["p50"] < res.endpoints["resnet"]["p50"]


def test_multi_sim_deterministic_given_seed():
    a = run_multi_simulation(_two_endpoint_specs(False), duration=120.0, seed=5)
    b = run_multi_simulation(_two_endpoint_specs(False), duration=120.0, seed=5)
    assert a.summary == b.summary
    assert a.endpoints == b.endpoints


def test_multi_sim_surfaces_per_endpoint_retry_rate():
    """Per-endpoint retry accounting reaches both the frontend stats and
    the multi-sim endpoint summaries (PR 2 plumbed only the aggregate)."""
    specs = _two_endpoint_specs(shared=False)
    # crash-prone fleet for iris only: its retries must show up under
    # "iris" without leaking into "resnet"
    specs["iris"] = EndpointSpec(
        policy="mlproxy", sla=SLAConfig(slo_target=0.5),
        workload=get_workload("sklearn-iris"),
        arrivals=PoissonProcess(rate=40.0, duration=240.0),
        platform_config=PlatformConfig(
            initial_scale=2, failure_prob_per_batch=0.05),
    )
    sim = MultiEndpointSimulator(specs, duration=240.0, seed=3)
    res = sim.run()
    for name, s in res.endpoints.items():
        assert {"retry_rate", "retried_batches", "upstream_batches"} <= set(s)
    assert res.endpoints["iris"]["retried_batches"] > 0
    assert 0.0 < res.endpoints["iris"]["retry_rate"] < 1.0
    assert res.endpoints["resnet"]["retried_batches"] == 0.0

    # the frontend's own per-endpoint stats carry the same numbers, and
    # the aggregate is their batch-weighted combination
    fstats = sim.frontend.stats(sim.now)
    for name in specs:
        ep = fstats["endpoints"][name]
        assert ep["retry_rate"] == res.endpoints[name]["retry_rate"]
    agg = fstats["aggregate"]
    total_up = sum(fstats["endpoints"][n]["upstream_batches"] for n in specs)
    total_re = sum(fstats["endpoints"][n]["retried_batches"] for n in specs)
    assert agg["retried_batches"] == total_re
    assert agg["retry_rate"] == pytest.approx(total_re / total_up)


def test_routed_latency_requires_endpoint_stamp():
    from repro.core.request import Batch
    routed = EndpointRoutedLatency({"a": get_workload("sklearn-iris")})
    b = Batch(requests=[Request(arrival_time=0.0)], dispatch_time=0.0,
              cause="full")
    with pytest.raises(KeyError):
        routed.mean_batch(b)
    b.endpoint = "a"
    assert routed.mean_batch(b) > 0
