"""Tests for the serverless platform model, arrivals, traces and simulator."""
import numpy as np
import pytest

from repro.core import SLAConfig
from repro.core.request import Batch, Request
from repro.serverless.latency import (
    AffineLatency,
    LinearLatency,
    MeasuredLatency,
    PowerLawLatency,
    get_workload,
)
from repro.serverless.platform import PlatformConfig, ServerlessPlatform
from repro.simulation.arrivals import (
    DeterministicProcess,
    MMPP2,
    PoissonProcess,
    TraceModulatedPoisson,
)
from repro.simulation.events import EventQueue
from repro.simulation.simulator import run_simulation
from repro.simulation.traces import Trace, synthetic_trace


# ------------------------------------------------------------------ latency
def test_affine_latency_sublinear_per_inference():
    m = AffineLatency(a=0.1, c=0.01, noise_cv=0.0)
    per1 = m.mean(1) / 1
    per16 = m.mean(16) / 16
    assert per16 < per1  # batching reduces time-per-inference


def test_linear_latency_no_benefit():
    m = LinearLatency(base=0.05, noise_cv=0.0)
    assert m.mean(8) / 8 == pytest.approx(m.mean(1))


def test_powerlaw_latency():
    m = PowerLawLatency(base=0.1, gamma=0.5, noise_cv=0.0)
    assert m.mean(4) == pytest.approx(0.2)


def test_measured_latency_interpolates_and_extrapolates():
    m = MeasuredLatency(points=[(1, 0.1), (4, 0.16), (8, 0.24)], noise_cv=0.0)
    assert m.mean(1) == pytest.approx(0.1)
    assert m.mean(2) == pytest.approx(0.12)
    assert m.mean(16) == pytest.approx(0.24 + 0.02 * 8)
    assert m.mean(0) == pytest.approx(0.1)


def test_latency_noise_is_unbiased():
    m = AffineLatency(a=0.1, c=0.0, noise_cv=0.3)
    rng = np.random.default_rng(0)
    xs = [m.sample(1, rng) for _ in range(20000)]
    assert np.mean(xs) == pytest.approx(0.1, rel=0.02)


def test_latency_percentile_analytic():
    m = AffineLatency(a=0.1, c=0.0, noise_cv=0.2)
    rng = np.random.default_rng(0)
    xs = sorted(m.sample(1, rng) for _ in range(20000))
    emp95 = xs[int(0.95 * len(xs))]
    assert m.percentile(1, 95) == pytest.approx(emp95, rel=0.03)


def test_paper_workloads_brt_matches_table2():
    # s(1) must equal Table 2's baseline response time (±15%)
    for name, brt_ms in [
        ("sklearn-iris", 8), ("keras-toxic", 40), ("onnx-resnet50", 201),
        ("pytorch-fashion-mnist", 125), ("tfserving-mobilenet", 83),
        ("tfserving-resnet", 204),
    ]:
        assert get_workload(name).mean(1) == pytest.approx(brt_ms / 1000, rel=0.15)


# ------------------------------------------------------------------- traces
def test_trace_rate_lookup_and_scaling():
    tr = Trace(times=np.array([0.0, 10.0, 20.0]), rates=np.array([1.0, 3.0]))
    assert tr.rate_at(5.0) == 1.0
    assert tr.rate_at(15.0) == 3.0
    assert tr.rate_at(25.0) == 0.0
    sc = tr.scaled(30.0)
    assert sc.max_rate == 30.0
    assert sc.rate_at(5.0) == 10.0


def test_synthetic_traces_shapes():
    for kind in ("wc", "t4", "t5", "constant"):
        tr = synthetic_trace(kind, duration=100.0, n_bins=50, seed=1)
        assert tr.duration == pytest.approx(100.0)
        assert tr.max_rate == pytest.approx(1.0)
        assert tr.rates.min() >= 0.0
    # WC must be peakier than T4 (sharp event spikes)
    wc = synthetic_trace("wc", seed=1)
    t4 = synthetic_trace("t4", seed=1)
    assert wc.rates.mean() < t4.rates.mean()


def test_trace_csv_roundtrip(tmp_path):
    tr = synthetic_trace("wc", duration=60.0, n_bins=30)
    p = tmp_path / "trace.csv"
    tr.to_csv(str(p))
    tr2 = Trace.from_csv(str(p))
    np.testing.assert_allclose(tr2.rates, tr.rates, rtol=1e-5)
    np.testing.assert_allclose(tr2.times, tr.times, atol=1e-5)


def test_trace_stretch():
    tr = synthetic_trace("t5", duration=100.0)
    st = tr.stretched(400.0)
    assert st.duration == pytest.approx(400.0)
    assert st.max_rate == tr.max_rate


# ----------------------------------------------------------------- arrivals
def test_poisson_rate():
    rng = np.random.default_rng(0)
    p = PoissonProcess(rate=50.0, duration=200.0)
    t, n = 0.0, 0
    while True:
        t2 = p.next_arrival(t, rng)
        if t2 is None:
            break
        t, n = t2, n + 1
    assert n == pytest.approx(50.0 * 200.0, rel=0.05)


def test_trace_modulated_poisson_follows_trace():
    tr = Trace(times=np.array([0.0, 100.0, 200.0]), rates=np.array([5.0, 50.0]))
    rng = np.random.default_rng(0)
    p = TraceModulatedPoisson(tr)
    t, lo, hi = 0.0, 0, 0
    while True:
        t2 = p.next_arrival(t, rng)
        if t2 is None:
            break
        if t2 < 100:
            lo += 1
        else:
            hi += 1
        t = t2
    assert lo == pytest.approx(500, rel=0.2)
    assert hi == pytest.approx(5000, rel=0.1)


def test_mmpp_switches_states():
    rng = np.random.default_rng(0)
    p = MMPP2(rate_lo=1.0, rate_hi=100.0, mean_lo=10.0, mean_hi=10.0, duration=200.0)
    t, n = 0.0, 0
    while True:
        t2 = p.next_arrival(t, rng)
        if t2 is None:
            break
        t, n = t2, n + 1
    # expected ≈ (1+100)/2 * 200 = 10100; loose band
    assert 5000 < n < 16000


def test_deterministic_process():
    rng = np.random.default_rng(0)
    p = DeterministicProcess(gap=0.5, duration=2.0)
    assert p.next_arrival(0.0, rng) == 0.5
    assert p.next_arrival(1.6, rng) is None


# ----------------------------------------------------------------- platform
def _mk_platform(**cfg_kw):
    events = EventQueue()
    done = []
    plat = ServerlessPlatform(
        config=PlatformConfig(**cfg_kw),
        latency_model=AffineLatency(a=0.1, c=0.0, noise_cv=0.0),
        events=events,
        rng=np.random.default_rng(0),
        on_batch_done=lambda b, lat, t: done.append((b, lat, t)),
    )
    return plat, events, done


def _drain(events, until=1e9):
    now = 0.0
    while events:
        t, fn = events.pop()
        if t > until:
            break
        now = t
        fn(t)
    return now


def test_platform_processes_batch_with_cold_start():
    plat, events, done = _mk_platform(cold_start=2.0)
    b = Batch(requests=[Request(arrival_time=0.0)], dispatch_time=0.0, cause="full")
    plat.submit(b, 0.0)
    _drain(events, until=10.0)
    assert len(done) == 1
    _, lat, t = done[0]
    # cold start 2.0 + service 0.1
    assert lat == pytest.approx(2.1, abs=0.05)


def test_platform_warm_container_no_cold_start():
    plat, events, done = _mk_platform(initial_scale=1)
    b = Batch(requests=[Request(arrival_time=0.0)], dispatch_time=0.0, cause="full")
    plat.submit(b, 0.0)
    _drain(events, until=10.0)
    assert done[0][1] == pytest.approx(0.1, abs=1e-6)


def test_platform_queues_when_busy():
    plat, events, done = _mk_platform(initial_scale=1, max_scale=1, min_scale=1)
    for i in range(3):
        b = Batch(requests=[Request(arrival_time=0.0)], dispatch_time=0.0, cause="full")
        plat.submit(b, 0.0)
    _drain(events, until=5.0)
    lats = sorted(l for (_, l, _) in done)
    assert lats == pytest.approx([0.1, 0.2, 0.3], abs=1e-6)


def test_platform_failure_requeues_batch():
    plat, events, done = _mk_platform(initial_scale=2, failure_prob_per_batch=1.0)
    b = Batch(requests=[Request(arrival_time=0.0)], dispatch_time=0.0, cause="full")
    plat.submit(b, 0.0)
    # all attempts fail (prob 1.0) until containers exhausted + restarted;
    # drain a while: the batch keeps being requeued, autoscaler restarts pods
    _drain(events, until=60.0)
    assert plat.failed_attempts >= 1
    # at-least-once: batch never completes with failure_prob 1.0 but is
    # never lost either — it's still pending or in flight
    assert len(done) == 0


def test_platform_straggler_and_hedge():
    plat, events, done = _mk_platform(
        initial_scale=2, straggler_prob=1.0, straggler_mult=10.0, hedge_factor=2.0
    )
    b = Batch(requests=[Request(arrival_time=0.0)], dispatch_time=0.0, cause="full")
    plat.submit(b, 0.0)
    _drain(events, until=30.0)
    assert len(done) == 1  # exactly one completion despite duplicates
    assert plat.hedged_dispatches >= 1


def test_billing_integral():
    plat, events, done = _mk_platform(initial_scale=2, min_scale=2)
    plat.start(0.0)
    _drain(events, until=10.0)
    plat.finalize(10.0)
    assert plat.avg_containers(10.0) == pytest.approx(2.0, rel=0.05)


def test_scale_to_zero():
    plat, events, _ = _mk_platform(initial_scale=1, scale_to_zero_grace=5.0)
    plat.start(0.0)
    b = Batch(requests=[Request(arrival_time=0.0)], dispatch_time=0.0, cause="full")
    plat.submit(b, 0.0)
    _drain(events, until=120.0)
    assert plat._billable_count() == 0


# ---------------------------------------------------------------- simulator
def test_simulator_mlproxy_beats_passthrough_on_cost():
    sla = SLAConfig(slo_target=0.5)
    wl = get_workload("pytorch-fashion-mnist")
    results = {}
    for policy in ("passthrough", "mlproxy"):
        res = run_simulation(
            policy=policy, sla=sla, workload=wl,
            arrivals=PoissonProcess(rate=30.0, duration=900.0),
            platform_config=PlatformConfig(initial_scale=1),
            duration=900.0, warmup=200.0, seed=7,
        )
        results[policy] = res.summary
    assert results["mlproxy"]["avg_containers"] < 0.6 * results["passthrough"]["avg_containers"]
    assert results["mlproxy"]["violation_pct"] < 2.0
    assert results["mlproxy"]["avg_batch_size"] > 2.0


def test_simulator_linear_workload_no_benefit():
    # §4.3: linear-scaling workloads shouldn't benefit from batching
    sla = SLAConfig(slo_target=0.5)
    wl = LinearLatency(base=0.05, noise_cv=0.05)
    results = {}
    for policy in ("passthrough", "mlproxy"):
        res = run_simulation(
            policy=policy, sla=sla, workload=wl,
            arrivals=PoissonProcess(rate=20.0, duration=600.0),
            platform_config=PlatformConfig(initial_scale=1),
            duration=600.0, warmup=150.0, seed=7,
        )
        results[policy] = res.summary
    ratio = results["mlproxy"]["avg_containers"] / max(
        results["passthrough"]["avg_containers"], 1e-9
    )
    assert ratio > 0.7  # no large cost win on the negative control


def test_simulator_deterministic_given_seed():
    sla = SLAConfig(slo_target=0.5)
    wl = get_workload("sklearn-iris")
    kw = dict(
        policy="mlproxy", sla=sla, workload=wl,
        arrivals=PoissonProcess(rate=50.0, duration=120.0),
        platform_config=PlatformConfig(initial_scale=1),
        duration=120.0, seed=3,
    )
    a = run_simulation(**kw).summary
    kw["arrivals"] = PoissonProcess(rate=50.0, duration=120.0)
    b = run_simulation(**kw).summary
    assert a == b


def test_simulator_ccdf_monotone():
    sla = SLAConfig(slo_target=0.5)
    res = run_simulation(
        policy="mlproxy", sla=sla, workload=get_workload("sklearn-iris"),
        arrivals=PoissonProcess(rate=20.0, duration=120.0),
        platform_config=PlatformConfig(initial_scale=1),
        duration=120.0, seed=1,
    )
    lat, ccdf = res.ccdf()
    assert np.all(np.diff(lat) >= 0)
    assert np.all(np.diff(ccdf) <= 1e-12)


def test_simulator_static_and_clipper_and_oracle_policies():
    sla = SLAConfig(slo_target=0.5)
    wl = get_workload("keras-toxic")
    for policy, kw in [
        ("static", {"batch_size": 8, "timeout": 0.2}),
        ("clipper", {}),
        ("oracle", {"latency_model": lambda bs: wl.mean(bs)}),
    ]:
        res = run_simulation(
            policy=policy, sla=sla, workload=wl,
            arrivals=PoissonProcess(rate=30.0, duration=300.0),
            platform_config=PlatformConfig(initial_scale=1),
            duration=300.0, warmup=60.0, seed=5, policy_kwargs=kw,
        )
        assert res.summary["completed"] > 100
