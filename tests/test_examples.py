"""Examples smoke tests — every ``examples/*.py`` main must keep running.

ISSUE-4 satellite: PR 3's API changes (``completions`` replacing the
removed ``completed`` list, named RNG streams) could have silently broken
the examples because nothing executed them in CI. These tests run each
example's ``main()`` in-process (tiny arguments where the script accepts
them) so the next API change that breaks an example fails a test instead
of a user. The JAX-backed examples are marked ``slow`` (compile-heavy);
CI's fast subset deselects them with ``-m "not slow"``.
"""
import runpy
import sys

import pytest


def _run_example(monkeypatch, capsys, name, argv=()):
    monkeypatch.setattr(sys, "argv", [f"examples/{name}.py", *argv])
    runpy.run_path(f"examples/{name}.py", run_name="__main__")
    return capsys.readouterr().out


def test_quickstart(monkeypatch, capsys):
    out = _run_example(monkeypatch, capsys, "quickstart")
    assert "MLProxy" in out and "avg containers" in out


def test_multi_endpoint(monkeypatch, capsys):
    out = _run_example(monkeypatch, capsys, "multi_endpoint")
    assert "fleet:" in out
    assert "iris-tight" in out and "resnet-loose" in out


def test_live_runtime(monkeypatch, capsys):
    out = _run_example(monkeypatch, capsys, "live_runtime",
                       ["--duration", "2", "--rate", "40"])
    assert "conservation" in out and "lost=0" in out
    assert "calibration fit" in out


@pytest.mark.slow
def test_serve_engine(monkeypatch, capsys):
    pytest.importorskip("jax")
    out = _run_example(monkeypatch, capsys, "serve_engine",
                       ["--duration", "3", "--rate", "20"])
    assert "completed" in out and "real JAX batches" in out


@pytest.mark.slow
def test_fleet_controller(monkeypatch, capsys):
    pytest.importorskip("jax")
    out = _run_example(monkeypatch, capsys, "fleet_controller")
    assert "timeout decisions" in out
