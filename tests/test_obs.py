"""Observability-plane tests.

Covers the ISSUE-9 acceptance surface: tracer determinism (same seed +
FakeClock ⇒ byte-identical span logs, all five policies), tracing-off
identity, metrics-registry round-trip, SmartMonitor snapshot back-compat
(old-format snapshots load; new format round-trips losslessly),
deterministic burn-rate meters, flight-recorder triggers, and the
sim↔live summary key-parity contract.
"""
from __future__ import annotations

import dataclasses
import json

import pytest

from repro.core import MonitorConfig, SLAConfig
from repro.core.monitor import SmartMonitor
from repro.core.policies import make_policy
from repro.core.request import reset_request_ids
from repro.obs import (
    EV_KIND,
    BurnRateMeter,
    FlightRecorder,
    MetricsRegistry,
    SPAN_KINDS,
    Tracer,
    build_batch_spans,
    build_request_spans,
    serialize_events,
)
from repro.serverless.latency import get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import MMPP2, PoissonProcess
from repro.simulation.simulator import (
    EndpointSpec,
    Simulator,
    run_multi_simulation,
)

POLICIES = ("mlproxy", "passthrough", "static", "clipper", "oracle")

WORKLOAD = get_workload("pytorch-fashion-mnist")


def _policy_kwargs(policy: str) -> dict:
    if policy == "static":
        return {"batch_size": 4, "timeout": 0.1}
    if policy == "oracle":
        return {"latency_model": lambda bs: WORKLOAD.percentile(bs, 95)}
    return {}


def _chaos_sim(policy: str, *, tracer=None, recorder=None,
               duration: float = 20.0, seed: int = 7):
    """Short MMPP2 chaos run: bursty load + faults + stragglers, so the
    span log exercises retry / hedge / expiry kinds, not just the happy
    path."""
    sim = Simulator(
        policy=policy,
        sla=SLAConfig(slo_target=0.5),
        workload=WORKLOAD,
        arrivals=MMPP2(rate_lo=5.0, rate_hi=45.0, mean_lo=6.0, mean_hi=3.0,
                       duration=duration),
        platform_config=PlatformConfig(
            failure_prob_per_batch=0.05,
            straggler_prob=0.05,
            straggler_mult=4.0,
            hedge_factor=3.0,
        ),
        policy_kwargs=_policy_kwargs(policy) or None,
        duration=duration,
        drain_grace=60.0,
        seed=seed,
        tracer=tracer,
        recorder=recorder,
    )
    result = sim.run()
    return sim, result


def _live_run(duration: float, *, tracer=None, recorder=None,
              crash_prob=None):
    from experiments.scenarios import LIVE_SCENARIOS, run_live_scenario
    from repro.runtime import FaultConfig

    sc = LIVE_SCENARIOS["live-crash-storm"]
    if crash_prob is not None:
        sc = dataclasses.replace(
            sc, faults=FaultConfig(crash_prob=crash_prob,
                                   crash_latency=0.01))
    sc = dataclasses.replace(sc, duration=duration)
    return run_live_scenario(sc, "mlproxy", faults=True,
                             tracer=tracer, recorder=recorder)


# ------------------------------------------------------------ determinism
class TestTracerDeterminism:
    @pytest.mark.parametrize("policy", POLICIES)
    def test_same_seed_byte_identical_span_log(self, policy):
        logs = []
        for _ in range(2):
            # req_ids are a process-global sequence (allocation order,
            # not randomness); reset so both runs label requests 0..n
            reset_request_ids()
            tracer = Tracer()
            _chaos_sim(policy, tracer=tracer)
            logs.append(serialize_events(tracer.events()))
        assert logs[0] == logs[1]
        assert len(logs[0]) > 0

    def test_tracer_off_summary_identical(self):
        _, plain = _chaos_sim("mlproxy")
        _, traced = _chaos_sim("mlproxy", tracer=Tracer())
        assert plain.summary == traced.summary

    def test_all_emitted_kinds_are_declared(self):
        tracer = Tracer()
        _chaos_sim("mlproxy", tracer=tracer)
        kinds = {ev[EV_KIND] for ev in tracer.events()}
        assert kinds <= set(SPAN_KINDS)
        # the chaos regime must actually exercise the lifecycle
        assert {"batched", "dispatched", "completed"} <= kinds

    def test_spans_reconstruct(self):
        tracer = Tracer()
        _, result = _chaos_sim("mlproxy", tracer=tracer)
        spans = build_request_spans(tracer.events())
        batches = build_batch_spans(tracer.events())
        completed = [s for s in spans if s["outcome"] == "completed"]
        assert len(completed) == int(result.summary["completed"])
        for s in completed:
            assert s["queue_wait"] is not None and s["queue_wait"] >= 0.0
            assert s["service"] is not None and s["service"] > 0.0
            assert s["e2e"] >= s["queue_wait"]
        # every batched request points at a real batch record
        assert all(s["batch"] in batches for s in spans if s["batch"] >= 0)


# -------------------------------------------------------- metrics registry
class TestMetricsRegistry:
    def test_counter_is_monotonic(self):
        reg = MetricsRegistry()
        c = reg.counter("n")
        c.inc()
        c.inc(3)
        assert reg.value("n") == 4
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_get_or_create_idempotent(self):
        reg = MetricsRegistry()
        assert reg.counter("n") is reg.counter("n")
        assert reg.gauge("g") is reg.gauge("g")

    def test_type_conflict_rejected(self):
        reg = MetricsRegistry()
        reg.counter("x")
        with pytest.raises(ValueError):
            reg.gauge("x")
        with pytest.raises(ValueError):
            reg.bind("x", lambda: 0)

    def test_bound_metric_reads_live_value(self):
        reg = MetricsRegistry()
        box = {"v": 0}
        reg.bind("ext", lambda: box["v"])
        box["v"] = 7
        assert reg.value("ext") == 7

    def test_histogram_buckets_and_mean(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", bounds=(0.1, 1.0))
        for v in (0.05, 0.5, 0.5, 5.0):
            h.observe(v)
        assert h.counts == [1, 2, 1]
        assert h.count == 4
        assert h.mean == pytest.approx(6.05 / 4)

    def test_snapshot_restore_round_trip(self):
        reg = MetricsRegistry()
        reg.counter("c").inc(5)
        reg.gauge("g").set(2.5)
        h = reg.histogram("h", bounds=(1.0,))
        h.observe(0.5)
        h.observe(3.0)
        reg.bind("b", lambda: 42)

        snap = reg.snapshot()
        # bound metrics are materialized into the snapshot...
        assert snap["bound"] == {"b": 42}

        fresh = MetricsRegistry()
        fresh.restore(snap)
        assert fresh.value("c") == 5
        assert fresh.value("g") == 2.5
        assert fresh.histogram("h").counts == [1, 1]
        assert fresh.histogram("h").total == pytest.approx(3.5)
        # ...but (by design) not restored: the source object owns them
        with pytest.raises(KeyError):
            fresh.value("b")
        # round-trip is lossless for owned metrics
        snap2 = fresh.snapshot()
        for table in ("counters", "gauges", "histograms"):
            assert snap2[table] == snap[table]


# -------------------------------------------- monitor snapshot back-compat
def _seeded_monitor() -> SmartMonitor:
    mon = SmartMonitor(MonitorConfig(min_samples=1),
                       SLAConfig(slo_target=0.1))
    t = 0.0
    for i in range(20):
        t += 0.05
        mon.record_upstream(4, 0.05 + 0.001 * i, now=t,
                            attempts=2 if i % 5 == 0 else 1)
        mon.record_dispatch(4, "timeout" if i % 3 == 0 else "full",
                            effective_size=8)
        mon.record_e2e(0.05 if i % 2 else 0.2, now=t)
    mon.record_failure(4, now=t)
    return mon


class TestMonitorSnapshotBackCompat:
    def test_new_format_round_trip_lossless(self):
        mon = _seeded_monitor()
        snap = mon.snapshot()
        fresh = SmartMonitor(MonitorConfig(min_samples=1),
                             SLAConfig(slo_target=0.1))
        fresh.restore(snap)
        assert fresh.snapshot() == snap
        assert fresh.lifetime_requests == mon.lifetime_requests
        assert fresh.lifetime_failed_attempts == 1
        assert fresh.burn.total == mon.burn.total
        assert fresh.burn.rates(1.0) == mon.burn.rates(1.0)

    def test_old_format_snapshot_loads(self):
        """Snapshots predating the typed-counter/burn migration carry no
        failure, padding, retry, or burn state — they must still load."""
        mon = _seeded_monitor()
        snap = mon.snapshot()
        for legacy_missing in ("burn", "lifetime_failed_attempts",
                               "lifetime_upstream", "lifetime_padding"):
            del snap[legacy_missing]
        fresh = SmartMonitor(MonitorConfig(min_samples=1),
                             SLAConfig(slo_target=0.1))
        fresh.restore(snap)
        # the historical core survives...
        assert fresh.lifetime_requests == mon.lifetime_requests
        assert fresh.lifetime_dispatches == mon.lifetime_dispatches
        assert fresh.lifetime_violations == mon.lifetime_violations
        # ...and the post-migration counters default to empty
        assert fresh.lifetime_failed_attempts == 0
        assert fresh.lifetime_retried_batches == 0
        assert fresh.padding_waste() == 0.0
        assert fresh.burn.total == 0

    def test_register_metrics_exposes_counters(self):
        mon = _seeded_monitor()
        reg = MetricsRegistry()
        mon.register_metrics(reg, prefix="ep0")
        assert reg.value("ep0.lifetime_requests") == mon.lifetime_requests
        assert reg.value("ep0.burn_samples") == mon.burn.total
        # bound views are live, not copies
        mon.record_e2e(0.01, now=2.0)
        assert reg.value("ep0.lifetime_requests") == mon.lifetime_requests


# -------------------------------------------------------------- burn rate
class TestBurnRate:
    def test_burn_one_at_exactly_budget_pace(self):
        # p95 budget: 5% violations allowed; feed exactly 5% violations
        meter = BurnRateMeter.for_percentile(95.0, fast_window=60.0,
                                             slow_window=600.0)
        t = 0.0
        for i in range(600):
            t += 1.0
            meter.record(t, violated=(i % 20 == 0))
        rates = meter.rates(t)
        assert rates["burn_rate_fast"] == pytest.approx(1.0, abs=0.35)
        assert rates["burn_rate_slow"] == pytest.approx(1.0, abs=0.05)

    def test_fast_window_catches_sharp_regression(self):
        meter = BurnRateMeter(budget=0.05, fast_window=60.0,
                              slow_window=600.0)
        t = 0.0
        for _ in range(540):
            t += 1.0
            meter.record(t, violated=False)
        for _ in range(60):  # total outage in the final minute
            t += 1.0
            meter.record(t, violated=True)
        rates = meter.rates(t)
        assert rates["burn_rate_fast"] == pytest.approx(20.0, rel=0.05)
        assert rates["burn_rate_slow"] == pytest.approx(2.0, rel=0.10)
        assert rates["burning"]

    def test_not_burning_on_blip(self):
        meter = BurnRateMeter(budget=0.05, fast_window=60.0,
                              slow_window=600.0)
        t = 0.0
        for i in range(600):
            t += 1.0
            # one bad minute early on, clean since
            meter.record(t, violated=(60 <= i < 120))
        assert not meter.rates(t)["burning"]

    def test_deterministic_and_out_of_order_fold(self):
        a, b = (BurnRateMeter(budget=0.1, fast_window=10.0,
                              slow_window=100.0) for _ in range(2))
        for m in (a, b):
            m.record(1.0, True)
            m.record(2.0, False)
            m.record(1.5, True)  # slightly out of order: folds, no error
        assert a.snapshot() == b.snapshot()
        assert a.rates(2.0) == b.rates(2.0)
        assert a.total == 3 and a.violations == 2

    def test_snapshot_restore_round_trip(self):
        meter = BurnRateMeter(budget=0.05)
        for i in range(50):
            meter.record(i * 0.5, violated=(i % 7 == 0))
        fresh = BurnRateMeter(budget=0.05)
        fresh.restore(meter.snapshot())
        assert fresh.rates(25.0) == meter.rates(25.0)
        assert fresh.snapshot() == meter.snapshot()

    def test_validation(self):
        with pytest.raises(ValueError):
            BurnRateMeter(budget=0.0)
        with pytest.raises(ValueError):
            BurnRateMeter(budget=0.05, fast_window=60.0, slow_window=30.0)
        # p100 clamps to a finite budget instead of dividing by zero
        assert BurnRateMeter.for_percentile(100.0).budget == 1e-3


# -------------------------------------------------------- flight recorder
class TestFlightRecorder:
    def test_ring_bounds_and_dropped(self):
        rec = FlightRecorder(capacity=4, out_dir="unused")
        for i in range(6):
            rec.note(float(i), "dispatch", n=i)
        assert len(rec) == 4
        assert rec.dropped == 2
        assert [e["n"] for e in rec.events()] == [2, 3, 4, 5]

    def test_dump_is_parseable_json(self, tmp_path):
        rec = FlightRecorder(out_dir=str(tmp_path))
        rec.note(1.0, "dispatch", endpoint="ep", size=4)
        path = rec.dump("breaker_open", now=2.0, extra={"endpoint": "ep"})
        assert path is not None and rec.dumps == [path]
        doc = json.loads((tmp_path / path.split("/")[-1]).read_text())
        assert doc["reason"] == "breaker_open"
        assert doc["now"] == 2.0
        assert doc["extra"] == {"endpoint": "ep"}
        assert doc["events"] == [{"t": 1.0, "kind": "dispatch",
                                  "endpoint": "ep", "size": 4}]

    def test_dump_sanitizes_reason_and_never_raises(self, tmp_path):
        rec = FlightRecorder(out_dir=str(tmp_path))
        path = rec.dump("conservation: lost/batches!")
        assert path is not None and "/flightrec-001-" in path
        assert path.endswith(".json")
        # an unwritable out_dir (path through a regular file) must not
        # turn the postmortem into a second crash
        blocker = tmp_path / "blocker"
        blocker.write_text("")
        rec2 = FlightRecorder(out_dir=str(blocker / "sub"))
        assert rec2.dump("whatever") is None
        assert rec2.dumps == []

    def test_conservation_failure_dumps_postmortem(self, tmp_path):
        rec = FlightRecorder(out_dir=str(tmp_path))
        sim, _ = _chaos_sim("mlproxy", recorder=rec, duration=10.0)
        sim.platform.assert_conserved(require_drained=True)  # healthy
        dumps_before = len(rec.dumps)
        sim.platform.duplicate_completions += 1  # corrupt the ledger
        with pytest.raises(AssertionError):
            sim.platform.assert_conserved()
        assert len(rec.dumps) == dumps_before + 1
        doc = json.loads(open(rec.dumps[-1]).read())
        assert doc["reason"].startswith("conservation-")
        assert doc["extra"]["duplicate_completions"] == 1

    def test_breaker_open_dumps_postmortem(self, tmp_path):
        """Forced outage under FakeClock: crash_prob=1.0 trips the
        breaker, which must leave a parseable postmortem."""
        rec = FlightRecorder(out_dir=str(tmp_path))
        _live_run(8.0, recorder=rec, crash_prob=1.0)
        assert rec.dumps
        doc = json.loads(open(rec.dumps[0]).read())
        assert doc["reason"] == "breaker_open"
        assert any(e["kind"] == "breaker_open" for e in doc["events"])
        assert any(e["kind"] == "dispatch" for e in doc["events"])


# ------------------------------------------------------- sim↔live parity
class TestSummaryKeyParity:
    #: live-only optional sub-dict (present only when a breaker is wired)
    LIVE_ONLY = {"breaker"}
    #: the shared observability keys every top-level summary must carry
    OBS_KEYS = {"events_processed", "queue_depth_hwm",
                "burn_rate_fast", "burn_rate_slow"}

    def _multi_sim(self):
        specs = {
            "ep": EndpointSpec(
                policy="mlproxy", sla=SLAConfig(slo_target=0.5),
                workload=WORKLOAD,
                arrivals=PoissonProcess(rate=20.0, duration=20.0),
                platform_config=PlatformConfig(initial_scale=1),
            ),
        }
        return run_multi_simulation(specs, duration=20.0, seed=3)

    def test_per_endpoint_summary_keys_identical(self):
        sim_keys = set(self._multi_sim().endpoints["ep"])
        live = _live_run(8.0)
        live_keys = set(live.summary["endpoints"]["ep"]) - self.LIVE_ONLY
        assert sim_keys == live_keys

    def test_top_level_obs_keys_in_both_worlds(self):
        _, single = _chaos_sim("mlproxy", duration=10.0)
        multi = self._multi_sim()
        live = _live_run(8.0)
        for summary in (single.summary, multi.summary, live.summary):
            assert self.OBS_KEYS <= set(summary)
            assert summary["events_processed"] > 0
            assert summary["queue_depth_hwm"] >= 1

    def test_policy_stats_key_parity_across_policies(self):
        sla = SLAConfig(slo_target=0.5)
        key_sets = {}
        for name in POLICIES:
            policy = make_policy(name, sla, dispatch_fn=lambda b: None,
                                 **_policy_kwargs(name))
            key_sets[name] = frozenset(policy.stats(0.0))
        assert len(set(key_sets.values())) == 1, key_sets
