"""Bucket-aware packing: dispatch at engine bucket edges, waste stats.

No JAX — the packing path is pure control plane. Covers the tuple
bucketing scheme, the packed full-trigger across every policy, request
conservation, the partial-dispatch queue split, the SmartMonitor
padding-waste counters, and snapshot back-compat.
"""
import pytest

from repro.core import (MonitorConfig, ProxyConfig, Request, SLAConfig,
                        SmartMonitor)
from repro.core.batch_queue import BatchQueue
from repro.core.config import OptimizerConfig, bucket_of, validate_buckets
from repro.core.policies import make_policy

BUCKETS = (1, 2, 4, 8)
POLICIES = ("passthrough", "static", "clipper", "oracle", "mlproxy")


def _policy_kwargs(policy):
    if policy == "static":
        return {"batch_size": 5, "timeout": 10.0}
    if policy == "oracle":
        return {"latency_model": lambda bs: 0.01 * bs, "max_cap": 6}
    if policy == "mlproxy":
        # start the AIMD cap mid-bucket so packing has something to round
        return {"optimizer": OptimizerConfig(initial_max_bs=5),
                "monitor": MonitorConfig(optimistic_default=0.0)}
    return {}


def _drive(policy, pack_buckets, n_requests=23, **extra):
    """Feed a fast burst through a policy; return (policy, batches)."""
    out = []
    sla = SLAConfig(slo_target=100.0)
    kwargs = _policy_kwargs(policy)
    kwargs.update(extra)
    if pack_buckets is not None:
        kwargs["pack_buckets"] = pack_buckets
    pol = make_policy(policy, sla, out.append, **kwargs)
    for i in range(n_requests):
        pol.on_request(Request(arrival_time=i * 1e-4), now=i * 1e-4)
    return pol, out


# ------------------------------------------------------------ tuple buckets
def test_bucket_of_tuple_scheme():
    assert bucket_of(1, BUCKETS) == 1
    assert bucket_of(3, BUCKETS) == 4
    assert bucket_of(8, BUCKETS) == 8
    assert bucket_of(9, BUCKETS) == 8  # above largest: clamps (chunked)


def test_validate_buckets_rejects_bad_grids():
    assert validate_buckets([1, 2, 4]) == (1, 2, 4)
    with pytest.raises(ValueError):
        validate_buckets(())
    with pytest.raises(ValueError):
        validate_buckets((4, 2))
    with pytest.raises(ValueError):
        validate_buckets((0, 2))


def test_proxy_config_pack_buckets_implies_bucketing():
    cfg = ProxyConfig(sla=SLAConfig(slo_target=1.0), pack_buckets=BUCKETS)
    assert cfg.bucketing == BUCKETS
    # explicit bucketing wins over the implication
    cfg2 = ProxyConfig(sla=SLAConfig(slo_target=1.0), pack_buckets=BUCKETS,
                       bucketing="pow2")
    assert cfg2.bucketing == "pow2"


# -------------------------------------------------------- packed dispatches
@pytest.mark.parametrize("policy", POLICIES)
def test_packing_conserves_requests(policy):
    pol, out = _drive(policy, BUCKETS)
    dispatched = sum(b.size for b in out)
    assert dispatched + pol.queue_len == 23
    pol.flush(1.0)
    assert sum(b.size for b in out) == 23
    assert pol.queue_len == 0


@pytest.mark.parametrize("policy", POLICIES)
def test_packed_full_batches_land_on_bucket_edges(policy):
    _, out = _drive(policy, BUCKETS)
    for b in out:
        if b.cause == "full":
            assert b.size in BUCKETS, (policy, b.size)
            # dispatched exactly at the edge: zero padding on full batches
            assert b.effective_size == b.size


def test_static_packed_rounds_target_up_to_edge():
    # target 5 rounds up to bucket 8: burst of 23 → 8 + 8, 7 left queued
    pol, out = _drive("static", BUCKETS)
    assert [b.size for b in out] == [8, 8]
    assert pol.queue_len == 7
    assert pol.stats(0.01)["padding_waste"] == 0.0


def test_static_unpacked_bucketing_pays_padding():
    # same burst, bucketed but NOT packed: full-trigger at 5 → bucket 8
    pol, out = _drive("static", None, bucketing=BUCKETS)
    assert all(b.size == 5 for b in out if b.cause == "full")
    assert all(b.effective_size == 8 for b in out if b.cause == "full")
    st = pol.stats(0.01)
    assert st["padded_slots"] > 0
    assert st["padding_waste"] == pytest.approx(
        st["padded_slots"] / st["dispatched_slots"])


def test_mlproxy_packed_dispatches_at_edges():
    pol, out = _drive("mlproxy", BUCKETS)
    full = [b for b in out if b.cause == "full"]
    assert full, "burst never filled a packed batch"
    assert all(b.size in BUCKETS for b in full)
    assert pol.stats(0.01)["padding_waste"] == 0.0


def test_timeout_flushes_whole_queue_despite_packing():
    # 3 queued (< bucket edge 8): the timeout dispatch takes all 3 —
    # SLA pressure beats packing efficiency
    pol, out = _drive("static", BUCKETS, n_requests=3)
    assert not out
    pol.on_timer(0.0 + 10.0 + 1e-6)
    assert [b.size for b in out] == [3]
    assert out[0].cause == "timeout"
    assert out[0].effective_size == 4  # still bucketed: padded to 4


# -------------------------------------------------- queue partial dispatch
def test_batch_queue_limit_splits_head_and_keeps_tail():
    out = []
    mon = SmartMonitor(MonitorConfig(), SLAConfig(slo_target=1.0))
    q = BatchQueue(out.append, mon)
    for i in range(10):
        q.append(Request(arrival_time=float(i)), now=float(i))
    q.next_deadline = 42.0
    batch = q._dispatch(9.5, cause="full", limit=4)
    assert batch.size == 4
    assert [r.arrival_time for r in batch.requests] == [0.0, 1.0, 2.0, 3.0]
    assert q.queue_len == 6
    # tail re-anchors: oldest remaining request drives FRT, timer cleared
    assert q.frt(9.5) == pytest.approx(9.5 - 4.0)
    assert q.next_deadline is None
    # limit >= queue drains everything (same as unlimited)
    rest = q._dispatch(9.6, cause="full", limit=99)
    assert rest.size == 6 and q.queue_len == 0


def test_batch_queue_limit_recomputes_tail_deadlines():
    out = []
    mon = SmartMonitor(MonitorConfig(), SLAConfig(slo_target=1.0))
    q = BatchQueue(out.append, mon)
    q.append(Request(arrival_time=0.0), now=0.0)
    q.append(Request(arrival_time=0.1, deadline=5.0), now=0.1)
    q.append(Request(arrival_time=0.2, deadline=3.0), now=0.2)
    q._dispatch(0.3, cause="full", limit=1)  # takes the deadline-free head
    assert q.queue_len == 2
    assert q.next_event_time() == 3.0  # earliest surviving expiry


# ----------------------------------------------------------- monitor stats
def test_monitor_padding_counters_and_snapshot_roundtrip():
    mon = SmartMonitor(MonitorConfig(), SLAConfig(slo_target=1.0))
    mon.record_dispatch(5, "full", effective_size=8)
    mon.record_dispatch(8, "full", effective_size=8)
    assert mon.lifetime_dispatched_slots == 16
    assert mon.lifetime_padded_slots == 3
    assert mon.padding_waste() == pytest.approx(3 / 16)
    clone = SmartMonitor(MonitorConfig(), SLAConfig(slo_target=1.0))
    clone.restore(mon.snapshot())
    assert clone.padding_waste() == pytest.approx(3 / 16)


def test_monitor_restore_accepts_pre_padding_snapshots():
    mon = SmartMonitor(MonitorConfig(), SLAConfig(slo_target=1.0))
    state = mon.snapshot()
    state.pop("lifetime_padding", None)  # snapshot from an older build
    clone = SmartMonitor(MonitorConfig(), SLAConfig(slo_target=1.0))
    clone.restore(state)
    assert clone.padding_waste() == 0.0
