"""Fleet controller (vectorized JAX) vs scalar Python implementation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AIMDBatchOptimizer,
    MonitorConfig,
    OptimizerConfig,
    SLAConfig,
    SmartMonitor,
)
from repro.core import jax_controller as jc


def test_aimd_step_matches_scalar():
    n = 16
    rng = np.random.default_rng(0)
    slo = np.full(n, 1.0, np.float32)
    state = jc.init_fleet(n, n_buckets=8, window=32, e2e_window=64)
    # scalar references
    scalars = []
    for i in range(n):
        sla = SLAConfig(slo_target=1.0)
        mon = SmartMonitor(MonitorConfig(window_size=64, window_horizon=1e12,
                                         e2e_horizon=1e12), sla)
        opt = AIMDBatchOptimizer(OptimizerConfig(), sla, mon)
        scalars.append((mon, opt))

    # feed identical observations to both
    for step in range(50):
        ep = int(rng.integers(0, n))
        lat = float(rng.uniform(0.05, 1.5))
        was_to = bool(rng.random() < 0.3)
        state = jc.record_e2e(state, jnp.asarray(ep), jnp.asarray(lat, jnp.float32))
        state = jc.record_dispatch(state, jnp.asarray(ep), jnp.asarray(was_to))
        mon, _ = scalars[ep]
        mon.record_e2e(lat, now=float(step))
        mon.record_dispatch(2, "timeout" if was_to else "full")

    state2 = jc.aimd_step(state, jnp.asarray(slo))
    for i, (mon, opt) in enumerate(scalars):
        opt.update(now=1e9)  # horizon large → no eviction difference
        assert float(state2.max_bs[i]) == pytest.approx(opt.max_bs_raw, rel=1e-5), i
    # counters reset
    assert int(state2.disp_count.sum()) == 0


def test_timeout_step_matches_equation():
    n = 4
    state = jc.init_fleet(n, n_buckets=8, window=16, initial_max_bs=8.0)
    # endpoint 0: bucket 2 (probe for queue_len=2) has known latency 0.3
    for _ in range(4):
        state = jc.record_upstream(
            state, jnp.asarray(0), jnp.asarray(2), jnp.asarray(0.3, jnp.float32)
        )
    queue_len = jnp.asarray([2, 0, 1, 8], jnp.int32)
    frt = jnp.asarray([0.1, 0.0, 0.0, 0.0], jnp.float32)
    slo = jnp.asarray([1.0, 1.0, 1.0, 1.0], jnp.float32)
    dispatch, to = jc.timeout_step(state, queue_len, frt, slo)
    # endpoint 0: TO = (1.0 - 0.3) - 0.1 = 0.6
    assert float(to[0]) == pytest.approx(0.6, abs=1e-6)
    assert not bool(dispatch[0])
    # endpoint 1: empty queue → no dispatch
    assert not bool(dispatch[1])
    # endpoint 2: no latency data anywhere → est 0 → TO = SLO > 0, queue < max
    assert not bool(dispatch[2])
    assert float(to[2]) == pytest.approx(1.0, abs=1e-6)
    # endpoint 3: queue_len == max_bs → dispatch 'full'
    assert bool(dispatch[3])


def test_masked_percentile_ignores_nans():
    x = jnp.asarray([[1.0, jnp.nan, 3.0, 2.0], [jnp.nan] * 4])
    p = jc._masked_percentile(x, 95.0)
    assert float(p[0]) == 3.0
    assert bool(jnp.isnan(p[1]))


def test_effective_max_bs_floor():
    state = jc.init_fleet(2, 4)
    state = state.__class__(**{**state.__dict__, "max_bs": jnp.asarray([1.6, 7.2])})
    eff = jc.effective_max_bs(state)
    assert eff.tolist() == [1, 7]
