"""Tests: optimizer, data pipeline, checkpointing, serving engine, training."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenDataset
from repro.distributed import checkpoint as ckpt
from repro.launch.train import TrainConfig, train
from repro.optim import adamw
from repro.serving.batcher import EngineBackedLatency
from repro.serving.engine import EngineConfig, InferenceEngine, ReplicaPool, next_bucket


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    cfg = adamw.AdamWConfig(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init_state(cfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_updates(cfg, params, g, state)
    assert float(loss(params)) < 1e-2


def test_adamw_grad_clip():
    cfg = adamw.AdamWConfig(grad_clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = adamw.init_state(cfg, params)
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, metrics = adamw.apply_updates(cfg, params, g, state)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)


def test_adamw_bf16_state_dtype():
    cfg = adamw.AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    state = adamw.init_state(cfg, params)
    assert state.mu["w"].dtype == jnp.bfloat16


def test_cosine_schedule_shape():
    s = adamw.cosine_schedule(jnp.asarray(0), warmup=10, total=100)
    assert float(s) == 0.0
    s = adamw.cosine_schedule(jnp.asarray(10), warmup=10, total=100)
    assert float(s) == pytest.approx(1.0)
    s = adamw.cosine_schedule(jnp.asarray(100), warmup=10, total=100)
    assert float(s) == pytest.approx(0.1)


# --------------------------------------------------------------------- data
def test_dataset_deterministic_and_restartable():
    cfg = DataConfig(seq_len=32, global_batch=4, seed=7)
    ds = TokenDataset(cfg)
    b1 = next(ds)
    b2 = next(ds)
    state = ds.state()
    b3 = next(ds)
    ds2 = TokenDataset(cfg)
    ds2.restore(state)
    b3b = next(ds2)
    np.testing.assert_array_equal(b3["tokens"], b3b["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert not np.array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].max() < cfg.vocab_size


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    d = str(tmp_path / "ckpt")
    ckpt.save_checkpoint(d, 10, tree, metadata={"note": "x"})
    assert ckpt.latest_step(d) == 10
    restored, meta = ckpt.restore_checkpoint(d, 10, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16
    assert meta["note"] == "x"


def test_checkpoint_prune_and_latest(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ckpt.save_checkpoint(d, s, tree)
    ckpt.prune_checkpoints(d, keep=2)
    assert ckpt.latest_step(d) == 4
    assert sorted(int(x.split("_")[1]) for x in os.listdir(d)) == [3, 4]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    ckpt.save_checkpoint(d, 1, {"w": jnp.zeros(2)})
    with pytest.raises(ValueError):
        ckpt.restore_checkpoint(d, 1, {"w": jnp.zeros(3)})


def test_checkpoint_atomic_commit(tmp_path):
    # a directory without manifest.json must be invisible to latest_step
    d = tmp_path / "ckpt"
    (d / "step_5").mkdir(parents=True)
    assert ckpt.latest_step(str(d)) is None


# ------------------------------------------------------------------- engine
@pytest.fixture(scope="module")
def small_engine():
    cfg = get_config("qwen2-0.5b").reduced()
    ecfg = EngineConfig(batch_buckets=(1, 2, 4), prompt_buckets=(8, 16),
                        max_len=32, gen_len=4)
    return InferenceEngine(cfg, ecfg, rng=jax.random.PRNGKey(0))


def test_next_bucket():
    assert next_bucket(1, (1, 2, 4)) == 1
    assert next_bucket(3, (1, 2, 4)) == 4
    with pytest.raises(ValueError):
        next_bucket(5, (1, 2, 4))


def test_engine_generates_and_buckets(small_engine):
    prompts = np.random.default_rng(0).integers(0, 100, (3, 5)).astype(np.int32)
    out, timing = small_engine.generate(prompts, gen_len=4)
    assert out.shape == (3, 4)
    assert timing["bucket"] == 4
    assert timing["prompt_bucket"] == 8
    assert timing["padding_waste"] == pytest.approx(0.25)


def test_engine_compile_cache_reused(small_engine):
    before = small_engine.compile_count
    prompts = np.zeros((3, 5), np.int32)
    small_engine.generate(prompts, gen_len=2)
    small_engine.generate(prompts + 1, gen_len=2)
    assert small_engine.compile_count == before + (2 if before == 0 else 0) or \
        small_engine.compile_count >= before  # same buckets → no new compiles
    after_two = small_engine.compile_count
    small_engine.generate(np.zeros((3, 5), np.int32), gen_len=2)
    assert small_engine.compile_count == after_two


def test_engine_deterministic_greedy(small_engine):
    prompts = np.arange(10, dtype=np.int32).reshape(2, 5) % 64
    a, _ = small_engine.generate(prompts, gen_len=4)
    b, _ = small_engine.generate(prompts, gen_len=4)
    np.testing.assert_array_equal(a, b)


def test_replica_pool_failover():
    cfg = get_config("qwen2-0.5b").reduced()
    ecfg = EngineConfig(batch_buckets=(1, 2), prompt_buckets=(8,), max_len=16,
                        gen_len=2)
    pool = ReplicaPool(cfg, ecfg, n_replicas=2)
    pool.fail(1)
    out, timing = pool.generate(np.zeros((1, 4), np.int32))
    assert timing["replica"] == 0
    assert pool.n_healthy == 1
    pool.recover(1)
    pool.scale_to(3)
    assert pool.n_healthy == 3


def test_engine_backed_latency(small_engine):
    lat = EngineBackedLatency(small_engine, prompt_len=5, gen_len=2)
    rng = np.random.default_rng(0)
    s = lat.sample(2, rng)
    assert s > 0
    assert lat.mean(2) > 0


# ------------------------------------------------------------------ training
def test_train_loop_loss_decreases(tmp_path):
    cfg = get_config("qwen2-0.5b").reduced()
    tcfg = TrainConfig(steps=30, log_every=5, checkpoint_every=100)
    out = train(cfg, tcfg, DataConfig(seq_len=32, global_batch=4,
                                      vocab_size=cfg.vocab_size))
    assert out["final_loss"] < out["first_loss"]


def test_train_checkpoint_restart_continues(tmp_path):
    cfg = get_config("qwen2-0.5b").reduced()
    d = str(tmp_path / "ck")
    tcfg = TrainConfig(steps=20, log_every=10, checkpoint_every=10,
                       checkpoint_dir=d)
    train(cfg, tcfg, DataConfig(seq_len=16, global_batch=2,
                                vocab_size=cfg.vocab_size))
    assert ckpt.latest_step(d) == 20
    # resume with more steps — must pick up from 20 without error
    tcfg2 = TrainConfig(steps=25, log_every=5, checkpoint_every=100,
                        checkpoint_dir=d)
    out = train(cfg, tcfg2, DataConfig(seq_len=16, global_batch=2,
                                       vocab_size=cfg.vocab_size))
    assert np.isfinite(out["final_loss"])
