"""Unit tests for Algorithm 1 (queue scheduler) and Algorithm 2 (AIMD)."""
import pytest

from repro.core import (
    AIMDBatchOptimizer,
    MLProxy,
    MonitorConfig,
    OptimizerConfig,
    ProxyConfig,
    Request,
    SLAConfig,
    SmartMonitor,
)
from repro.core.scheduler import QueueScheduler


def make_sched(slo=1.0, max_bs=4, est=None, **monitor_kw):
    """Scheduler with a monitor pre-seeded so RT95[bs] == est(bs)."""
    sla = SLAConfig(slo_target=slo)
    cfg = ProxyConfig(sla=sla, monitor=MonitorConfig(min_samples=1, **monitor_kw))
    mon = SmartMonitor(cfg.monitor, sla)
    if est is not None:
        for bs in range(1, 64):
            for _ in range(3):
                mon.record_upstream(bs, est(bs), now=0.0)
    out = []
    sched = QueueScheduler(cfg, mon, dispatch_fn=out.append, max_bs_fn=lambda: max_bs)
    return sched, mon, out


def test_dispatch_on_max_batch_size():
    sched, _, out = make_sched(max_bs=3, est=lambda bs: 0.1)
    for i in range(3):
        sched.on_arrival(Request(arrival_time=float(i) * 0.01), now=float(i) * 0.01)
    assert len(out) == 1
    assert out[0].size == 3
    assert out[0].cause == "full"
    assert sched.queue_len == 0


def test_timeout_computation_matches_equation():
    # RT95[bs] = 0.1 + 0.05*bs ; SLO = 1.0
    sched, _, out = make_sched(slo=1.0, max_bs=16, est=lambda bs: 0.1 + 0.05 * bs)
    sched.on_arrival(Request(arrival_time=10.0), now=10.0)
    # N_q = 1 → probe bs=2 → est = 0.2 ; DTO = 0.8 ; FRT = 0 → deadline 10.8
    assert sched.next_deadline == pytest.approx(10.8)
    sched.on_arrival(Request(arrival_time=10.3), now=10.3)
    # N_q = 2 → probe bs=3 → est = 0.25 ; DTO = 0.75 ; FRT = 0.3 → 10.3+0.45
    assert sched.next_deadline == pytest.approx(10.75)
    assert not out


def test_negative_timeout_dispatches_immediately():
    sched, _, out = make_sched(slo=0.2, max_bs=16, est=lambda bs: 0.5)
    sched.on_arrival(Request(arrival_time=0.0), now=0.0)
    assert len(out) == 1 and out[0].cause == "timeout"


def test_timer_fires_dispatch():
    sched, _, out = make_sched(slo=1.0, max_bs=16, est=lambda bs: 0.1)
    sched.on_arrival(Request(arrival_time=0.0), now=0.0)
    deadline = sched.next_deadline
    sched.on_timer(deadline - 0.01)  # early → no-op
    assert not out
    sched.on_timer(deadline)
    assert len(out) == 1 and out[0].cause == "timeout"
    assert sched.next_deadline is None


def test_frt_uses_oldest_request():
    sched, _, _ = make_sched(slo=1.0, max_bs=100, est=lambda bs: 0.0)
    sched.on_arrival(Request(arrival_time=0.0), now=0.0)
    for t in (0.2, 0.4, 0.6):
        sched.on_arrival(Request(arrival_time=t), now=t)
    # DTO = 1.0 - 0 = 1.0, FRT = 0.6 → deadline = 0.6 + (1.0 - 0.6) = 1.0
    assert sched.next_deadline == pytest.approx(1.0)


def test_flush():
    sched, _, out = make_sched(max_bs=10, est=lambda bs: 0.0)
    sched.on_arrival(Request(arrival_time=0.0), now=0.0)
    sched.flush(now=0.5)
    assert len(out) == 1 and out[0].cause == "flush"


def test_bucketing_pads_to_pow2():
    sla = SLAConfig(slo_target=1.0)
    cfg = ProxyConfig(sla=sla, monitor=MonitorConfig(min_samples=1), bucketing="pow2")
    mon = SmartMonitor(cfg.monitor, sla)
    out = []
    sched = QueueScheduler(cfg, mon, dispatch_fn=out.append, max_bs_fn=lambda: 5)
    for i in range(5):
        sched.on_arrival(Request(arrival_time=0.0), now=0.0)
    assert out[0].size == 5 and out[0].bucket_size == 8


# ----------------------------------------------------------------- Algorithm 2

def make_opt(slo=1.0, **kw):
    sla = SLAConfig(slo_target=slo)
    mon = SmartMonitor(MonitorConfig(), sla)
    opt = AIMDBatchOptimizer(OptimizerConfig(**kw), sla, mon)
    return opt, mon


def test_aimd_additive_increase():
    opt, mon = make_opt()
    mon.record_e2e(0.1, now=0.0)  # well under SLO
    opt.update(now=30.0)
    assert opt.max_bs == 2
    opt.update(now=60.0)
    assert opt.max_bs == 3


def test_aimd_multiplicative_decrease_on_latency():
    opt, mon = make_opt()
    for _ in range(10):
        opt.update(now=0.0)  # no data → increase
    assert opt.max_bs == 11
    mon.record_e2e(0.9, now=300.0)  # > 0.8 * SLO → violation
    opt.update(now=300.0)
    assert opt.max_bs_raw == pytest.approx(11.0 * 0.8)


def test_aimd_decrease_on_timeout_ratio():
    opt, mon = make_opt(to_thresh=0.5)
    for _ in range(4):
        opt.update(now=0.0)
    start = opt.max_bs_raw
    mon.record_dispatch(2, "timeout")
    mon.record_dispatch(2, "timeout")
    mon.record_dispatch(2, "full")
    opt.update(now=100.0)
    assert opt.max_bs_raw == pytest.approx(start * 0.8)
    # interval counters reset after update
    assert mon.timeout_ratio() == 0.0


def test_aimd_respects_interval():
    opt, mon = make_opt(update_interval=30.0)
    assert not opt.maybe_update(now=0.0)  # anchors
    assert not opt.maybe_update(now=10.0)
    assert opt.maybe_update(now=31.0)
    assert not opt.maybe_update(now=40.0)


def test_aimd_floor_at_one():
    opt, mon = make_opt()
    mon.record_e2e(10.0, now=0.0)
    opt._last_update = 0.0
    for t in range(1, 50):
        mon.record_e2e(10.0, now=30.0 * t)
        opt.update(now=30.0 * t)
    assert opt.max_bs == 1


# ----------------------------------------------------------------- MLProxy


def test_proxy_end_to_end_flow():
    sla = SLAConfig(slo_target=1.0)
    cfg = ProxyConfig(
        sla=sla,
        monitor=MonitorConfig(min_samples=1),
        optimizer=OptimizerConfig(initial_max_bs=8),
    )
    batches = []
    proxy = MLProxy(cfg, dispatch_fn=batches.append)
    # seed latency knowledge: upstream takes 0.1 s for any size
    for bs in range(1, 8):
        proxy.monitor.record_upstream(bs, 0.1, now=0.0)
    t = 0.0
    proxy.on_request(Request(arrival_time=t), now=t)
    assert proxy.scheduler.next_deadline == pytest.approx(0.9)
    proxy.on_timer(0.9)
    assert len(batches) == 1
    proxy.on_response(batches[0], upstream_latency=0.1, now=1.0)
    stats = proxy.stats(now=1.0)
    assert stats["dispatched_requests"] == 1
    assert stats["violation_rate"] == 0.0


def test_proxy_snapshot_restore_resumes_warm():
    sla = SLAConfig(slo_target=1.0)
    cfg = ProxyConfig(sla=sla, monitor=MonitorConfig(min_samples=1))
    batches = []
    proxy = MLProxy(cfg, dispatch_fn=batches.append)
    for bs in range(1, 8):
        proxy.monitor.record_upstream(bs, 0.25, now=0.0)
    for _ in range(5):
        proxy.optimizer.update(now=0.0)
    state = proxy.snapshot()

    proxy2 = MLProxy(cfg, dispatch_fn=batches.append)
    proxy2.restore(state)
    assert proxy2.max_bs == proxy.max_bs
    assert proxy2.monitor.upstream_percentile(4, 0.0) == pytest.approx(0.25)
