"""Tests for the live async runtime (repro.runtime).

Covers the ISSUE-4 acceptance points: FakeClock determinism (same seed +
trace → identical dispatch decisions), admission control / backpressure,
graceful drain with the runtime conservation invariant (submitted ==
completed + rejected, zero lost), all five policies running unmodified,
sim↔live parity on a shared schedule, and the calibration bridge
round-trip (measure → fit → simulate within 10%).
"""
import asyncio
import math

import numpy as np
import pytest

from repro.core import SLAConfig, ms
from repro.core.config import OptimizerConfig, ProxyConfig
from repro.runtime import (AsyncProxyServer, Calibration, FakeClock,
                           LoadGenerator, RuntimeConfig, SyntheticTarget,
                           WallClock, clamp_policy_kwargs, run, run_replay)
from repro.serverless.latency import AffineLatency, MeasuredLatency, get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import (MMPP2, PoissonProcess, Schedule,
                                       sample_schedule)
from repro.simulation.simulator import run_simulation

SLA = SLAConfig(slo_target=ms(500))
WL = get_workload("pytorch-fashion-mnist")

ALL_POLICIES = ("passthrough", "static", "clipper", "oracle", "mlproxy")


def policy_kwargs(policy):
    if policy == "static":
        return {"batch_size": 8, "timeout": 0.2}
    if policy == "oracle":
        return {"latency_model": lambda bs: WL.percentile(bs, 95)}
    return {}


# --------------------------------------------------------------- FakeClock
class TestFakeClock:
    def test_sleep_orders_virtual_time(self):
        clock = FakeClock()
        log = []

        async def sleeper(tag, dt):
            await clock.sleep(dt)
            log.append((tag, clock.now()))

        async def main():
            await asyncio.gather(sleeper("b", 2.0), sleeper("a", 1.0),
                                 sleeper("c", 3.0))

        run(clock, main())
        assert log == [("a", 1.0), ("b", 2.0), ("c", 3.0)]
        assert clock.now() == 3.0

    def test_wait_timeout_and_event(self):
        clock = FakeClock()
        results = {}

        async def main():
            ev = asyncio.Event()

            async def setter():
                await clock.sleep(0.5)
                ev.set()

            task = asyncio.ensure_future(setter())
            results["timeout"] = await clock.wait(asyncio.Event(), 0.2)
            results["event"] = await clock.wait(ev, 10.0)
            await task

        run(clock, main())
        assert results == {"timeout": False, "event": True}
        assert clock.now() < 1.0  # event win did not burn the 10s timeout

    def test_deadlock_detection(self):
        clock = FakeClock()

        async def main():
            await asyncio.Event().wait()  # never set, no timers pending

        with pytest.raises(RuntimeError, match="deadlock"):
            run(clock, main())


# ----------------------------------------------------------- determinism
class TestDeterminism:
    def test_same_seed_identical_dispatch_decisions(self):
        """Two runs of seed+trace produce the same decision log, twice."""
        kw = dict(
            policy="mlproxy", sla=SLA, workload=WL,
            arrivals=MMPP2(rate_lo=10.0, rate_hi=80.0, mean_lo=20.0,
                           mean_hi=5.0, duration=90.0),
            duration=90.0, seed=42,
        )
        a = run_replay(**kw)
        b = run_replay(**kw)
        assert a.dispatch_log == b.dispatch_log
        assert len(a.dispatch_log) > 10
        np.testing.assert_array_equal(a.e2e_latencies, b.e2e_latencies)
        assert a.summary["p95"] == b.summary["p95"]

    def test_different_seed_differs(self):
        kw = dict(policy="mlproxy", sla=SLA, workload=WL,
                  arrivals=PoissonProcess(rate=30.0, duration=60.0),
                  duration=60.0)
        a = run_replay(seed=0, **kw)
        b = run_replay(seed=1, **kw)
        assert a.dispatch_log != b.dispatch_log


# ----------------------------------------------- admission / backpressure
class TestAdmissionControl:
    def test_max_outstanding_rejects_and_conserves(self):
        """A slow upstream + tight outstanding cap sheds load, loses none."""
        slow = AffineLatency(a=2.0, c=0.0, noise_cv=0.0)
        res = run_replay(
            policy="passthrough", sla=SLA, workload=slow,
            arrivals=PoissonProcess(rate=50.0, duration=20.0), duration=20.0,
            seed=3, config=RuntimeConfig(max_outstanding=10),
            target_concurrency=2,
        )
        c = res.conservation
        assert c["rejected"] > 0
        assert c["lost"] == 0
        assert c["submitted"] == c["completed"] + c["rejected"]

    def test_max_queue_caps_policy_queue(self):
        clock = FakeClock()
        server = AsyncProxyServer(
            clock=clock, config=RuntimeConfig(max_queue=4))
        # static policy that never dispatches before its long timeout:
        # submissions beyond the queue cap must be rejected at the door
        server.add_endpoint(
            "ep", sla=SLA,
            target=SyntheticTarget(WL, clock, rng=np.random.default_rng(0)),
            policy="static", policy_kwargs={"batch_size": 100, "timeout": 60.0},
        )

        async def main():
            await server.start()
            tickets = [server.submit(endpoint="ep") for _ in range(10)]
            rejected = sum(t.rejected for t in tickets)
            await server.drain()
            return rejected

        rejected = run(clock, main())
        assert rejected == 6  # 4 admitted into the queue, rest shed
        assert server.conservation()["lost"] == 0

    def test_no_admission_after_drain(self):
        clock = FakeClock()
        server = AsyncProxyServer(clock=clock)
        server.add_endpoint(
            "ep", sla=SLA,
            target=SyntheticTarget(WL, clock, rng=np.random.default_rng(0)),
            policy="passthrough",
        )

        async def main():
            await server.start()
            server.submit(endpoint="ep")
            await server.drain()
            late = server.submit(endpoint="ep")
            assert late.rejected
            return server.conservation()

        c = run(clock, main())
        assert c["submitted"] == 2
        assert c["completed"] == 1
        assert c["rejected"] == 1


# ------------------------------------------------------------------ drain
class TestDrain:
    @pytest.mark.parametrize("policy", ALL_POLICIES)
    def test_drain_conservation_all_policies(self, policy):
        """No request lost on shutdown for any policy, queued or in-flight.

        ``run_replay`` drains internally and ``drain()`` asserts the
        conservation invariant; this re-checks the ledger explicitly.
        """
        res = run_replay(
            policy=policy, sla=SLA, workload=WL,
            arrivals=PoissonProcess(rate=40.0, duration=30.0), duration=30.0,
            seed=5, policy_kwargs=policy_kwargs(policy),
        )
        c = res.conservation
        assert c["lost"] == 0
        assert c["outstanding"] == 0
        assert c["queued"] == 0
        assert c["submitted"] == c["completed"] + c["rejected"]
        assert res.summary["completed"] > 0

    def test_drain_flushes_queued_requests(self):
        """Requests still queued at drain are flush-dispatched, not dropped."""
        clock = FakeClock()
        server = AsyncProxyServer(clock=clock)
        server.add_endpoint(
            "ep", sla=SLA,
            target=SyntheticTarget(WL, clock, rng=np.random.default_rng(0)),
            policy="static", policy_kwargs={"batch_size": 64, "timeout": 300.0},
        )

        async def main():
            await server.start()
            tickets = [server.submit(endpoint="ep") for _ in range(7)]
            await server.drain()
            return tickets

        tickets = run(clock, main())
        assert all(t.future.done() and not t.rejected for t in tickets)
        assert server.completed == 7
        assert [e[4] for e in server.dispatch_log] == ["flush"]


# --------------------------------------------------------------- targets
class TestTargets:
    def test_synthetic_concurrency_queueing_shows_in_latency(self):
        """With one upstream slot, queueing inflates measured latency."""
        det = AffineLatency(a=0.1, c=0.0, noise_cv=0.0)
        free = run_replay(policy="passthrough", sla=SLA, workload=det,
                          arrivals=PoissonProcess(rate=30.0, duration=10.0),
                          duration=10.0, seed=2)
        queued = run_replay(policy="passthrough", sla=SLA, workload=det,
                            arrivals=PoissonProcess(rate=30.0, duration=10.0),
                            duration=10.0, seed=2, target_concurrency=1)
        assert free.summary["p95"] == pytest.approx(0.1, rel=1e-6)
        assert queued.summary["p95"] > free.summary["p95"] * 2
        assert queued.conservation["lost"] == 0

    def test_wall_clock_short_run(self):
        """A real wall-clock run (no FakeClock) completes and conserves."""
        res = run_replay(
            policy="mlproxy", sla=SLAConfig(slo_target=ms(300)),
            workload=get_workload("sklearn-iris"),
            arrivals=PoissonProcess(rate=60.0, duration=1.0), duration=1.0,
            seed=0, clock=WallClock(),
        )
        assert res.summary["completed"] > 20
        assert res.conservation["lost"] == 0


# ------------------------------------------------------ config-time clamp
class TestPolicyCapClamp:
    def test_mlproxy_cap_clamped_to_bucket(self):
        kw = clamp_policy_kwargs("mlproxy", {}, 32)
        assert kw["optimizer"].max_bs_cap == 32

    def test_mlproxy_proxy_config_clamped(self):
        pc = ProxyConfig(sla=SLA, optimizer=OptimizerConfig(max_bs_cap=256))
        kw = clamp_policy_kwargs("mlproxy", {"proxy_config": pc}, 16)
        assert kw["proxy_config"].optimizer.max_bs_cap == 16

    def test_under_cap_untouched(self):
        opt = OptimizerConfig(max_bs_cap=8)
        kw = clamp_policy_kwargs("mlproxy", {"optimizer": opt}, 32)
        assert kw["optimizer"] is opt

    def test_static_clamped_and_error_mode(self):
        assert clamp_policy_kwargs(
            "static", {"batch_size": 100, "timeout": 0.1}, 32
        )["batch_size"] == 32
        with pytest.raises(ValueError, match="exceeds the largest"):
            clamp_policy_kwargs("static", {"batch_size": 100, "timeout": 0.1},
                                32, mode="error")

    def test_unset_clipper_oracle_cap_never_raises(self):
        """Regression: the caller never set max_cap, so neither mode may
        raise — the policy's implicit default is not a caller choice."""
        for policy in ("clipper", "oracle"):
            kw = clamp_policy_kwargs(policy, {}, 64, mode="error")
            assert kw.get("max_cap") == 64  # default 256 lowered silently
            kw = clamp_policy_kwargs(policy, {}, 64, mode="clamp")
            assert kw.get("max_cap") == 64

    def test_unset_cap_not_injected_when_default_fits(self):
        """Regression: clamping can never *raise* an unset cap — when the
        engine bucket exceeds the policy default, nothing is injected."""
        for policy in ("clipper", "oracle"):
            assert "max_cap" not in clamp_policy_kwargs(policy, {}, 512)

    def test_provided_clipper_cap_still_clamps_and_errors(self):
        assert clamp_policy_kwargs("clipper", {"max_cap": 128}, 32)[
            "max_cap"] == 32
        with pytest.raises(ValueError, match="exceeds the largest"):
            clamp_policy_kwargs("clipper", {"max_cap": 128}, 32, mode="error")

    def test_server_applies_clamp_from_target(self):
        clock = FakeClock()
        server = AsyncProxyServer(clock=clock)
        target = SyntheticTarget(WL, clock, rng=np.random.default_rng(0))
        target.max_batch = 16
        server.add_endpoint("ep", sla=SLA, target=target, policy="mlproxy")
        pol = server.frontend.endpoint("ep").policy
        assert pol.config.optimizer.max_bs_cap == 16


# ------------------------------------------------------------ sim ↔ live
class TestParity:
    def test_mlproxy_parity_on_shared_schedule(self):
        """Same schedule, transparent platform vs synthetic target:
        RT95 / violations / batching within the documented tolerance."""
        duration = 120.0
        times = sample_schedule(PoissonProcess(rate=30.0, duration=duration),
                                7, duration)
        transparent = PlatformConfig(
            container_concurrency=10**6, cold_start=0.0, min_scale=1,
            max_scale=1, initial_scale=1, ps_slowdown=0.0,
            scale_to_zero_grace=1e12,
        )
        sim = run_simulation(policy="mlproxy", sla=SLA, workload=WL,
                             arrivals=Schedule(times),
                             platform_config=transparent,
                             duration=duration, seed=7)
        live = run_replay(policy="mlproxy", sla=SLA, workload=WL,
                          arrivals=Schedule(times), duration=duration, seed=7)
        assert live.summary["completed"] == sim.summary["completed"] == len(times)
        assert live.summary["p95"] == pytest.approx(sim.summary["p95"], rel=0.10)
        assert abs(live.summary["violation_pct"]
                   - sim.summary["violation_pct"]) < 2.0
        assert live.summary["dispatched_batches"] == pytest.approx(
            sim.policy_stats["dispatched_batches"], rel=0.10)

    def test_schedule_replays_identically_in_both_worlds(self):
        """The Schedule process hands both drivers the same instants."""
        times = sample_schedule(PoissonProcess(rate=20.0, duration=30.0),
                                0, 30.0)
        sched = Schedule(times)
        rng = np.random.default_rng(0)
        swept = []
        t = 0.0
        while t < 30.0:
            swept.extend(sched.next_arrivals(t, rng, 7.0).tolist())
            t += 7.0
        np.testing.assert_allclose(swept, times)


# ------------------------------------------------------------ calibration
class TestCalibration:
    def _samples(self, model, buckets=(1, 2, 4, 8), n=200, seed=0):
        rng = np.random.default_rng(seed)
        return {b: [model.sample(b, rng) for _ in range(n)] for b in buckets}

    def test_affine_fit_recovers_noiseless_curve(self):
        truth = AffineLatency(a=0.05, c=0.01, noise_cv=0.0)
        fit = AffineLatency.fit([(b, truth.mean(b)) for b in (1, 2, 4, 8, 16)])
        assert fit.a == pytest.approx(0.05, rel=1e-6)
        assert fit.c == pytest.approx(0.01, rel=1e-6)

    def test_measured_from_samples_and_noise_estimate(self):
        truth = AffineLatency(a=0.05, c=0.01, noise_cv=0.2)
        m = MeasuredLatency.from_samples(self._samples(truth))
        for b in (1, 2, 4, 8):
            assert m.mean(b) == pytest.approx(truth.mean(b), rel=0.05)
        assert m.noise_cv == pytest.approx(0.2, rel=0.3)

    def test_roundtrip_within_10pct(self):
        """Acceptance: measure → fit → simulate reproduces measured means
        within 10% across buckets."""
        truth = get_workload("tfserving-mobilenet")
        calib = Calibration.from_samples(self._samples(truth), source="test")
        errors = calib.verify_roundtrip(rtol=0.10)
        assert set(errors) == {1, 2, 4, 8}

    def test_json_roundtrip(self, tmp_path):
        truth = AffineLatency(a=0.1, c=0.005, noise_cv=0.1)
        calib = Calibration.from_samples(self._samples(truth), source="t")
        path = str(tmp_path / "calib.json")
        calib.save(path)
        loaded = Calibration.load(path)
        assert loaded == calib
        assert loaded.measured_model().mean(4) == pytest.approx(
            calib.measured_model().mean(4))

    def test_live_run_measures_buckets(self):
        """bucket_samples from a live run fit into a usable calibration."""
        res = run_replay(
            policy="mlproxy", sla=SLAConfig(slo_target=ms(1000)),
            workload=get_workload("tfserving-mobilenet"),
            arrivals=PoissonProcess(rate=40.0, duration=60.0), duration=60.0,
            seed=7, policy_kwargs={"bucketing": "pow2"},
        )
        calib = Calibration.from_samples(res.bucket_samples, source="live")
        assert calib.buckets and all(s.n > 0 for s in calib.buckets)
        model = calib.measured_model()
        assert math.isfinite(model.mean(1)) and model.mean(1) > 0


# -------------------------------------------------------------- loadgen
class TestLoadGenerator:
    def test_arrivals_land_on_schedule(self):
        clock = FakeClock()
        server = AsyncProxyServer(clock=clock)
        server.add_endpoint(
            "ep", sla=SLA,
            target=SyntheticTarget(WL, clock, rng=np.random.default_rng(0)),
            policy="passthrough",
        )
        times = np.array([0.5, 1.0, 2.25])
        gen = LoadGenerator(server, Schedule(times), duration=10.0,
                            endpoint="ep")

        async def main():
            await server.start()
            tickets = await gen.run()
            await server.drain()
            return tickets

        tickets = run(clock, main())
        arrivals = [t.request.arrival_time for t in tickets]
        np.testing.assert_allclose(arrivals, times)
