"""End-to-end behaviour tests: paper-claim validation at test scale,
control-plane fault tolerance, elastic restore, engine-in-the-loop serving."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (
    MLProxy,
    MonitorConfig,
    OptimizerConfig,
    ProxyConfig,
    Request,
    SLAConfig,
    ms,
)
from repro.serverless.latency import get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import TraceModulatedPoisson
from repro.simulation.simulator import run_simulation
from repro.simulation.traces import synthetic_trace


def _sim(policy, *, seed=0, duration=900.0, rate=30.0, slo=500.0,
         workload="pytorch-fashion-mnist", trace="wc", platform=None,
         policy_kwargs=None):
    tr = synthetic_trace(trace, duration=duration, seed=seed).scaled(rate)
    return run_simulation(
        policy=policy, sla=SLAConfig(slo_target=ms(slo)),
        workload=get_workload(workload),
        arrivals=TraceModulatedPoisson(tr),
        platform_config=platform or PlatformConfig(initial_scale=1),
        duration=duration, warmup=duration / 5, seed=seed,
        policy_kwargs=policy_kwargs or {},
    ).summary


def test_paper_claim_cost_and_slo_reduction():
    """Paper Table 3 directionally: containers ↓ sharply with violations
    held low and avg batch in the paper's band (T4-like diurnal trace,
    capacity-capped cluster as in the paper's 27-vCPU deployment)."""
    pc = PlatformConfig(initial_scale=1, max_scale=27, cold_start=10.0)
    base = _sim("passthrough", rate=60.0, slo=1000.0, trace="t4", platform=pc)
    prox = _sim("mlproxy", rate=60.0, slo=1000.0, trace="t4", platform=pc)
    reduction = 1 - prox["avg_containers"] / base["avg_containers"]
    assert reduction > 0.5, (base, prox)
    assert prox["violation_pct"] < max(2 * base["violation_pct"], 1.0)
    assert 2.0 < prox["avg_batch_size"] < 20.0


def test_proxy_crash_restart_mid_run():
    """Control-plane fault tolerance: snapshot mid-run, restore into a new
    proxy, behaviour (Max_BS, latency knowledge) carries over."""
    sla = SLAConfig(slo_target=0.5)
    cfg = ProxyConfig(sla=sla, monitor=MonitorConfig(min_samples=1),
                      optimizer=OptimizerConfig(update_interval=5.0))
    sink = []
    proxy = MLProxy(cfg, dispatch_fn=sink.append)
    t = 0.0
    for i in range(200):
        t += 0.02
        proxy.on_request(Request(arrival_time=t), now=t)
        proxy.on_timer(t)
        while sink:
            b = sink.pop()
            proxy.on_response(b, 0.05 + 0.001 * b.size, now=t + 0.06)
    snap = proxy.snapshot()
    learned_bs = proxy.max_bs
    est = proxy.monitor.upstream_percentile(2, now=t)

    proxy2 = MLProxy(cfg, dispatch_fn=sink.append)
    proxy2.restore(snap)
    assert proxy2.max_bs == learned_bs
    assert proxy2.monitor.upstream_percentile(2, now=t) == est
    # and it keeps operating
    proxy2.on_request(Request(arrival_time=t + 1), now=t + 1)
    assert proxy2.scheduler.queue_len >= 0


def test_platform_fault_injection_does_not_lose_requests():
    pc = PlatformConfig(initial_scale=2, failure_prob_per_batch=0.01,
                        straggler_prob=0.02, straggler_mult=4.0,
                        hedge_factor=3.0)
    s = _sim("mlproxy", platform=pc, duration=600.0)
    # all requests that arrived post-warmup completed (at-least-once)
    assert s["completed"] > 0
    assert s["failed_attempts"] >= 0
    assert s["violation_pct"] < 25.0


def test_elastic_checkpoint_restore_other_mesh(tmp_path):
    """Train on the default device, restore onto a 2x2 mesh (subprocess
    with 4 virtual devices)."""
    code = f"""
import dataclasses, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.model import Model
from repro.distributed import checkpoint as ckpt
from repro.distributed.elastic import restore_elastic
cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(), num_layers=2)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
d = {str(tmp_path)!r}
ckpt.save_checkpoint(d, 7, params, metadata={{"arch": cfg.name}})
mesh = jax.make_mesh((2, 2), ("data", "model"))
step, restored, meta = restore_elastic(d, params, mesh, cfg)
assert step == 7 and meta["arch"] == cfg.name
tok = jnp.zeros((2, 8), jnp.int32)
with mesh:
    logits = jax.jit(model.forward)(restored, tok)
ref = model.forward(params, tok)
import numpy as np
np.testing.assert_allclose(np.asarray(logits), np.asarray(ref), atol=2e-4)
print("ELASTIC-OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ELASTIC-OK" in out.stdout


def test_engine_in_the_loop_serving():
    """MLProxy driving the real JAX engine (hybrid sim): batches grow."""
    import jax

    from repro.configs import get_config
    from repro.serving.batcher import EngineBackedLatency
    from repro.serving.engine import EngineConfig, InferenceEngine
    from repro.simulation.arrivals import PoissonProcess

    cfg = get_config("qwen2-0.5b").reduced()
    ecfg = EngineConfig(batch_buckets=(1, 2, 4, 8), prompt_buckets=(16,),
                        max_len=24, gen_len=2)
    eng = InferenceEngine(cfg, ecfg, rng=jax.random.PRNGKey(0))
    lat = EngineBackedLatency(eng, prompt_len=8, gen_len=2)
    res = run_simulation(
        policy="mlproxy", sla=SLAConfig(slo_target=2.0), workload=lat,
        arrivals=PoissonProcess(rate=20.0, duration=25.0),
        platform_config=PlatformConfig(initial_scale=1, cold_start=0.2),
        duration=25.0, seed=0,
        policy_kwargs={"bucketing": "pow2",
                       "optimizer": OptimizerConfig(update_interval=4.0,
                                                    initial_max_bs=2)},
    )
    s = res.summary
    assert s["completed"] > 100
    # real wall-clock engine latencies vary run to run; the claim under
    # test is that batches FORM (>1), not a specific operating point
    assert s["avg_batch_size"] > 1.2
    assert eng.stats["batches"] > 0


def test_replica_pool_elastic_scaling_under_failures():
    import jax

    from repro.configs import get_config
    from repro.serving.engine import EngineConfig, ReplicaPool

    cfg = get_config("qwen2-0.5b").reduced()
    ecfg = EngineConfig(batch_buckets=(1, 2), prompt_buckets=(8,),
                        max_len=16, gen_len=2)
    pool = ReplicaPool(cfg, ecfg, n_replicas=3, rng=jax.random.PRNGKey(0))
    prompts = np.zeros((2, 8), np.int32)
    pool.fail(0)
    pool.fail(2)
    out, timing = pool.generate(prompts)  # only replica 1 healthy
    assert timing["replica"] == 1
    pool.scale_to(4)
    assert pool.n_healthy >= 2
    out2, _ = pool.generate(prompts)
    np.testing.assert_array_equal(out, out2)  # same weights → same greedy
