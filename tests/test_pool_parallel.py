"""ReplicaPool concurrency + ReplicaPoolTarget deadline aborts (no JAX).

The pool's contract after the parallel-dispatch change: concurrent
callers overlap on DIFFERENT replicas (each replica has its own lock),
while calls landing on the SAME replica still serialize — a replica's
compile caches and KV pool are not thread-safe.
"""
import threading

import numpy as np
import pytest

import repro.serving.engine as engine_mod
from repro.core.request import Batch, Request
from repro.serving.batcher import ReplicaPoolTarget
from repro.serving.engine import ReplicaPool


class _BlockingStubEngine:
    """Stub engine whose generate() parks on an event, tracking overlap."""

    entered = 0
    peak = 0
    _mu = threading.Lock()
    release = threading.Event()

    def __init__(self, cfg, engine_cfg, params=None, rng=None):
        self.cfg = cfg
        self.engine_cfg = engine_cfg
        self.params = params if params is not None else object()

    def generate(self, prompts, gen_len=None):
        cls = _BlockingStubEngine
        with cls._mu:
            cls.entered += 1
            cls.peak = max(cls.peak, cls.entered)
        try:
            assert cls.release.wait(timeout=10.0), "stub never released"
        finally:
            with cls._mu:
                cls.entered -= 1
        return prompts[:, :1], {"latency_s": 0.0, "bucket": len(prompts)}


@pytest.fixture
def blocking_pool(monkeypatch):
    monkeypatch.setattr(engine_mod, "InferenceEngine", _BlockingStubEngine)
    _BlockingStubEngine.entered = 0
    _BlockingStubEngine.peak = 0
    _BlockingStubEngine.release = threading.Event()
    return lambda n: ReplicaPool(cfg=None, engine_cfg=None, n_replicas=n,
                                 rng=np.zeros(2))


def _run_concurrent(pool, n_callers):
    threads = [threading.Thread(
        target=lambda: pool.generate(np.zeros((1, 4), np.int32)))
        for _ in range(n_callers)]
    for t in threads:
        t.start()
    return threads


def test_concurrent_callers_overlap_on_distinct_replicas(blocking_pool):
    pool = blocking_pool(3)
    threads = _run_concurrent(pool, 3)
    # all three callers must be INSIDE generate simultaneously — each on
    # its own replica — before anyone is released
    deadline = threading.Event()
    for _ in range(200):
        if _BlockingStubEngine.entered == 3:
            break
        deadline.wait(0.01)
    assert _BlockingStubEngine.entered == 3, "callers serialized"
    _BlockingStubEngine.release.set()
    for t in threads:
        t.join(timeout=10.0)
    assert _BlockingStubEngine.peak == 3


def test_same_replica_calls_serialize(blocking_pool):
    pool = blocking_pool(1)
    threads = _run_concurrent(pool, 3)
    for _ in range(30):
        if _BlockingStubEngine.entered == 1:
            break
        threading.Event().wait(0.01)
    # give the other callers a chance to (wrongly) enter
    threading.Event().wait(0.05)
    assert _BlockingStubEngine.entered == 1, "replica lock not enforced"
    _BlockingStubEngine.release.set()
    for t in threads:
        t.join(timeout=10.0)
    assert _BlockingStubEngine.peak == 1  # never more than one inside


class _CountingStubEngine:
    def __init__(self, cfg, engine_cfg, params=None, rng=None):
        self.cfg = cfg
        self.engine_cfg = engine_cfg
        self.params = params if params is not None else object()
        self.fail = False
        self.calls = 0

    def generate(self, prompts, gen_len=None):
        if self.fail:
            raise RuntimeError("injected replica failure")
        self.calls += 1
        return prompts[:, :1], {"latency_s": 0.001, "bucket": len(prompts)}


def test_failed_replica_lock_is_released(monkeypatch):
    monkeypatch.setattr(engine_mod, "InferenceEngine", _CountingStubEngine)
    pool = ReplicaPool(cfg=None, engine_cfg=None, n_replicas=2,
                       rng=np.zeros(2))
    pool.replicas[0].fail = True
    pool.replicas[1].fail = True
    with pytest.raises(RuntimeError, match="no healthy replicas"):
        pool.generate(np.zeros((1, 4), np.int32))
    # the failover path must not leak a held lock on the failed replicas
    assert all(not lk.locked() for lk in pool._locks)
    pool.recover(0)
    pool.replicas[0].fail = False
    _, timing = pool.generate(np.zeros((1, 4), np.int32))
    assert timing["replica"] == 0


def test_serial_calls_visit_all_replicas(monkeypatch):
    """Idle-preferring acquisition degrades to strict round-robin when
    calls are serial: every replica still serves traffic."""
    monkeypatch.setattr(engine_mod, "InferenceEngine", _CountingStubEngine)
    pool = ReplicaPool(cfg=None, engine_cfg=None, n_replicas=4,
                       rng=np.zeros(2))
    seen = [pool.generate(np.zeros((1, 4), np.int32))[1]["replica"]
            for _ in range(8)]
    assert sorted(set(seen)) == [0, 1, 2, 3]
    assert all(r.calls == 2 for r in pool.replicas)


# ------------------------------------------------------- deadline aborts
class _FakeChunkPool:
    """Stands in for ReplicaPool in the chunked target path: each
    generate() advances a fake clock by 1.0s."""

    class engine_cfg:
        batch_buckets = (1, 2, 4)

    def __init__(self):
        self.now = 0.0
        self.calls = 0

    def clock(self):
        return self.now

    def generate(self, prompts, gen_len=None):
        self.calls += 1
        self.now += 1.0
        return np.ones((len(prompts), 2), np.int32), {
            "latency_s": 1.0, "bucket": len(prompts)}


def _batch(n):
    return Batch(requests=[Request(arrival_time=0.0) for _ in range(n)],
                 dispatch_time=0.0, cause="full")


def test_deadline_aborts_remaining_chunks():
    pool = _FakeChunkPool()
    done = []
    target = ReplicaPoolTarget(pool, prompt_len=4, clock=pool.clock,
                               on_done=lambda b, lat, now: done.append(lat))
    batch = _batch(10)  # chunks of 4, 4, 2
    out, timing = target(batch, deadline=0.5)  # passes after chunk 1
    assert pool.calls == 1
    assert timing["chunks"] == 1
    assert timing["deadline_aborted"] == 6
    assert target.deadline_aborted == 6
    assert out.shape[0] == 10
    for req in batch.requests[:4]:
        assert req.payload is not None and not req.timed_out
    for req in batch.requests[4:]:
        assert req.timed_out and req.payload is None
    assert (out[4:] == 0).all()  # aborted tail rows zero-padded
    assert done == [pytest.approx(1.0)]  # on_done fired once, measured


def test_no_deadline_runs_every_chunk():
    pool = _FakeChunkPool()
    target = ReplicaPoolTarget(pool, prompt_len=4, clock=pool.clock)
    batch = _batch(10)
    _, timing = target(batch)
    assert pool.calls == 3
    assert timing["chunks"] == 3
    assert "deadline_aborted" not in timing
    assert all(r.payload is not None for r in batch.requests)


def test_first_chunk_always_runs_even_past_deadline():
    # the chunk already being formed is dispatched — only FOLLOW-UP
    # chunks are abortable (a JAX dispatch is not interruptible anyway)
    pool = _FakeChunkPool()
    target = ReplicaPoolTarget(pool, prompt_len=4, clock=pool.clock)
    batch = _batch(6)
    _, timing = target(batch, deadline=-1.0)
    assert pool.calls == 1
    assert timing["deadline_aborted"] == 2
    assert not batch.requests[0].timed_out


def test_unchunked_batch_ignores_deadline():
    pool = _FakeChunkPool()
    target = ReplicaPoolTarget(pool, prompt_len=4, clock=pool.clock)
    batch = _batch(4)  # fits the largest bucket: single engine call
    _, timing = target(batch, deadline=-1.0)
    assert pool.calls == 1
    assert all(not r.timed_out for r in batch.requests)
