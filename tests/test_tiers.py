"""Tests for heterogeneous fleet tiers and cost-aware spillover routing.

Covers the ISSUE-10 acceptance points: per-tier AND aggregate ledger
conservation across every policy (with crash + preempt chaos on), the
preempt fault's requeue ordering through the attempt ledger, same-seed
byte-identical router decision logs under FakeClock, and the 1-tier
degenerate case being byte-identical to today's single-fleet runs in
both worlds. Router escalation rules (in-flight cap, queue-depth probe,
latency EWMA + deterministic re-probe) are unit-tested directly.
"""
import numpy as np
import pytest

from repro.core import SLAConfig, ms
from repro.core.frontend import SpilloverRouter, TierRoute
from repro.core.request import Batch, Request, reset_request_ids
from repro.runtime import (AsyncProxyServer, FakeClock, LoadGenerator,
                           RuntimeConfig, SyntheticTarget, run)
from repro.runtime.targets import TieredTarget
from repro.serverless.latency import (AffineLatency, EndpointRoutedLatency,
                                      ScaledLatency, get_workload)
from repro.serverless.platform import PlatformConfig, ServerlessPlatform
from repro.serverless.tiers import TieredPlatform, TierSpec, make_router
from repro.simulation.arrivals import PoissonProcess
from repro.simulation.events import EventQueue
from repro.simulation.simulator import EndpointSpec, run_multi_simulation

from experiments.scenarios import POLICIES

WL = get_workload("sklearn-iris")
SLA = SLAConfig(slo_target=ms(500))


def policy_kwargs(policy):
    if policy == "static":
        return {"batch_size": 8, "timeout": 0.2}
    if policy == "oracle":
        return {"latency_model": lambda bs: WL.percentile(bs, 95)}
    return {}


def _batch(endpoint="ep", size=1, t=0.0, tier=None):
    b = Batch(requests=[Request(arrival_time=t) for _ in range(size)],
              dispatch_time=t, cause="full")
    b.endpoint = endpoint
    b.tier = tier
    return b


# ---------------------------------------------------------------- TierSpec
class TestTierSpec:
    def test_validation(self):
        with pytest.raises(ValueError, match="non-empty name"):
            TierSpec(name="")
        with pytest.raises(ValueError, match="cost_weight"):
            TierSpec(name="t", cost_weight=0.0)
        with pytest.raises(ValueError, match="latency_scale"):
            TierSpec(name="t", latency_scale=-1.0)
        with pytest.raises(ValueError, match="preempt_prob"):
            TierSpec(name="t", preempt_prob=1.5, preemptible=True)
        with pytest.raises(ValueError, match="requires preemptible"):
            TierSpec(name="t", preempt_prob=0.1)

    def test_as_route_carries_guards(self):
        r = TierSpec(name="cheap", cost_weight=0.5, max_inflight=3,
                     queue_depth_max=7, latency_threshold=0.9).as_route()
        assert r == TierRoute(name="cheap", cost_weight=0.5, max_inflight=3,
                              queue_depth_max=7, latency_threshold=0.9)

    def test_effective_config_overrides(self):
        base = PlatformConfig(max_scale=100)
        spec = TierSpec(name="spot", capacity=4, preemptible=True,
                        preempt_prob=0.2)
        cfg = spec.effective_config(base)
        assert cfg.max_scale == 4
        assert cfg.preempt_prob_per_batch == 0.2
        # no overrides → base passes through untouched (same object)
        assert TierSpec(name="plain").effective_config(base) is base

    def test_effective_latency(self):
        base = AffineLatency(a=0.1, c=0.0, noise_cv=0.0)
        assert TierSpec(name="t").effective_latency(base) is base
        scaled = TierSpec(name="t", latency_scale=2.0).effective_latency(base)
        assert scaled.mean(4) == pytest.approx(2.0 * base.mean(4))
        own = AffineLatency(a=0.5, c=0.0)
        spec = TierSpec(name="t", latency=own, latency_scale=3.0)
        assert spec.effective_latency(base) is own  # explicit model wins


class TestScaledLatency:
    def test_scales_every_surface_same_draws(self):
        base = AffineLatency(a=0.1, c=0.01, noise_cv=0.3)
        scaled = ScaledLatency(base=base, scale=2.0)
        b = _batch(size=4)
        assert scaled.mean(4) == pytest.approx(2.0 * base.mean(4))
        assert scaled.mean_batch(b) == pytest.approx(2.0 * base.mean_batch(b))
        assert scaled.percentile(4, 95) == pytest.approx(
            2.0 * base.percentile(4, 95))
        r1, r2 = np.random.default_rng(0), np.random.default_rng(0)
        assert scaled.sample(4, r1) == pytest.approx(2.0 * base.sample(4, r2))
        # draw counts identical: streams stay aligned after the call
        assert r1.random() == r2.random()


# ---------------------------------------------------------------- router
class TestSpilloverRouter:
    def test_validation(self):
        with pytest.raises(ValueError, match="at least one tier"):
            SpilloverRouter([])
        with pytest.raises(ValueError, match="duplicate"):
            SpilloverRouter([TierRoute("a"), TierRoute("a")])

    def test_prefers_cheapest(self):
        r = SpilloverRouter([TierRoute("fast", cost_weight=3.0),
                             TierRoute("cheap", cost_weight=1.0)])
        assert r.tier_names == ("cheap", "fast")
        b = _batch()
        assert r.route(b, 0.0) == "cheap"
        assert b.tier == "cheap"
        assert r.decision_log == [(0.0, "ep", 1, "cheap", "preferred")]
        assert r.spillovers == 0

    def test_inflight_cap_spills_and_release_recovers(self):
        r = SpilloverRouter([TierRoute("cheap", cost_weight=1.0,
                                       max_inflight=1),
                             TierRoute("fast", cost_weight=3.0)])
        assert r.route(_batch(), 0.0) == "cheap"
        assert r.route(_batch(), 1.0) == "fast"   # cap hit → spillover
        assert r.escalations["inflight_cap"] == 1
        assert r.spillovers == 1
        r.on_batch_done("cheap", 0.05, 2.0)       # slot freed
        assert r.route(_batch(), 3.0) == "cheap"
        assert r.decision_log[1][4] == "spillover"

    def test_queue_depth_probe_spills(self):
        depths = {"cheap": 5, "fast": 0}
        r = SpilloverRouter([TierRoute("cheap", cost_weight=1.0,
                                       queue_depth_max=3),
                             TierRoute("fast", cost_weight=3.0)],
                            queue_probe=depths.get)
        assert r.route(_batch(), 0.0) == "fast"
        assert r.escalations["queue_depth"] == 1
        depths["cheap"] = 0
        assert r.route(_batch(), 1.0) == "cheap"

    def test_latency_ewma_spills_then_reprobes(self):
        r = SpilloverRouter([TierRoute("cheap", cost_weight=1.0,
                                       latency_threshold=0.1),
                             TierRoute("fast", cost_weight=3.0)],
                            probe_every=4)
        # poison the cheap tier's EWMA
        r.route(_batch(), 0.0)
        r.on_batch_done("cheap", 5.0, 0.1)
        picks = [r.route(_batch(), float(i)) for i in range(1, 9)]
        # every 4th consecutive latency-skip deterministically re-probes
        assert picks == ["fast", "fast", "fast", "cheap",
                         "fast", "fast", "fast", "cheap"]
        probe_rows = [d for d in r.decision_log if d[4] == "probe"]
        assert len(probe_rows) == 2
        # a healthy probe sample clears the escalation
        r.on_batch_done("cheap", 0.01, 9.0)
        r.on_batch_done("cheap", 0.01, 9.1)
        ema = r._lat_ema["cheap"]
        if ema <= 0.1:
            assert r.route(_batch(), 10.0) == "cheap"

    def test_exhausted_lands_on_most_expensive(self):
        r = SpilloverRouter([TierRoute("cheap", cost_weight=1.0,
                                       max_inflight=1),
                             TierRoute("fast", cost_weight=3.0,
                                       max_inflight=1)])
        assert r.route(_batch(), 0.0) == "cheap"
        assert r.route(_batch(), 1.0) == "fast"
        assert r.route(_batch(), 2.0) == "fast"   # everything guarded
        assert r.decision_log[2][4] == "exhausted"

    def test_release_is_floor_zero_and_unknown_safe(self):
        r = SpilloverRouter([TierRoute("cheap")])
        r.release("cheap")
        r.release("nope")
        r.release(None)
        assert r.stats()["inflight"] == {"cheap": 0}


# ----------------------------------------------- (endpoint, tier) latency
class TestEndpointTierLatency:
    def test_fallback_order(self):
        base = AffineLatency(a=0.1, c=0.0, noise_cv=0.0)
        fast = AffineLatency(a=0.01, c=0.0, noise_cv=0.0)
        lat = EndpointRoutedLatency({"ep": base, ("ep", "fast"): fast})
        assert lat.mean_batch(_batch(tier="fast")) == fast.mean(1)
        # unkeyed tier falls back to the endpoint's tier-agnostic curve
        assert lat.mean_batch(_batch(tier="spot")) == base.mean(1)
        assert lat.mean_batch(_batch(tier=None)) == base.mean(1)

    def test_keyerror_names_both_probes(self):
        lat = EndpointRoutedLatency({("ep", "fast"):
                                     AffineLatency(a=0.01, c=0.0)})
        with pytest.raises(KeyError,
                           match=r"other.*fast.*then.*other.*registered"):
            lat.mean_batch(_batch(endpoint="other", tier="fast"))
        # tier-keyed-only registration: plain-endpoint probe also fails
        with pytest.raises(KeyError, match="registered"):
            lat.mean_batch(_batch(tier=None))


# ------------------------------------------------------- preempt fault
def _mk_platform(**cfg_kw):
    done = []
    events = EventQueue()
    plat = ServerlessPlatform(
        config=PlatformConfig(**cfg_kw),
        latency_model=AffineLatency(a=0.1, c=0.0, noise_cv=0.0),
        events=events,
        rng=np.random.default_rng(0),
        on_batch_done=lambda b, lat, t: done.append((b, lat, t)),
    )
    return plat, events, done


def _drain(events, until=1e9):
    now = 0.0
    while events:
        t, fn = events.pop()
        if t > until:
            break
        now = t
        fn(t)
    return now


class TestPreemptFault:
    def test_preempt_requeues_all_coresident_fifo(self):
        plat, events, done = _mk_platform(
            initial_scale=1, min_scale=1, max_scale=1,
            container_concurrency=3, ps_slowdown=0.0,
        )
        batches = [_batch() for _ in range(3)]
        for b in batches:
            plat.submit(b, 0.0)
        c = plat.containers[0]
        started_order = [a.item.batch for a in c.attempts]
        plat._preempt(c.attempts[0], 0.05)
        assert plat.preemptions == 1
        assert plat.preempted_attempts == 3   # every co-resident victim
        assert plat.failed_attempts == 0      # preempt is not a crash
        requeued = [it.batch for it in plat.pending if it.queued]
        assert requeued == started_order      # oldest re-dispatches first
        cons = plat.assert_conserved()
        assert cons["lost_batches"] == 0
        _drain(events, until=120.0)
        assert len(done) == 3
        plat.assert_conserved(require_drained=True)

    def test_stochastic_preemptions_never_lose_work(self):
        plat, events, done = _mk_platform(
            initial_scale=2, min_scale=1, container_concurrency=4,
            ps_slowdown=0.25, preempt_prob_per_batch=0.3,
        )
        for i in range(50):
            plat.submit(_batch(t=i * 0.05), i * 0.05)
        _drain(events, until=600.0)
        assert len(done) == 50
        assert plat.preemptions > 0           # the fault path actually fired
        cons = plat.assert_conserved(require_drained=True)
        assert cons["requeued_batches"] >= cons["preempted_attempts"]
        assert cons["preemptions"] == plat.preemptions

    def test_cost_integral_is_container_seconds(self):
        plat, events, done = _mk_platform(initial_scale=1, min_scale=1,
                                          max_scale=1)
        plat.submit(_batch(), 0.0)
        _drain(events, until=60.0)
        plat.finalize(60.0)
        assert plat.cost_integral == plat.container_seconds > 0


# --------------------------------------------------------- TieredPlatform
TIERS_2 = (
    TierSpec(name="cheap", cost_weight=1.0, latency_scale=2.0,
             max_inflight=4),
    TierSpec(name="fast", cost_weight=3.0),
)
TIERS_SPOT = (
    TierSpec(name="spot", cost_weight=0.4, preemptible=True,
             preempt_prob=0.15, max_inflight=4),
    TierSpec(name="ondemand", cost_weight=1.0),
)


def _tiered_platform(tiers, **base_kw):
    done = []
    events = EventQueue()
    plat = TieredPlatform(
        tiers,
        latency_model=AffineLatency(a=0.05, c=0.0, noise_cv=0.0),
        events=events,
        rng=np.random.default_rng(0),
        on_batch_done=lambda b, lat, t: done.append((b, lat, t)),
        base_config=PlatformConfig(**base_kw),
        fault_rng=np.random.default_rng(99),
    )
    plat.start(0.0)
    return plat, events, done


class TestTieredPlatform:
    def test_needs_tiers_and_unique_names(self):
        ev = EventQueue()
        kw = dict(latency_model=AffineLatency(a=0.1, c=0.0), events=ev,
                  rng=np.random.default_rng(0),
                  on_batch_done=lambda *a: None)
        with pytest.raises(ValueError, match="at least one tier"):
            TieredPlatform((), **kw)
        with pytest.raises(ValueError, match="duplicate"):
            TieredPlatform((TierSpec(name="a"), TierSpec(name="a")), **kw)

    def test_unstamped_batch_lands_on_cheapest(self):
        plat, events, done = _tiered_platform(TIERS_2)
        b = _batch()
        plat.submit(b, 0.0)
        assert b.tier == "cheap"
        assert plat.default_routed == 1
        assert plat.platforms["cheap"].conservation()["submitted_batches"] == 1

    def test_unknown_tier_raises(self):
        plat, events, done = _tiered_platform(TIERS_2)
        with pytest.raises(KeyError, match="unknown tier 'gpu'"):
            plat.submit(_batch(tier="gpu"), 0.0)

    def test_weighted_cost_integral(self):
        plat, events, done = _tiered_platform(TIERS_2, initial_scale=1,
                                              min_scale=1, max_scale=1)
        plat.submit(_batch(tier="cheap"), 0.0)
        plat.submit(_batch(tier="fast"), 0.0)
        _drain(events, until=60.0)
        plat.finalize(60.0)
        by_tier = plat.cost_by_tier()
        expect = sum(v["cost_integral"] for v in by_tier.values())
        assert plat.cost_integral == pytest.approx(expect)
        assert by_tier["fast"]["cost_integral"] == pytest.approx(
            3.0 * plat.platforms["fast"].container_seconds)
        # unweighted integral is the plain sum of seconds
        assert plat.container_seconds == pytest.approx(
            sum(p.container_seconds for p in plat.platforms.values()))

    def test_conservation_per_tier_and_aggregate_under_faults(self):
        plat, events, done = _tiered_platform(
            TIERS_SPOT, initial_scale=2, min_scale=1,
            container_concurrency=4, ps_slowdown=0.25,
            failure_prob_per_batch=0.05,
        )
        rng = np.random.default_rng(3)
        for i in range(120):
            tier = "spot" if rng.random() < 0.7 else "ondemand"
            plat.submit(_batch(t=i * 0.03, tier=tier), i * 0.03)
        _drain(events, until=900.0)
        assert len(done) == 120
        assert plat.platforms["spot"].preemptions > 0
        agg = plat.assert_conserved(require_drained=True)
        assert agg["submitted_batches"] == 120 == plat.submitted_batches
        by_tier = plat.conservation_by_tier()
        assert sum(c["submitted_batches"] for c in by_tier.values()) == 120
        assert by_tier["ondemand"]["preemptions"] == 0  # tier-scoped fault

    def test_tier_boundary_leak_detected(self):
        plat, events, done = _tiered_platform(TIERS_2)
        plat.submit(_batch(tier="cheap"), 0.0)
        plat.platforms["fast"].submit(_batch(tier="fast"), 0.0)  # bypass
        with pytest.raises(AssertionError, match="tier boundary leak"):
            plat.assert_conserved()


# -------------------------------------------------- sim-world integration
def _sim_spec(policy, tiers, pc=None, rate=40.0):
    return EndpointSpec(
        policy=policy, sla=SLA, workload=WL,
        arrivals=PoissonProcess(rate=rate, duration=40.0),
        policy_kwargs=policy_kwargs(policy),
        platform_config=pc,
        tiers=tiers,
    )


class TestTieredSimulation:
    @pytest.mark.parametrize("policy", POLICIES)
    def test_one_tier_is_byte_identical_to_single_fleet(self, policy):
        kw = dict(duration=40.0, drain_grace=120.0, seed=7)
        reset_request_ids()
        plain = run_multi_simulation({"ep": _sim_spec(policy, None)}, **kw)
        reset_request_ids()
        tiered = run_multi_simulation(
            {"ep": _sim_spec(policy, (TierSpec(name="only"),))}, **kw)
        assert tiered.summary == plain.summary
        assert tiered.endpoints == plain.endpoints
        np.testing.assert_array_equal(tiered.e2e_latencies["ep"],
                                      plain.e2e_latencies["ep"])
        assert plain.tiers == {} and plain.routers == {}
        assert set(tiered.tiers) == {"dedicated:ep"}
        assert tiered.routers["ep"]["decisions"] > 0

    @pytest.mark.parametrize("policy", POLICIES)
    def test_spot_fleet_conserves_per_tier(self, policy):
        pc = PlatformConfig(initial_scale=2, container_concurrency=4,
                            ps_slowdown=0.25, failure_prob_per_batch=0.03)
        res = run_multi_simulation(
            {"ep": _sim_spec(policy, TIERS_SPOT, pc=pc)},
            duration=40.0, drain_grace=240.0, seed=11)
        tiers = res.tiers["dedicated:ep"]
        submitted = sum(t["submitted_batches"] for t in tiers.values())
        completed = sum(t["completed_batches"] for t in tiers.values())
        assert submitted == completed > 0          # drained, nothing lost
        assert res.summary["lost_batches"] == 0
        assert res.summary["duplicate_completions"] == 0
        assert tiers["ondemand"]["preemptions"] == 0
        r = res.routers["ep"]
        assert r["decisions"] == res.endpoints["ep"]["dispatched_batches"]
        assert sum(r["inflight"].values()) == 0

    def test_same_seed_identical_router_decisions(self):
        def one():
            reset_request_ids()
            sim_kw = dict(duration=30.0, drain_grace=120.0, seed=5)
            return run_multi_simulation(
                {"ep": _sim_spec("mlproxy", TIERS_2, rate=80.0)}, **sim_kw)

        a, b = one(), one()
        assert a.routers["ep"] == b.routers["ep"]
        assert a.summary == b.summary

    def test_shared_group_must_agree_on_tiers(self):
        specs = {
            "a": _sim_spec("static", TIERS_2),
            "b": _sim_spec("static", None),
        }
        specs["a"].platform = specs["b"].platform = "shared"
        specs["b"].tiers = (TierSpec(name="other"),)
        with pytest.raises(ValueError, match="disagree on tiers"):
            run_multi_simulation(specs, duration=5.0)


# ------------------------------------------------- live-world integration
def _live_run(seed=0, rate=250.0, duration=4.0):
    reset_request_ids()
    clock = FakeClock()
    server = AsyncProxyServer(clock=clock, config=RuntimeConfig())
    base = AffineLatency(a=0.01, c=0.005, noise_cv=0.0)
    cheap = SyntheticTarget(ScaledLatency(base=base, scale=2.0), clock,
                            rng=np.random.default_rng(1), concurrency=2)
    fast = SyntheticTarget(base, clock, rng=np.random.default_rng(2),
                           concurrency=4)
    target = TieredTarget({"cheap": cheap, "fast": fast}, clock,
                          cost_weights={"cheap": 1.0, "fast": 3.0})
    router = SpilloverRouter([
        TierRoute("cheap", cost_weight=1.0, max_inflight=2),
        TierRoute("fast", cost_weight=3.0),
    ])
    server.add_endpoint("ep", sla=SLA, target=target, policy="static",
                        policy_kwargs={"batch_size": 4, "timeout": 0.02},
                        router=router)
    gen = LoadGenerator(server, PoissonProcess(rate=rate, duration=duration),
                        duration=duration, rng=np.random.default_rng(seed),
                        endpoint="ep")

    async def main():
        await server.start()
        await gen.run()
        await server.drain()

    run(clock, main())
    return server, router, target


class TestTieredRuntime:
    def test_routing_conserves_and_spills(self):
        server, router, target = _live_run()
        server.assert_conserved(require_drained=True)
        ep = server.summary()["endpoints"]["ep"]
        assert ep["router"]["decisions"] > 0
        assert ep["router"]["spillovers"] > 0
        assert sum(ep["router"]["inflight"].values()) == 0  # no slot leaks
        # every dispatched batch landed on exactly one tier
        calls = sum(target.calls.values())
        assert calls == ep["router"]["decisions"]
        assert ep["cost_integral"] == pytest.approx(
            sum(target.cost_weights[n] * target.busy_seconds[n]
                for n in target.targets))
        assert ep["tiers"]["tiers"]["fast"]["cost_weight"] == 3.0

    def test_same_seed_byte_identical_decision_log(self):
        _, r1, _ = _live_run(seed=3)
        _, r2, _ = _live_run(seed=3)
        assert len(r1.decision_log) > 10
        assert r1.decision_log == r2.decision_log
        _, r3, _ = _live_run(seed=4)
        assert r3.decision_log != r1.decision_log

    def test_default_tier_fallback_without_router(self):
        reset_request_ids()
        clock = FakeClock()
        server = AsyncProxyServer(clock=clock, config=RuntimeConfig())
        base = AffineLatency(a=0.01, c=0.0, noise_cv=0.0)
        target = TieredTarget(
            {"cheap": SyntheticTarget(base, clock,
                                      rng=np.random.default_rng(1)),
             "fast": SyntheticTarget(base, clock,
                                     rng=np.random.default_rng(2))},
            clock, cost_weights={"cheap": 1.0, "fast": 3.0})
        server.add_endpoint("ep", sla=SLA, target=target, policy="static",
                            policy_kwargs={"batch_size": 2, "timeout": 0.01})
        gen = LoadGenerator(server, PoissonProcess(rate=100.0, duration=1.0),
                            duration=1.0, rng=np.random.default_rng(0),
                            endpoint="ep")

        async def main():
            await server.start()
            await gen.run()
            await server.drain()

        run(clock, main())
        server.assert_conserved(require_drained=True)
        assert target.default_routed == sum(target.calls.values()) > 0
        assert target.calls["fast"] == 0   # everything on the cheap default
