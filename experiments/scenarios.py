"""Named chaos scenarios for the serverless platform's fault path.

Each :class:`ChaosScenario` is a reproducible fault regime — platform
config (crash probability, stragglers, hedging, scaling knobs), arrival
shape, workload and SLO — that can be run against any batching policy via
:func:`run_scenario`. Every run ends by asserting the platform's
conservation invariant (see
:meth:`~repro.serverless.platform.ServerlessPlatform.assert_conserved`):
every submitted batch completes exactly once, nothing lost, nothing
duplicated, regardless of how many crashes/hedges/drains happened on the
way.

The five regimes target the failure modes the attempt ledger exists for:

* ``crash-storm`` — frequent container crashes with co-resident batches
  (``container_concurrency > 1``): the lost-batch path.
* ``cold-start-storm`` — on/off traffic with slow cold starts and an eager
  scale-to-zero, so work repeatedly lands on an empty fleet.
* ``flash-crowd`` — a 10×-in-minutes ramp that drives panic-mode scaling
  while crashes churn the fleet.
* ``straggler-heavy`` — heavy-tailed service times with hedged duplicates:
  the hedge-storm / duplicate-completion path.
* ``drain-under-load`` — aggressive scale-down under sustained load plus
  crashes, so draining containers die with work in flight.

``benchmarks/bench_chaos.py`` sweeps these scenarios over every policy and
reports violation-rate / cost deltas versus the same scenario with fault
injection disabled.

The LIVE mirror: each :class:`LiveChaosScenario` replays one fault regime
against the wall-clock runtime (:class:`~repro.runtime.AsyncProxyServer`
under :class:`~repro.runtime.clock.FakeClock`) with faults injected at the
dispatch target by :class:`~repro.runtime.faults.FaultyTarget` instead of
inside the platform model. The five live regimes map one-to-one onto the
five :class:`~repro.runtime.faults.FaultConfig` fault kinds (crash /
timeout / straggler / partial / preempt); :func:`run_live_scenario` ends
every run by asserting the runtime's extended conservation invariant
(``submitted == completed + rejected + shed + timed_out + failed``, zero
lost, zero duplicate completions).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import SLAConfig, ms
from repro.serverless.latency import get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import (
    ArrivalProcess,
    PoissonProcess,
    TraceModulatedPoisson,
)
from repro.simulation.simulator import SimResult, Simulator
from repro.simulation.traces import Trace, synthetic_trace

POLICIES = ("passthrough", "static", "clipper", "oracle", "mlproxy")


@dataclasses.dataclass(frozen=True)
class ChaosScenario:
    """One named fault regime: platform knobs + arrival shape + workload."""

    name: str
    description: str
    platform: PlatformConfig
    workload: str = "pytorch-fashion-mnist"
    slo_ms: float = 500.0
    arrival: str = "trace-wc"  # poisson | trace-wc | ramp | onoff
    rate: float = 25.0
    duration: float = 600.0
    drain_grace: float = 240.0
    seed: int = 11

    def baseline_platform(self) -> PlatformConfig:
        """The same scaling regime with fault injection switched off."""
        return dataclasses.replace(
            self.platform,
            failure_prob_per_batch=0.0,
            straggler_prob=0.0,
            hedge_factor=0.0,
        )


def _ramp_trace(duration: float, rate: float) -> Trace:
    """Flash crowd: 10% base load, then a fast ramp to 100% that holds."""
    times = np.linspace(0.0, duration, 25)
    fracs = []
    for t in times[:-1]:
        x = t / duration
        if x < 0.4:
            fracs.append(0.1)
        elif x < 0.6:
            fracs.append(0.1 + 0.9 * (x - 0.4) / 0.2)
        else:
            fracs.append(1.0)
    return Trace(times=times, rates=np.asarray(fracs) * rate)


def _onoff_trace(duration: float, rate: float, period: float = 120.0,
                 duty: float = 0.5) -> Trace:
    """Square-wave traffic: bursts separated by silence (scale-to-zero bait)."""
    edges = [0.0]
    rates = []
    t = 0.0
    while t < duration - 1e-9:
        on_end = min(t + period * duty, duration)
        edges.append(on_end)
        rates.append(rate)
        if on_end >= duration - 1e-9:
            break
        off_end = min(t + period, duration)
        edges.append(off_end)
        rates.append(0.0)
        t = off_end
    return Trace(times=np.asarray(edges), rates=np.asarray(rates))


def make_arrivals(sc: ChaosScenario, duration: float) -> ArrivalProcess:
    """Fresh arrival process for one run of ``sc`` (processes are stateful)."""
    if sc.arrival == "poisson":
        return PoissonProcess(rate=sc.rate, duration=duration)
    if sc.arrival == "trace-wc":
        trace = synthetic_trace("wc", duration=duration, seed=3).scaled(sc.rate)
        return TraceModulatedPoisson(trace)
    if sc.arrival == "ramp":
        return TraceModulatedPoisson(_ramp_trace(duration, sc.rate))
    if sc.arrival == "onoff":
        return TraceModulatedPoisson(_onoff_trace(duration, sc.rate))
    raise ValueError(f"unknown arrival shape {sc.arrival!r}")


SCENARIOS: Dict[str, ChaosScenario] = {
    sc.name: sc
    for sc in (
        ChaosScenario(
            name="crash-storm",
            description="frequent crashes with co-resident batches",
            platform=PlatformConfig(
                initial_scale=2,
                container_concurrency=4,
                ps_slowdown=0.25,
                failure_prob_per_batch=0.08,
            ),
            arrival="trace-wc",
        ),
        ChaosScenario(
            name="cold-start-storm",
            description="bursty on/off traffic, slow cold starts, eager "
                        "scale-to-zero",
            platform=PlatformConfig(
                cold_start=8.0,
                scale_to_zero_grace=10.0,
                container_concurrency=2,
                ps_slowdown=0.25,
                failure_prob_per_batch=0.01,
            ),
            arrival="onoff",
            slo_ms=1000.0,  # cold starts dominate; sub-second is unreachable
        ),
        ChaosScenario(
            name="flash-crowd",
            description="10x ramp in minutes under crash churn",
            platform=PlatformConfig(
                initial_scale=1,
                container_concurrency=2,
                ps_slowdown=0.25,
                failure_prob_per_batch=0.02,
            ),
            arrival="ramp",
            rate=40.0,
        ),
        ChaosScenario(
            name="straggler-heavy",
            description="heavy-tailed service times with capped hedging",
            platform=PlatformConfig(
                initial_scale=2,
                container_concurrency=2,
                ps_slowdown=0.25,
                straggler_prob=0.15,
                straggler_mult=8.0,
                hedge_factor=3.0,
                max_hedges=2,
                failure_prob_per_batch=0.005,
            ),
            arrival="poisson",
        ),
        ChaosScenario(
            name="drain-under-load",
            description="aggressive scale-down drains containers that then "
                        "crash with work in flight",
            platform=PlatformConfig(
                initial_scale=2,
                container_concurrency=2,
                ps_slowdown=0.25,
                max_scale_down_rate=4.0,
                scale_to_zero_grace=10.0,
                failure_prob_per_batch=0.03,
            ),
            arrival="onoff",
        ),
    )
}


def run_scenario(
    scenario: ChaosScenario | str,
    policy: str = "mlproxy",
    *,
    faults: bool = True,
    quick: bool = False,
    seed: Optional[int] = None,
    tracer=None,
    recorder=None,
) -> Tuple[SimResult, dict]:
    """Run one policy through one scenario and enforce conservation.

    Returns ``(SimResult, conservation_dict)``. Raises ``AssertionError``
    if any submitted batch is lost, duplicated, or left undrained.
    ``tracer``/``recorder`` thread the optional observability plane
    (:mod:`repro.obs`) through the simulator.
    """
    if isinstance(scenario, str):
        scenario = SCENARIOS[scenario]
    duration = max(120.0, scenario.duration * 0.25) if quick else scenario.duration
    workload = get_workload(scenario.workload)
    policy_kwargs = {}
    if policy == "static":
        policy_kwargs = {"batch_size": 8, "timeout": 0.2}
    elif policy == "oracle":
        policy_kwargs = {
            "latency_model": lambda bs, _w=workload: _w.percentile(bs, 95)
        }
    sim = Simulator(
        policy=policy,
        sla=SLAConfig(slo_target=ms(scenario.slo_ms)),
        workload=workload,
        arrivals=make_arrivals(scenario, duration),
        platform_config=(
            scenario.platform if faults else scenario.baseline_platform()
        ),
        policy_kwargs=policy_kwargs,
        duration=duration,
        drain_grace=scenario.drain_grace,
        seed=scenario.seed if seed is None else seed,
        tracer=tracer,
        recorder=recorder,
    )
    result = sim.run()
    conservation = sim.platform.assert_conserved(require_drained=True)
    return result, conservation


# --------------------------------------------------------------------------
# live-runtime chaos: the same fault taxonomy against AsyncProxyServer
# --------------------------------------------------------------------------
from repro.runtime import (  # noqa: E402 — live suite; keeps the sim
    AsyncProxyServer,        # section importable without the runtime deps
    BreakerConfig,
    FakeClock,
    FaultConfig,
    FaultyTarget,
    LoadGenerator,
    RuntimeConfig,
    SyntheticTarget,
    run,
)

#: The retry + breaker regime every live scenario runs under. Retries are
#: the recovery mechanism the acceptance gate measures; the breaker keeps
#: a DEAD endpoint from burning its whole queue on hopeless retries — its
#: threshold sits high (0.9) so a noisy-but-alive upstream (25% crash
#: storm) is absorbed by retries alone, while a hard outage (~100%
#: failure) trips it within one window.
LIVE_RUNTIME = RuntimeConfig(
    max_retries=4,
    retry_backoff=0.05,
    retry_backoff_cap=1.0,
    retry_jitter=0.1,
    breaker=BreakerConfig(window=20, min_samples=10,
                          failure_threshold=0.9, open_duration=2.0),
    brownout_queue=8,
)


@dataclasses.dataclass(frozen=True)
class LiveChaosScenario:
    """One live fault regime: a FaultyTarget config + arrival shape."""

    name: str
    description: str
    faults: FaultConfig
    workload: str = "pytorch-fashion-mnist"
    slo_ms: float = 500.0
    #: Deadline budget as a multiple of the SLO — loose enough that a
    #: couple of backed-off retries still fit inside it.
    deadline_factor: float = 8.0
    rate: float = 15.0
    duration: float = 120.0
    runtime: RuntimeConfig = LIVE_RUNTIME
    seed: int = 11

    def baseline_faults(self) -> FaultConfig:
        """The same seed with every injection probability zeroed."""
        return FaultConfig(seed=self.faults.seed)


LIVE_SCENARIOS: Dict[str, LiveChaosScenario] = {
    sc.name: sc
    for sc in (
        LiveChaosScenario(
            name="live-crash-storm",
            description="1 in 4 dispatch attempts dies before completing",
            faults=FaultConfig(crash_prob=0.25, crash_latency=0.01),
        ),
        LiveChaosScenario(
            name="live-timeout-flood",
            description="upstream stalls burn most of the deadline budget",
            faults=FaultConfig(timeout_prob=0.15, timeout_stall=1.0),
        ),
        LiveChaosScenario(
            name="live-straggler-tail",
            description="cold-start slowdowns with no hard failures",
            faults=FaultConfig(straggler_prob=0.2, straggler_delay=0.8),
        ),
        LiveChaosScenario(
            name="live-partial-batch",
            description="batches execute but lose results; whole-batch retry",
            faults=FaultConfig(partial_prob=0.2),
        ),
        LiveChaosScenario(
            name="live-preemption",
            description="the platform reclaims containers mid-execution",
            faults=FaultConfig(preempt_prob=0.25, preempt_after=0.05),
        ),
    )
}


@dataclasses.dataclass
class LiveScenarioResult:
    """Outcome of one :func:`run_live_scenario`."""

    summary: dict
    conservation: dict
    #: the FaultyTarget's (call index, time, kind) schedule
    fault_log: list
    #: the server's (time, endpoint, size, failure #, backoff, error) log
    retry_log: list
    dispatch_log: list


def run_live_scenario(
    scenario: LiveChaosScenario | str,
    policy: str = "mlproxy",
    *,
    faults: bool = True,
    quick: bool = False,
    seed: Optional[int] = None,
    runtime: Optional[RuntimeConfig] = None,
    bare: bool = False,
    tracer=None,
    recorder=None,
) -> LiveScenarioResult:
    """Run one policy through one live fault regime and enforce the
    extended conservation invariant at drain.

    The dispatch target is a :class:`SyntheticTarget` on the workload's
    latency model, wrapped in a :class:`FaultyTarget` carrying the
    scenario's fault config (all-zero probabilities when ``faults`` is
    False — RNG-identical to the bare target). ``runtime`` overrides the
    scenario's retry/breaker regime, and ``bare=True`` skips the
    FaultyTarget wrapper entirely (the bench's byte-identity check runs
    the no-fault case both ways: plain default config on the bare target
    — the pre-fault-tolerance runtime — versus the scenario's retry +
    breaker regime through the zero-probability wrapper).
    """
    if isinstance(scenario, str):
        scenario = LIVE_SCENARIOS[scenario]
    duration = min(45.0, scenario.duration) if quick else scenario.duration
    base_seed = scenario.seed if seed is None else seed
    workload = get_workload(scenario.workload)
    policy_kwargs = {}
    if policy == "static":
        policy_kwargs = {"batch_size": 8, "timeout": 0.2}
    elif policy == "oracle":
        policy_kwargs = {
            "latency_model": lambda bs, _w=workload: _w.percentile(bs, 95)
        }
    clock = FakeClock()
    server = AsyncProxyServer(
        clock=clock,
        config=runtime if runtime is not None else scenario.runtime,
        tracer=tracer,
        recorder=recorder,
    )
    # arrivals/service streams mirror run_replay's named split; the fault
    # stream is FaultyTarget's own third SeedSequence child
    arr_ss, svc_ss = np.random.SeedSequence(base_seed).spawn(2)
    inner = SyntheticTarget(workload, clock,
                            rng=np.random.default_rng(svc_ss))
    fault_cfg = scenario.faults if faults else scenario.baseline_faults()
    fault_cfg = dataclasses.replace(fault_cfg, seed=base_seed)
    if bare:
        if faults:
            raise ValueError("bare=True cannot inject faults")
        target = inner
    else:
        target = FaultyTarget(inner, clock, fault_cfg, tracer=tracer)
    sla = SLAConfig(slo_target=ms(scenario.slo_ms),
                    deadline_factor=scenario.deadline_factor)
    server.add_endpoint("ep", sla=sla, target=target, policy=policy,
                        policy_kwargs=policy_kwargs)
    gen = LoadGenerator(
        server, PoissonProcess(rate=scenario.rate, duration=duration),
        duration=duration, rng=np.random.default_rng(arr_ss), endpoint="ep")

    async def main() -> None:
        await server.start()
        await gen.run()
        await server.drain(timeout=60.0)
        # retrieve every ticket's outcome: TargetError futures otherwise
        # warn "exception was never retrieved" at GC
        for t in gen.tickets:
            if t.future.done():
                t.future.exception()

    run(clock, main())
    conservation = server.assert_conserved(require_drained=True)
    return LiveScenarioResult(
        summary=server.summary(),
        conservation=conservation,
        fault_log=list(getattr(target, "fault_log", [])),
        retry_log=list(server.retry_log),
        dispatch_log=list(server.dispatch_log),
    )
