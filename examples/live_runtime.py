"""Live runtime quickstart: the reverse proxy on a real wall clock.

Runs the paper's loop OUTSIDE the simulator: an asyncio
:class:`AsyncProxyServer` drives MLProxy with real timers, a load
generator replays a Poisson arrival process in real time, and a synthetic
upstream (any latency model; swap in an ``EngineTarget`` for real JAX
replicas) serves the dispatched batches. On shutdown the runtime drains
gracefully and asserts the conservation invariant, then fits the measured
per-bucket latencies into a calibration the simulator can load.

    PYTHONPATH=src python examples/live_runtime.py [--duration 10]
"""
import argparse

from repro.core import SLAConfig, ms
from repro.runtime import Calibration, WallClock, run_replay
from repro.serverless.latency import get_workload
from repro.simulation.arrivals import PoissonProcess


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--rate", type=float, default=40.0)
    p.add_argument("--duration", type=float, default=10.0)
    p.add_argument("--slo-ms", type=float, default=500.0)
    p.add_argument("--calibration-out", default=None,
                   help="write the fitted calibration JSON here")
    args = p.parse_args()

    workload = get_workload("pytorch-fashion-mnist")
    print(f"[live] {args.duration:.0f}s wall-clock run @ {args.rate:.0f} req/s "
          f"(workload {workload.name}, SLO {args.slo_ms:.0f} ms)")
    res = run_replay(
        policy="mlproxy",
        sla=SLAConfig(slo_target=ms(args.slo_ms)),
        workload=workload,
        arrivals=PoissonProcess(rate=args.rate, duration=args.duration),
        duration=args.duration,
        seed=0,
        clock=WallClock(),
        policy_kwargs={"bucketing": "pow2"},
    )
    s = res.summary
    c = res.conservation
    print(f"[live] completed {s['completed']:.0f} requests in "
          f"{len(res.dispatch_log)} batches "
          f"(avg batch {s['avg_batch_size']:.2f}, "
          f"P95 {s['p95']*1000:.0f} ms, violations {s['violation_pct']:.2f}%)")
    print(f"[live] conservation: submitted={c['submitted']} "
          f"completed={c['completed']} rejected={c['rejected']} "
          f"lost={c['lost']}")
    assert c["lost"] == 0 and c["submitted"] == c["completed"] + c["rejected"]

    calib = Calibration.from_samples(res.bucket_samples, source="live:example")
    print(f"[live] calibration fit over buckets "
          f"{[b.bucket for b in calib.buckets]}: "
          f"s(b) ≈ {calib.affine_a*1000:.1f} + {calib.affine_c*1000:.2f}·b ms "
          f"(noise CV {calib.noise_cv:.3f})")
    if args.calibration_out:
        calib.save(args.calibration_out)
        print(f"[live] wrote {args.calibration_out}")


if __name__ == "__main__":
    main()
