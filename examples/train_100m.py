"""Train a ~100M-parameter qwen2-family model for a few hundred steps on
CPU, with checkpoint/restart (kill it mid-run and re-invoke: it resumes
from the last committed step, including the data-iterator position).

    PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.train import TrainConfig, train
from repro.optim.adamw import AdamWConfig


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--checkpoint-dir", default="/tmp/repro_train_100m")
    args = p.parse_args()

    # ~100M params: qwen2 family at reduced width/depth
    cfg = dataclasses.replace(
        get_config("qwen2-0.5b"),
        name="qwen2-100m",
        num_layers=6,
        d_model=512,
        num_heads=8,
        num_kv_heads=2,
        head_dim=64,
        d_ff=2048,
        vocab_size=65536,
        max_seq_len=512,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
        attn_q_chunk=128,
    )
    print(f"[example] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    out = train(
        cfg,
        TrainConfig(steps=args.steps, log_every=10, checkpoint_every=50,
                    checkpoint_dir=args.checkpoint_dir,
                    optimizer=AdamWConfig(learning_rate=1e-3)),
        DataConfig(seq_len=128, global_batch=8, vocab_size=cfg.vocab_size),
    )
    print(f"[example] loss {out['first_loss']:.3f} → {out['final_loss']:.3f} "
          f"over {out['steps']} steps")


if __name__ == "__main__":
    main()
