"""End-to-end serving driver: MLProxy fronting the REAL JAX engine.

Hybrid loop: simulated Poisson arrivals drive the proxy; every dispatched
batch executes a real bucketed prefill+decode on this host (the measured
wall time IS the upstream latency the monitor learns from). Demonstrates:
batch-size bucketing, the compile cache, adaptive Max_BS growth, and the
replica pool's failover.

    PYTHONPATH=src python examples/serve_engine.py [--requests 300]
"""
import argparse

import jax

from repro.configs import get_config
from repro.core import SLAConfig
from repro.serverless.platform import PlatformConfig
from repro.serving.batcher import EngineBackedLatency
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.simulation.arrivals import PoissonProcess
from repro.simulation.simulator import run_simulation


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="qwen2-0.5b")
    p.add_argument("--rate", type=float, default=40.0)
    p.add_argument("--duration", type=float, default=60.0)
    p.add_argument("--slo-ms", type=float, default=2000.0)
    args = p.parse_args()

    cfg = get_config(args.arch).reduced()
    ecfg = EngineConfig(batch_buckets=(1, 2, 4, 8, 16, 32),
                        prompt_buckets=(16,), max_len=32, gen_len=4)
    engine = InferenceEngine(cfg, ecfg, rng=jax.random.PRNGKey(0))
    print(f"[serve] warming compile cache for {cfg.name} "
          f"(buckets {ecfg.batch_buckets}) ...")
    engine.warmup(plen=16)
    print(f"[serve] {engine.compile_count} compiled programs cached")

    latency = EngineBackedLatency(engine, prompt_len=16, gen_len=4)
    sla = SLAConfig(slo_target=args.slo_ms / 1000.0)
    from repro.core import OptimizerConfig

    res = run_simulation(
        policy="mlproxy",
        sla=sla,
        workload=latency,  # real JAX execution per dispatched batch
        arrivals=PoissonProcess(rate=args.rate, duration=args.duration),
        platform_config=PlatformConfig(initial_scale=1, cold_start=0.5),
        duration=args.duration,
        seed=0,
        policy_kwargs={
            "bucketing": "pow2",
            # faster AIMD cadence so short demo runs show batch growth
            "optimizer": OptimizerConfig(update_interval=5.0, initial_max_bs=2),
        },
    )
    s = res.summary
    print(f"\n[serve] completed {s['completed']:.0f} requests "
          f"({engine.stats['batches']:.0f} real JAX batches, "
          f"{engine.stats['tokens']:.0f} tokens generated)")
    print(f"[serve] avg batch {s['avg_batch_size']:.2f}, "
          f"P95 {s['p95']*1000:.0f} ms, violations {s['violation_pct']:.2f}%, "
          f"avg containers {s['avg_containers']:.2f}")
    print(f"[serve] padding waste is visible in engine timings; "
          f"the monitor keys latency windows by bucket (TPU adaptation)")


if __name__ == "__main__":
    main()
