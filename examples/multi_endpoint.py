"""Multi-endpoint quickstart: one proxy process, two SLA classes.

A tight-SLO small model and a loose-SLO large model share one
:class:`~repro.core.frontend.ProxyFrontend`; each endpoint runs its own
MLProxy instance and converges to its own Max_BS. Run:

    PYTHONPATH=src python examples/multi_endpoint.py
"""
from repro.core import SLAConfig, ms
from repro.serverless.latency import get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import PoissonProcess
from repro.simulation.simulator import EndpointSpec, run_multi_simulation


def main() -> None:
    duration = 600.0
    specs = {
        "iris-tight": EndpointSpec(
            policy="mlproxy",
            sla=SLAConfig(slo_target=ms(200)),
            workload=get_workload("sklearn-iris"),
            arrivals=PoissonProcess(rate=60.0, duration=duration),
            platform_config=PlatformConfig(initial_scale=1),
        ),
        "resnet-loose": EndpointSpec(
            policy="mlproxy",
            sla=SLAConfig(slo_target=ms(1500)),
            workload=get_workload("tfserving-resnet"),
            arrivals=PoissonProcess(rate=8.0, duration=duration),
            platform_config=PlatformConfig(initial_scale=1),
        ),
    }
    res = run_multi_simulation(specs, duration=duration, warmup=duration / 5,
                               seed=0)
    print(f"fleet: {res.summary['avg_containers']:.2f} avg containers, "
          f"{res.summary['completed']:.0f} requests, "
          f"{res.summary['violation_pct']:.2f}% violations overall")
    for name, s in res.endpoints.items():
        print(f"  {name:13s} SLO {s['slo_target']*1000:6.0f} ms  "
              f"viol {s['violation_pct']:6.3f}%  "
              f"avg BS {s['avg_batch_size']:5.2f}  "
              f"Max_BS {s['max_bs']:4.0f}  p95 {s['p95']*1000:7.1f} ms")


if __name__ == "__main__":
    main()
