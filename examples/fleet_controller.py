"""Fleet-scale control plane: one jitted call drives MLProxy decisions for
4096 endpoints at once (the "provider ships MLProxy in their API gateway"
deployment from the paper's §6, at cloud scale).

    PYTHONPATH=src python examples/fleet_controller.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jax_controller as jc


def main() -> None:
    n = 4096
    state = jc.init_fleet(n, n_buckets=16, window=64, initial_max_bs=1.0)
    rng = np.random.default_rng(0)
    slo = jnp.asarray(rng.uniform(0.2, 2.0, n), jnp.float32)

    # feed synthetic latency observations: each endpoint has its own
    # sub-linear curve s(b) = a + c·b
    a = rng.uniform(0.02, 0.15, n)
    c = rng.uniform(0.001, 0.01, n)
    print(f"[fleet] {n} endpoints, heterogeneous SLOs and latency curves")

    for round_ in range(12):
        # simulate one optimizer interval: observations at current max_bs
        bs = np.asarray(jc.effective_max_bs(state))
        lat = (a + c * bs) * rng.lognormal(0, 0.1, n)
        for _ in range(4):  # a few observations per endpoint per interval
            state = jc.record_upstream(
                state, jnp.arange(n), jnp.minimum(bs, 15), jnp.asarray(lat, jnp.float32))
            state = jc.record_e2e(state, jnp.arange(n), jnp.asarray(lat * 1.3, jnp.float32))
            state = jc.record_dispatch(state, jnp.arange(n),
                                       jnp.asarray(rng.random(n) < 0.3))
        t0 = time.perf_counter()
        state = jc.aimd_step(state, slo)
        jax.block_until_ready(state.max_bs)
        dt = time.perf_counter() - t0
        eff = np.asarray(jc.effective_max_bs(state))
        print(f"[fleet] interval {round_:2d}: AIMD over {n} endpoints in "
              f"{dt*1e3:6.2f} ms | max_bs p50={np.median(eff):.0f} "
              f"p95={np.percentile(eff, 95):.0f} max={eff.max()}")

    d, to = jc.timeout_step(state, jnp.ones((n,), jnp.int32),
                            jnp.zeros((n,), jnp.float32), slo)
    print(f"[fleet] timeout decisions: dispatch-now for {int(d.sum())} "
          f"endpoints, median TO {float(jnp.median(to))*1e3:.0f} ms")


if __name__ == "__main__":
    main()
