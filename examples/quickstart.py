"""Quickstart: MLProxy in 60 seconds.

Runs the paper's core loop end-to-end on a simulated serverless platform:
Poisson arrivals → MLProxy (adaptive batching, Algorithms 1+2) → Knative-
like autoscaled backend, and prints the cost/SLO comparison against a
stock API gateway.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import SLAConfig, ms
from repro.serverless.latency import get_workload
from repro.serverless.platform import PlatformConfig
from repro.simulation.arrivals import PoissonProcess
from repro.simulation.simulator import run_simulation


def main() -> None:
    sla = SLAConfig(slo_target=ms(500))  # P95 ≤ 500 ms
    workload = get_workload("pytorch-fashion-mnist")  # Table-2 workload

    print(f"workload: {workload.name}, s(1)={workload.mean(1)*1000:.0f} ms, "
          f"s(16)={workload.mean(16)*1000:.0f} ms  (sub-linear → batchable)")
    print(f"SLO: P95 ≤ {sla.slo_target*1000:.0f} ms\n")

    for policy in ("passthrough", "mlproxy"):
        res = run_simulation(
            policy=policy,
            sla=sla,
            workload=workload,
            arrivals=PoissonProcess(rate=30.0, duration=900.0),
            platform_config=PlatformConfig(initial_scale=1),
            duration=900.0,
            warmup=180.0,
            seed=0,
        )
        s = res.summary
        label = "stock gateway" if policy == "passthrough" else "MLProxy    "
        print(f"{label}: avg containers {s['avg_containers']:5.2f}  "
              f"SLO violations {s['violation_pct']:6.3f}%  "
              f"avg batch {s['avg_batch_size']:5.2f}  "
              f"P95 {s['p95']*1000:4.0f} ms")


if __name__ == "__main__":
    main()
