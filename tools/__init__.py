"""Developer tooling for the repo (not shipped with ``src/repro``)."""
