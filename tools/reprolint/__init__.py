"""reprolint — a dependency-free AST linter for this repo's invariants.

The repo's core guarantees (bit-identical FakeClock replays, named-stream
RNG determinism, the exactly-once conservation ledger) are enforced
dynamically by benches and parity suites. ``reprolint`` moves them to
static, CI-time checks: a stray ``time.monotonic()`` or fire-and-forget
``asyncio.create_task`` is rejected before it can silently skew a replay.

Usage::

    python -m tools.reprolint src benchmarks experiments
    python -m tools.reprolint src --format json --output report.json
    python -m tools.reprolint --list-rules

Exit status is 0 when every finding is either suppressed inline or
covered by the baseline, 1 otherwise (2 on usage errors).

Rules
-----
Determinism:

``wallclock``
    Any reference (not just call — a default argument like
    ``clock=time.monotonic`` counts) to ``time.time/monotonic/
    perf_counter/process_time`` (and ``*_ns`` variants) or
    ``datetime.now/utcnow/today`` outside the sanctioned wall-clock
    seams: ``runtime/clock.py`` (THE seam), measurement modules
    (``serving/engine.py``, ``runtime/calibrate.py``, ``launch/``) and
    the ``benchmarks/`` harness. Everything else must take a ``Clock``
    or an injected ``clock`` callable.

``sleep-literal``
    ``asyncio.sleep(<nonzero literal>)`` outside ``runtime/clock.py``.
    Real durations must go through ``Clock.sleep`` so FakeClock replays
    stay virtual; ``asyncio.sleep(0)`` (a bare event-loop yield) is
    always allowed.

``unseeded-rng``
    In ``src/repro``: any use of the stdlib ``random`` module, a
    zero-argument ``np.random.default_rng()``, or the legacy NumPy
    global-state API (``np.random.seed/rand/randn/...``). All randomness
    must flow through named ``SeedSequence`` streams passed in
    explicitly. ``jax.random`` (explicit-key API) is not flagged.

Async-safety:

``dropped-task``
    ``asyncio.create_task(...)`` / ``ensure_future(...)`` /
    ``loop.create_task(...)`` used as a bare expression statement. The
    event loop holds only a weak reference to tasks, so a dropped task
    can be garbage-collected mid-flight; keep a reference and discard it
    in a done-callback (see ``runtime/server.py``'s ``_batch_tasks``).

``blocking-in-async``
    ``time.sleep``, ``subprocess.*``, ``os.system``, or builtin
    ``open()`` called inside an ``async def`` body — these block the
    event loop and stall every in-flight request.

``await-in-lock``
    ``await`` inside a synchronous ``with`` block whose context manager
    looks like a lock (name contains ``lock``/``mutex`` or is a
    ``threading.Lock()``/``RLock()`` call). A threading lock held across
    an ``await`` deadlocks as soon as the resumed coroutine lands on
    another waiter; use ``asyncio.Lock`` with ``async with``.

Protocol & ledger discipline:

``policy-protocol``
    Every class the ``make_policy`` factory can return must statically
    define the full ``Policy`` protocol surface (``on_request``,
    ``on_response``, ``on_timer``, ``expire``, ``next_event_time``,
    ``flush``, ``stats``, ``snapshot``, ``restore``, ``max_bs``,
    ``queue_len``). Required members are read from the ``Policy``
    Protocol class itself, so extending the protocol automatically
    extends the check; inherited members (bases resolved by name across
    the linted tree) count.

``ledger-counter``
    In the ledger modules (``serverless/platform.py``,
    ``runtime/server.py``): every monotonic counter — an attribute only
    ever ``self.x += <int literal>``, never decremented — must be read
    in that class's ``summary()``, ``stats()``, or ``conservation()``
    method. A counter that never surfaces is invisible to the
    conservation checks and to operators.

``slots-dataclass``
    Hot-path dataclasses under ``src/repro/simulation/`` must declare
    ``@dataclass(slots=True)`` — per-event allocations make ``__dict__``
    overhead measurable in the event-core benchmark.

Suppressions
------------
Append ``# reprolint: disable=RULE`` (comma-separate several rules, or
``disable=all``) to the offending line::

    t0 = time.monotonic()  # reprolint: disable=wallclock

Baseline
--------
``tools/reprolint/baseline.json`` grandfathers pre-existing findings so
the linter can gate CI while old debt is paid down incrementally. Each
entry carries a mandatory human ``justification``. Entries match on
``(rule, path, message)`` — line numbers are deliberately excluded so
unrelated edits don't churn the baseline. Regenerate with
``--write-baseline`` (then fill in the justifications), and delete
entries as the underlying findings are fixed; stale entries are reported
as warnings. The checked-in baseline is empty: the tree is clean.

Adding a rule
-------------
1. Write a function in ``tools/reprolint/rules.py`` decorated with
   ``@rule("my-rule", "one-line description")``. It receives the
   :class:`~tools.reprolint.engine.Project` and yields
   :class:`~tools.reprolint.engine.Finding` objects — use
   ``project.files`` for per-file AST walks and
   ``FileContext.qualified_name`` to resolve imports/aliases.
2. Add an inline-fixture test in ``tests/test_reprolint.py`` covering a
   positive hit, a suppressed hit, and (if applicable) a whitelisted
   path.
3. Run ``python -m tools.reprolint src benchmarks experiments`` and fix
   or baseline (with justification) anything the new rule surfaces.
"""
from tools.reprolint.engine import (  # noqa: F401
    Finding,
    LintConfig,
    Project,
    lint_paths,
    lint_sources,
)
from tools.reprolint import rules as _rules  # noqa: F401  (registers rules)
from tools.reprolint.engine import RULES  # noqa: F401
