"""Rule engine: file loading, alias resolution, suppressions, baseline.

The engine is deliberately dependency-free (stdlib ``ast`` only) so the
lint job needs nothing but a Python interpreter. Rules are plain
functions registered with :func:`rule`; each receives the whole
:class:`Project` and yields :class:`Finding` objects, so per-file rules
and cross-file rules (protocol conformance) share one interface.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Set, Tuple

#: rule name -> (description, check function)
RULES: Dict[str, "Rule"] = {}

_SUPPRESS_RE = re.compile(r"#\s*reprolint:\s*disable=([A-Za-z0-9_,\-\s]+)")


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    description: str
    check: Callable[["Project", "LintConfig"], Iterator["Finding"]]


def rule(name: str, description: str):
    """Decorator registering a rule function in :data:`RULES`."""

    def _register(fn):
        RULES[name] = Rule(name=name, description=description, check=fn)
        return fn

    return _register


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    @property
    def key(self) -> Tuple[str, str, str]:
        # line/col deliberately excluded: baseline entries must survive
        # unrelated edits that shift line numbers
        return (self.rule, self.path, self.message)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class LintConfig:
    """Path whitelists and anchor points for the rule set.

    Path semantics: entries ending in ``/`` are prefix matches against
    the posix-relative path being linted; other entries match exactly.
    ``ledger_modules`` / ``protocol_module`` / ``registry_module`` are
    *suffix* matches so the rules find their anchor files regardless of
    whether the tree is linted as ``src`` or ``src/repro``.
    """

    # determinism ------------------------------------------------------
    #: modules allowed to touch the wall clock: the Clock seam itself,
    #: real-measurement modules (engine timing, calibration, launch
    #: scripts) and the benchmark harness
    wallclock_allowed: Tuple[str, ...] = (
        "src/repro/runtime/clock.py",
        "src/repro/runtime/calibrate.py",
        "src/repro/serving/engine.py",
        "src/repro/launch/",
        "benchmarks/",
    )
    #: modules allowed to asyncio.sleep a literal duration (the seam)
    sleep_allowed: Tuple[str, ...] = ("src/repro/runtime/clock.py",)
    #: subtree where all randomness must flow through named streams
    rng_scope: Tuple[str, ...] = ("src/repro/",)
    # protocol & ledger ------------------------------------------------
    protocol_module: str = "core/batch_queue.py"
    protocol_class: str = "Policy"
    registry_module: str = "core/policies.py"
    registry_func: str = "make_policy"
    #: ledger classes live here; counters must surface in reporting
    ledger_modules: Tuple[str, ...] = (
        "serverless/platform.py",
        "runtime/server.py",
    )
    ledger_reporting_methods: Tuple[str, ...] = (
        "summary",
        "stats",
        "conservation",
    )
    #: subtree whose dataclasses must declare slots=True
    slots_paths: Tuple[str, ...] = ("src/repro/simulation/",)
    # observability ----------------------------------------------------
    #: instrumented modules (suffix match): every class-level monotonic
    #: counter here must be bound into the MetricsRegistry via a
    #: binding method, or it is invisible to the metrics plane
    metrics_modules: Tuple[str, ...] = (
        "core/batch_queue.py",
        "core/frontend.py",
        "core/monitor.py",
        "runtime/server.py",
        "runtime/breaker.py",
        "runtime/faults.py",
        "serverless/platform.py",
        "serverless/tiers.py",
    )
    #: method names whose attribute reads count as "bound" (the
    #: ``registry.bind(name, lambda: self.counter)`` convention)
    metrics_binding_methods: Tuple[str, ...] = ("register_metrics",)

    # --- path helpers -------------------------------------------------
    @staticmethod
    def path_in(path: str, entries: Iterable[str]) -> bool:
        for entry in entries:
            if entry.endswith("/"):
                if path.startswith(entry):
                    return True
            elif path == entry:
                return True
        return False


class FileContext:
    """One parsed source file: AST, import aliases, suppressions."""

    def __init__(self, path: str, source: str) -> None:
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.aliases = self._collect_aliases(self.tree)
        self.suppressions = self._collect_suppressions(source)

    @staticmethod
    def _collect_aliases(tree: ast.AST) -> Dict[str, str]:
        """Map local binding -> fully qualified module path.

        ``import numpy as np`` binds ``np -> numpy``; ``from time import
        monotonic as mono`` binds ``mono -> time.monotonic``. Only the
        root binding matters — :meth:`qualified_name` extends it through
        attribute chains.
        """
        aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    root = alias.name.split(".")[0]
                    aliases[alias.asname or root] = (
                        alias.name if alias.asname else root)
            elif isinstance(node, ast.ImportFrom) and node.module:
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    aliases[alias.asname or alias.name] = (
                        f"{node.module}.{alias.name}")
        return aliases

    @staticmethod
    def _collect_suppressions(source: str) -> Dict[int, Set[str]]:
        out: Dict[int, Set[str]] = {}
        for lineno, line in enumerate(source.splitlines(), start=1):
            m = _SUPPRESS_RE.search(line)
            if m:
                out[lineno] = {
                    name.strip() for name in m.group(1).split(",")
                    if name.strip()}
        return out

    def qualified_name(self, node: ast.AST) -> Optional[str]:
        """Resolve a Name/Attribute chain to its imported dotted path.

        Returns None for anything not rooted in an import binding
        (locals, ``self.x``, call results), which is exactly what keeps
        the determinism rules from flagging injected ``clock()`` calls.
        """
        if isinstance(node, ast.Name):
            return self.aliases.get(node.id)
        if isinstance(node, ast.Attribute):
            base = self.qualified_name(node.value)
            if base is None:
                return None
            return f"{base}.{node.attr}"
        return None

    def suppressed(self, finding: Finding) -> bool:
        names = self.suppressions.get(finding.line)
        if not names:
            return False
        return "all" in names or finding.rule in names


class Project:
    """The set of files under lint, plus unparsable-file records."""

    def __init__(self, files: List[FileContext],
                 parse_errors: List[Finding]) -> None:
        self.files = files
        self.parse_errors = parse_errors
        self._by_path = {f.path: f for f in files}

    def find_module(self, suffix: str) -> Optional[FileContext]:
        """First file whose path ends with ``suffix`` (posix)."""
        for f in self.files:
            if f.path == suffix or f.path.endswith("/" + suffix):
                return f
        return None

    def class_index(self) -> Dict[str, Tuple[FileContext, ast.ClassDef]]:
        """Class name -> defining (file, node), first definition wins."""
        index: Dict[str, Tuple[FileContext, ast.ClassDef]] = {}
        for f in self.files:
            for node in ast.walk(f.tree):
                if isinstance(node, ast.ClassDef) and node.name not in index:
                    index[node.name] = (f, node)
        return index


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]
    suppressed: int
    files_checked: int


def _build_project(sources: Dict[str, str]) -> Project:
    files: List[FileContext] = []
    errors: List[Finding] = []
    for path in sorted(sources):
        try:
            files.append(FileContext(path, sources[path]))
        except SyntaxError as exc:
            errors.append(Finding(
                rule="parse-error", path=path, line=exc.lineno or 1,
                col=exc.offset or 0, message=f"cannot parse: {exc.msg}"))
    return Project(files, errors)


def run_rules(project: Project, config: LintConfig,
              only: Optional[Iterable[str]] = None) -> LintResult:
    selected = sorted(only) if only else sorted(RULES)
    raw: List[Finding] = list(project.parse_errors)
    for name in selected:
        raw.extend(RULES[name].check(project, config))
    kept: List[Finding] = []
    suppressed = 0
    for finding in raw:
        ctx = project._by_path.get(finding.path)
        if ctx is not None and ctx.suppressed(finding):
            suppressed += 1
        else:
            kept.append(finding)
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return LintResult(findings=kept, suppressed=suppressed,
                      files_checked=len(project.files))


def lint_sources(sources: Dict[str, str],
                 config: Optional[LintConfig] = None,
                 only: Optional[Iterable[str]] = None) -> LintResult:
    """Lint in-memory sources (test fixtures): ``{posix path: source}``."""
    return run_rules(_build_project(sources), config or LintConfig(),
                     only=only)


def iter_python_files(paths: Iterable[str]) -> Iterator[Path]:
    for raw in paths:
        p = Path(raw)
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for sub in sorted(p.rglob("*.py")):
                if any(part.startswith(".") or part == "__pycache__"
                       for part in sub.parts):
                    continue
                yield sub


def lint_paths(paths: Iterable[str],
               config: Optional[LintConfig] = None,
               only: Optional[Iterable[str]] = None,
               root: Optional[Path] = None) -> LintResult:
    """Lint files/directories on disk; paths recorded relative to root."""
    root = (root or Path.cwd()).resolve()
    sources: Dict[str, str] = {}
    for file in iter_python_files(paths):
        resolved = file.resolve()
        try:
            rel = resolved.relative_to(root).as_posix()
        except ValueError:
            rel = file.as_posix()
        sources[rel] = resolved.read_text(encoding="utf-8")
    return run_rules(_build_project(sources), config or LintConfig(),
                     only=only)


# --------------------------------------------------------------- baseline
def load_baseline(path: Path) -> List[dict]:
    data = json.loads(path.read_text(encoding="utf-8"))
    entries = data.get("entries", [])
    for entry in entries:
        for field in ("rule", "path", "message", "justification"):
            if field not in entry:
                raise ValueError(
                    f"baseline entry missing '{field}': {entry!r}")
    return entries


def save_baseline(path: Path, entries: List[dict]) -> None:
    payload = {
        "comment": ("Grandfathered reprolint findings. Every entry needs a "
                    "human justification; delete entries as findings are "
                    "fixed. Matched on (rule, path, message)."),
        "entries": sorted(entries,
                          key=lambda e: (e["path"], e["rule"], e["message"])),
    }
    path.write_text(json.dumps(payload, indent=2) + "\n", encoding="utf-8")


def apply_baseline(findings: List[Finding], entries: List[dict]
                   ) -> Tuple[List[Finding], List[Finding], List[dict]]:
    """Split findings into (fresh, baselined); also return stale entries."""
    keyed = {(e["rule"], e["path"], e["message"]): e for e in entries}
    fresh: List[Finding] = []
    baselined: List[Finding] = []
    used: Set[Tuple[str, str, str]] = set()
    for finding in findings:
        if finding.key in keyed:
            baselined.append(finding)
            used.add(finding.key)
        else:
            fresh.append(finding)
    stale = [e for k, e in keyed.items() if k not in used]
    return fresh, baselined, stale
