"""The rule set. See the package docstring for what each rule protects.

Every rule is a generator taking ``(project, config)`` and yielding
:class:`~tools.reprolint.engine.Finding`. Per-file rules iterate
``project.files``; the protocol rule is cross-file (it resolves base
classes through ``project.class_index()``).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Set, Tuple

from tools.reprolint.engine import (
    FileContext,
    Finding,
    LintConfig,
    Project,
    rule,
)

# ----------------------------------------------------------- determinism
WALLCLOCK_NAMES = frozenset({
    "time.time", "time.time_ns",
    "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "time.process_time", "time.process_time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
})


@rule("wallclock",
      "wall-clock reference outside the Clock seam / measurement modules")
def check_wallclock(project: Project, config: LintConfig
                    ) -> Iterator[Finding]:
    for ctx in project.files:
        if LintConfig.path_in(ctx.path, config.wallclock_allowed):
            continue
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.Name, ast.Attribute)):
                q = ctx.qualified_name(node)
                if q in WALLCLOCK_NAMES:
                    yield Finding(
                        rule="wallclock", path=ctx.path,
                        line=node.lineno, col=node.col_offset,
                        message=(f"reference to {q}; inject a Clock (or a "
                                 "clock callable) instead so FakeClock "
                                 "replays stay deterministic"))
            elif isinstance(node, ast.ImportFrom) and node.module:
                for alias in node.names:
                    full = f"{node.module}.{alias.name}"
                    if full in WALLCLOCK_NAMES:
                        yield Finding(
                            rule="wallclock", path=ctx.path,
                            line=node.lineno, col=node.col_offset,
                            message=(f"import of {full}; inject a Clock "
                                     "(or a clock callable) instead"))


@rule("sleep-literal",
      "asyncio.sleep with a literal nonzero duration outside the Clock seam")
def check_sleep_literal(project: Project, config: LintConfig
                        ) -> Iterator[Finding]:
    for ctx in project.files:
        if LintConfig.path_in(ctx.path, config.sleep_allowed):
            continue
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if ctx.qualified_name(node.func) != "asyncio.sleep":
                continue
            if not node.args:
                continue
            arg = node.args[0]
            if (isinstance(arg, ast.Constant)
                    and isinstance(arg.value, (int, float))
                    and arg.value != 0):
                yield Finding(
                    rule="sleep-literal", path=ctx.path,
                    line=node.lineno, col=node.col_offset,
                    message=(f"asyncio.sleep({arg.value!r}) bypasses the "
                             "Clock seam; use clock.sleep(...) so virtual "
                             "time advances in FakeClock runs "
                             "(asyncio.sleep(0) yields are fine)"))


#: legacy NumPy global-state API — hidden process-wide RNG state
NUMPY_GLOBAL_RNG = frozenset({
    "numpy.random.seed", "numpy.random.rand", "numpy.random.randn",
    "numpy.random.randint", "numpy.random.random",
    "numpy.random.random_sample", "numpy.random.choice",
    "numpy.random.shuffle", "numpy.random.permutation",
    "numpy.random.normal", "numpy.random.uniform",
    "numpy.random.poisson", "numpy.random.exponential",
})


@rule("unseeded-rng",
      "stdlib random / unseeded or global-state NumPy RNG in src/repro")
def check_unseeded_rng(project: Project, config: LintConfig
                       ) -> Iterator[Finding]:
    for ctx in project.files:
        if not any(ctx.path.startswith(scope) for scope in config.rng_scope):
            continue
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.Name, ast.Attribute)):
                q = ctx.qualified_name(node)
                if q is not None and (q == "random"
                                      or q.startswith("random.")):
                    yield Finding(
                        rule="unseeded-rng", path=ctx.path,
                        line=node.lineno, col=node.col_offset,
                        message=(f"stdlib {q} uses hidden global state; "
                                 "draw from a named SeedSequence stream "
                                 "(np.random.Generator) passed in "
                                 "explicitly"))
            elif isinstance(node, ast.Call):
                q = ctx.qualified_name(node.func)
                if (q == "numpy.random.default_rng"
                        and not node.args and not node.keywords):
                    yield Finding(
                        rule="unseeded-rng", path=ctx.path,
                        line=node.lineno, col=node.col_offset,
                        message=("np.random.default_rng() without a seed is "
                                 "OS-entropy seeded; pass a SeedSequence "
                                 "spawn so runs replay bit-identically"))
                elif q in NUMPY_GLOBAL_RNG:
                    yield Finding(
                        rule="unseeded-rng", path=ctx.path,
                        line=node.lineno, col=node.col_offset,
                        message=(f"{q} mutates/reads NumPy's process-wide "
                                 "RNG; use an explicit Generator from a "
                                 "named SeedSequence stream"))


# ----------------------------------------------------------- async-safety
@rule("dropped-task",
      "create_task/ensure_future result dropped (GC-cancellation hazard)")
def check_dropped_task(project: Project, config: LintConfig
                       ) -> Iterator[Finding]:
    for ctx in project.files:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Expr)
                    and isinstance(node.value, ast.Call)):
                continue
            func = node.value.func
            q = ctx.qualified_name(func)
            is_spawn = (q in ("asyncio.create_task", "asyncio.ensure_future")
                        or (isinstance(func, ast.Attribute)
                            and func.attr in ("create_task",
                                              "ensure_future")))
            if is_spawn:
                yield Finding(
                    rule="dropped-task", path=ctx.path,
                    line=node.lineno, col=node.col_offset,
                    message=("task reference dropped; the event loop only "
                             "holds a weak ref, so the task can be "
                             "garbage-collected mid-flight — keep a "
                             "reference and discard it in a done-callback"))


BLOCKING_CALLS = frozenset({
    "time.sleep", "os.system", "os.popen",
    "subprocess.run", "subprocess.call", "subprocess.check_call",
    "subprocess.check_output", "subprocess.Popen",
    "socket.create_connection", "urllib.request.urlopen",
    "requests.get", "requests.post", "requests.put", "requests.request",
})


def _walk_scoped(node: ast.AST, in_async: bool
                 ) -> Iterator[Tuple[ast.AST, bool]]:
    """Yield (node, inside-async-def) without crossing function scopes
    incorrectly: a sync def nested in an async def is NOT async context,
    and vice versa."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, ast.AsyncFunctionDef):
            yield from _walk_scoped(child, True)
        elif isinstance(child, (ast.FunctionDef, ast.Lambda)):
            yield from _walk_scoped(child, False)
        else:
            yield child, in_async
            yield from _walk_scoped(child, in_async)


@rule("blocking-in-async",
      "blocking call (time.sleep / subprocess / open) inside async def")
def check_blocking_in_async(project: Project, config: LintConfig
                            ) -> Iterator[Finding]:
    for ctx in project.files:
        for node, in_async in _walk_scoped(ctx.tree, False):
            if not (in_async and isinstance(node, ast.Call)):
                continue
            q = ctx.qualified_name(node.func)
            blocking: Optional[str] = None
            if q in BLOCKING_CALLS:
                blocking = q
            elif (isinstance(node.func, ast.Name)
                  and node.func.id == "open"
                  and "open" not in ctx.aliases):
                blocking = "open"
            if blocking is not None:
                yield Finding(
                    rule="blocking-in-async", path=ctx.path,
                    line=node.lineno, col=node.col_offset,
                    message=(f"{blocking}() blocks the event loop inside "
                             "an async def, stalling every in-flight "
                             "request; run it in an executor or use the "
                             "async equivalent"))


_LOCKISH_NAME = re.compile(r"(?i)(lock|mutex)")
_LOCK_FACTORIES = frozenset({
    "threading.Lock", "threading.RLock", "threading.Condition",
    "threading.Semaphore", "threading.BoundedSemaphore",
})


def _terminal_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Call):
        return _terminal_name(node.func)
    return None


def _contains_await(node: ast.AST) -> bool:
    """Await anywhere in this subtree, not descending into nested defs."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            continue
        if isinstance(child, ast.Await) or _contains_await(child):
            return True
    return False


@rule("await-in-lock",
      "await inside a sync `with <lock>:` block (event-loop deadlock)")
def check_await_in_lock(project: Project, config: LintConfig
                        ) -> Iterator[Finding]:
    for ctx in project.files:
        for node, in_async in _walk_scoped(ctx.tree, False):
            if not (in_async and isinstance(node, ast.With)):
                continue
            lockish = False
            for item in node.items:
                expr = item.context_expr
                name = _terminal_name(expr)
                q = ctx.qualified_name(
                    expr.func) if isinstance(expr, ast.Call) else None
                if q in _LOCK_FACTORIES or (
                        name and _LOCKISH_NAME.search(name)):
                    lockish = True
            if lockish and _contains_await(node):
                yield Finding(
                    rule="await-in-lock", path=ctx.path,
                    line=node.lineno, col=node.col_offset,
                    message=("await while holding a threading lock: the "
                             "coroutine suspends with the lock held and "
                             "any other waiter deadlocks the loop; use "
                             "asyncio.Lock with `async with`"))


# ------------------------------------------------ protocol & ledger rules
_PROTOCOL_BASE_EXEMPT = frozenset({"object", "Protocol", "ABC", "Generic"})


def _class_member_names(node: ast.ClassDef) -> Set[str]:
    names: Set[str] = set()
    for stmt in node.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(stmt.name)
        elif isinstance(stmt, ast.AnnAssign) and isinstance(
                stmt.target, ast.Name):
            names.add(stmt.target.id)
        elif isinstance(stmt, ast.Assign):
            for target in stmt.targets:
                if isinstance(target, ast.Name):
                    names.add(target.id)
    return names


def _resolve_members(
        name: str,
        index: Dict[str, Tuple[FileContext, ast.ClassDef]],
        seen: Set[str]) -> Optional[Set[str]]:
    """Full member surface of a class, following bases by name.

    Returns None when any base cannot be resolved inside the linted tree
    (the rule then skips the class rather than false-positive)."""
    if name in seen:
        return set()
    seen.add(name)
    entry = index.get(name)
    if entry is None:
        return None
    _, node = entry
    members = _class_member_names(node)
    for base in node.bases:
        base_name = _terminal_name(base)
        if base_name is None or base_name in _PROTOCOL_BASE_EXEMPT:
            continue
        inherited = _resolve_members(base_name, index, seen)
        if inherited is None:
            return None
        members |= inherited
    return members


@rule("policy-protocol",
      "factory-registered policy class missing Policy protocol members")
def check_policy_protocol(project: Project, config: LintConfig
                          ) -> Iterator[Finding]:
    proto_ctx = project.find_module(config.protocol_module)
    registry_ctx = project.find_module(config.registry_module)
    if proto_ctx is None or registry_ctx is None:
        return  # anchors not under lint (e.g. partial fixture) — no-op

    required: Set[str] = set()
    for node in ast.walk(proto_ctx.tree):
        if (isinstance(node, ast.ClassDef)
                and node.name == config.protocol_class):
            required = {n for n in _class_member_names(node)
                        if not n.startswith("_")}
            break
    if not required:
        return

    registered: List[Tuple[str, int]] = []
    for node in ast.walk(registry_ctx.tree):
        if (isinstance(node, ast.FunctionDef)
                and node.name == config.registry_func):
            for sub in ast.walk(node):
                if (isinstance(sub, ast.Return)
                        and isinstance(sub.value, ast.Call)
                        and isinstance(sub.value.func, ast.Name)):
                    registered.append(
                        (sub.value.func.id, sub.value.lineno))
            break

    index = project.class_index()
    for cls_name, _ in sorted(set(registered)):
        entry = index.get(cls_name)
        if entry is None:
            continue  # constructed via an alias we can't resolve
        ctx, node = entry
        members = _resolve_members(cls_name, index, set())
        if members is None:
            continue  # unresolvable base outside the linted tree
        missing = sorted(required - members)
        if missing:
            yield Finding(
                rule="policy-protocol", path=ctx.path,
                line=node.lineno, col=node.col_offset,
                message=(f"class {cls_name} is registered in "
                         f"{config.registry_func}() but does not define "
                         f"Policy member(s): {', '.join(missing)}"))


@rule("ledger-counter",
      "monotonic self.<counter> += 1 never surfaced in summary/stats/"
      "conservation")
def check_ledger_counter(project: Project, config: LintConfig
                         ) -> Iterator[Finding]:
    for ctx in project.files:
        if not any(ctx.path == m or ctx.path.endswith("/" + m)
                   for m in config.ledger_modules):
            continue
        for cls in ctx.tree.body:
            if not isinstance(cls, ast.ClassDef):
                continue
            reporting_reads: Set[str] = set()
            has_reporting = False
            for stmt in cls.body:
                if (isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and stmt.name in config.ledger_reporting_methods):
                    has_reporting = True
                    for node in ast.walk(stmt):
                        if (isinstance(node, ast.Attribute)
                                and isinstance(node.value, ast.Name)
                                and node.value.id == "self"):
                            reporting_reads.add(node.attr)
            if not has_reporting:
                continue  # not a ledger class (config holders etc.)
            increments: Dict[str, int] = {}
            decremented: Set[str] = set()
            for node in ast.walk(cls):
                if not (isinstance(node, ast.AugAssign)
                        and isinstance(node.target, ast.Attribute)
                        and isinstance(node.target.value, ast.Name)
                        and node.target.value.id == "self"):
                    continue
                attr = node.target.attr
                if (isinstance(node.op, ast.Add)
                        and isinstance(node.value, ast.Constant)
                        and isinstance(node.value.value, int)):
                    increments.setdefault(attr, node.lineno)
                elif isinstance(node.op, ast.Sub):
                    decremented.add(attr)  # gauge, not a monotonic counter
            for attr, lineno in sorted(increments.items(),
                                       key=lambda kv: kv[1]):
                if attr in decremented or attr in reporting_reads:
                    continue
                yield Finding(
                    rule="ledger-counter", path=ctx.path,
                    line=lineno, col=0,
                    message=(f"counter self.{attr} in class {cls.name} is "
                             "incremented but never read in "
                             f"{'/'.join(config.ledger_reporting_methods)}"
                             "(); invisible counters can't be conserved "
                             "or monitored"))


@rule("unregistered-counter",
      "monotonic counter in an instrumented module never bound into the "
      "metrics registry")
def check_unregistered_counter(project: Project, config: LintConfig
                               ) -> Iterator[Finding]:
    """Counters in ``metrics_modules`` must surface in ``register_metrics``.

    The observability plane's contract is that every hand-rolled ledger
    counter binds into the MetricsRegistry (``registry.bind(name,
    lambda: self.counter)``), so dashboards and tests see one uniform
    surface. A counter incremented but never read inside a binding
    method is invisible to that surface. Exemptions: attributes that are
    also decremented (gauges, not monotonic counters) and ``_private``
    bookkeeping attributes (not part of the metrics surface).
    """
    for ctx in project.files:
        if not any(ctx.path == m or ctx.path.endswith("/" + m)
                   for m in config.metrics_modules):
            continue
        for cls in ctx.tree.body:
            if not isinstance(cls, ast.ClassDef):
                continue
            binding_reads: Set[str] = set()
            has_binding = False
            for stmt in cls.body:
                if (isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and stmt.name in config.metrics_binding_methods):
                    has_binding = True
                    for node in ast.walk(stmt):
                        if (isinstance(node, ast.Attribute)
                                and isinstance(node.value, ast.Name)
                                and node.value.id == "self"):
                            binding_reads.add(node.attr)
            increments: Dict[str, int] = {}
            decremented: Set[str] = set()
            for node in ast.walk(cls):
                if not (isinstance(node, ast.AugAssign)
                        and isinstance(node.target, ast.Attribute)
                        and isinstance(node.target.value, ast.Name)
                        and node.target.value.id == "self"):
                    continue
                attr = node.target.attr
                if (isinstance(node.op, ast.Add)
                        and isinstance(node.value, ast.Constant)
                        and isinstance(node.value.value, int)
                        and node.value.value > 0):
                    increments.setdefault(attr, node.lineno)
                elif isinstance(node.op, ast.Sub):
                    decremented.add(attr)  # gauge, not a monotonic counter
            counters = {attr: lineno for attr, lineno in increments.items()
                        if attr not in decremented
                        and not attr.startswith("_")}
            if not counters:
                continue
            if not has_binding:
                _, first_line = min(counters.items(), key=lambda kv: kv[1])
                yield Finding(
                    rule="unregistered-counter", path=ctx.path,
                    line=cls.lineno, col=cls.col_offset,
                    message=(f"class {cls.name} keeps monotonic counter(s) "
                             f"{', '.join(sorted(counters))} but defines no "
                             f"{'/'.join(config.metrics_binding_methods)}() "
                             "to bind them into the metrics registry"))
                continue
            for attr, lineno in sorted(counters.items(),
                                       key=lambda kv: kv[1]):
                if attr in binding_reads:
                    continue
                yield Finding(
                    rule="unregistered-counter", path=ctx.path,
                    line=lineno, col=0,
                    message=(f"counter self.{attr} in class {cls.name} is "
                             "incremented but never bound in "
                             f"{'/'.join(config.metrics_binding_methods)}"
                             "(); unregistered counters are invisible to "
                             "the metrics plane"))


# ----------------------------------------------------------- fault safety
#: callee terminal names that look like an upstream dispatch — the thing
#: a retry loop re-invokes
_RETRY_CALLEE = re.compile(
    r"(?i)(target|upstream|dispatch|execute|invoke|probe|attempt)")
#: identifiers that evidence the loop is bounded by a retry cap or a
#: deadline budget
_RETRY_BOUND = re.compile(
    r"(?i)(deadline|retr|attempt|budget|cap|max|limit|bound)")


def _infinite_loop_header(ctx: FileContext, node: ast.AST) -> Optional[str]:
    """Human-readable header when the loop can only exit via break/raise."""
    if isinstance(node, ast.While):
        test = node.test
        if isinstance(test, ast.Constant) and bool(test.value):
            return f"while {test.value!r}"
    elif isinstance(node, ast.For):
        it = node.iter
        if (isinstance(it, ast.Call)
                and (ctx.qualified_name(it.func) == "itertools.count"
                     or _terminal_name(it.func) == "count")):
            return "for ... in count()"
    return None


def _iter_loop_scope(node: ast.AST) -> Iterator[ast.AST]:
    """Loop subtree without descending into nested defs/lambdas."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            continue
        yield child
        yield from _iter_loop_scope(child)


@rule("unbounded-retry",
      "infinite loop re-invoking an upstream target with no retry cap or "
      "deadline bound in sight")
def check_unbounded_retry(project: Project, config: LintConfig
                          ) -> Iterator[Finding]:
    for ctx in project.files:
        for node in ast.walk(ctx.tree):
            header = _infinite_loop_header(ctx, node)
            if header is None:
                continue
            dispatch_call: Optional[str] = None
            bounded = False
            for sub in _iter_loop_scope(node):
                if isinstance(sub, ast.Call):
                    name = _terminal_name(sub.func)
                    if (dispatch_call is None and name
                            and _RETRY_CALLEE.search(name)):
                        dispatch_call = name
                if isinstance(sub, ast.Name):
                    ident: Optional[str] = sub.id
                elif isinstance(sub, ast.Attribute):
                    ident = sub.attr
                else:
                    ident = None
                if ident and _RETRY_BOUND.search(ident):
                    bounded = True
                    break
            if dispatch_call is not None and not bounded:
                yield Finding(
                    rule="unbounded-retry", path=ctx.path,
                    line=node.lineno, col=node.col_offset,
                    message=(f"`{header}` loop re-invokes "
                             f"{dispatch_call}() with no visible retry cap "
                             "or deadline bound; an endpoint that fails "
                             "forever spins this loop forever — bound it "
                             "by a max-attempts counter or the batch "
                             "deadline"))


@rule("slots-dataclass",
      "hot-path dataclass under simulation/ without slots=True")
def check_slots_dataclass(project: Project, config: LintConfig
                          ) -> Iterator[Finding]:
    for ctx in project.files:
        if not any(ctx.path.startswith(p) for p in config.slots_paths):
            continue
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            for deco in node.decorator_list:
                call_kw = deco.keywords if isinstance(deco, ast.Call) else []
                target = deco.func if isinstance(deco, ast.Call) else deco
                if _terminal_name(target) != "dataclass":
                    continue
                has_slots = any(
                    kw.arg == "slots"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True
                    for kw in call_kw)
                if not has_slots:
                    yield Finding(
                        rule="slots-dataclass", path=ctx.path,
                        line=node.lineno, col=node.col_offset,
                        message=(f"dataclass {node.name} allocates per-event"
                                 " on the sim hot path; declare "
                                 "@dataclass(slots=True) to drop the "
                                 "__dict__ overhead"))
