"""CLI entry point: ``python -m tools.reprolint [paths...]``."""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.reprolint.engine import (
    RULES,
    LintConfig,
    apply_baseline,
    lint_paths,
    load_baseline,
    save_baseline,
)
from tools.reprolint import rules as _rules  # noqa: F401  (registers rules)

DEFAULT_PATHS = ["src", "benchmarks", "experiments"]
DEFAULT_BASELINE = Path("tools/reprolint/baseline.json")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="AST linter for repo invariants (determinism, "
                    "async-safety, protocol/ledger discipline).")
    parser.add_argument("paths", nargs="*", default=None,
                        help=f"files/dirs to lint (default: "
                             f"{' '.join(DEFAULT_PATHS)})")
    parser.add_argument("--format", choices=("human", "json"),
                        default="human")
    parser.add_argument("--output", type=Path, default=None,
                        help="also write the report to this file")
    parser.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                        help=f"baseline file (default: {DEFAULT_BASELINE})")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore the baseline: report every finding")
    parser.add_argument("--write-baseline", action="store_true",
                        help="write current findings to the baseline file "
                             "(new entries get a TODO justification)")
    parser.add_argument("--rule", action="append", dest="rules",
                        metavar="RULE",
                        help="run only this rule (repeatable)")
    parser.add_argument("--list-rules", action="store_true")
    args = parser.parse_args(argv)

    if args.list_rules:
        width = max(len(name) for name in RULES)
        for name in sorted(RULES):
            print(f"{name:<{width}}  {RULES[name].description}")
        return 0

    if args.rules:
        unknown = sorted(set(args.rules) - set(RULES))
        if unknown:
            parser.error(f"unknown rule(s): {', '.join(unknown)}")

    result = lint_paths(args.paths or DEFAULT_PATHS, LintConfig(),
                        only=args.rules)

    entries = []
    if not args.no_baseline and args.baseline.is_file():
        try:
            entries = load_baseline(args.baseline)
        except (ValueError, json.JSONDecodeError) as exc:
            print(f"error: bad baseline {args.baseline}: {exc}",
                  file=sys.stderr)
            return 2
    fresh, baselined, stale = apply_baseline(result.findings, entries)

    if args.write_baseline:
        keyed = {(e["rule"], e["path"], e["message"]): e for e in entries}
        new_entries = []
        for finding in result.findings:
            prior = keyed.get(finding.key)
            new_entries.append({
                "rule": finding.rule, "path": finding.path,
                "message": finding.message,
                "justification": (prior["justification"] if prior
                                  else "TODO: justify or fix"),
            })
        save_baseline(args.baseline, new_entries)
        print(f"wrote {len(new_entries)} entries to {args.baseline}")
        return 0

    if args.format == "json":
        report = json.dumps({
            "version": 1,
            "files_checked": result.files_checked,
            "suppressed": result.suppressed,
            "findings": [f.to_dict() for f in fresh],
            "baselined": [f.to_dict() for f in baselined],
            "stale_baseline": stale,
        }, indent=2)
    else:
        lines = [f.render() for f in fresh]
        lines.append(
            f"{len(fresh)} finding(s) ({len(baselined)} baselined, "
            f"{result.suppressed} suppressed) across "
            f"{result.files_checked} files")
        report = "\n".join(lines)

    print(report)
    if args.output is not None:
        args.output.parent.mkdir(parents=True, exist_ok=True)
        args.output.write_text(report + "\n", encoding="utf-8")
    for entry in stale:
        print(f"warning: stale baseline entry (fixed? delete it): "
              f"{entry['rule']} {entry['path']}: {entry['message']}",
              file=sys.stderr)
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
