"""Clock seam for the wall-clock serving runtime.

Everything in :mod:`repro.runtime` tells time through a :class:`Clock`
instead of calling ``time``/``asyncio.sleep`` directly, which gives the
runtime two interchangeable time sources:

* :class:`WallClock` — real time. ``now()`` is a monotonic offset from
  construction (so runtime timestamps start near 0.0 like simulator time)
  and ``sleep``/``wait`` are plain asyncio primitives.
* :class:`FakeClock` — deterministic virtual time for tests and the
  sim↔live parity bench. Sleeping tasks park on a heap of
  ``(wake_time, seq, future)``; :meth:`FakeClock.run_until` advances
  virtual time only when the event loop has fully settled (no runnable
  task), then wakes the earliest sleeper. Same seed + same trace →
  bit-identical execution order, which is what makes the runtime's
  dispatch-decision log replayable (see ``tests/test_runtime.py``).

The protocol is intentionally tiny — ``now``, ``sleep``, ``wait`` (event
with timeout), ``run_until`` (drive a coroutine to completion) — so any
other source (e.g. a scaled-time clock for accelerated soak tests) can
slot in.
"""
from __future__ import annotations

import asyncio
import heapq
import itertools
import time
from typing import Any, Awaitable, Coroutine, List, Optional, Tuple


class Clock:
    """Protocol: monotonic ``now()`` plus async ``sleep``/``wait``."""

    def now(self) -> float:
        raise NotImplementedError

    async def sleep(self, seconds: float) -> None:
        raise NotImplementedError

    async def wait(self, event: asyncio.Event, timeout: Optional[float]) -> bool:
        """Wait until ``event`` is set or ``timeout`` elapses.

        Returns True if the event was set, False on timeout. ``None``
        timeout waits indefinitely.
        """
        raise NotImplementedError

    async def run_until(self, aw: Awaitable) -> Any:
        """Drive ``aw`` to completion under this clock; returns its result."""
        raise NotImplementedError


class WallClock(Clock):
    """Real time, zeroed at construction."""

    def __init__(self) -> None:
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    async def sleep(self, seconds: float) -> None:
        await asyncio.sleep(max(0.0, seconds))

    async def wait(self, event: asyncio.Event, timeout: Optional[float]) -> bool:
        if timeout is None:
            await event.wait()
            return True
        try:
            await asyncio.wait_for(event.wait(), max(0.0, timeout))
            return True
        except asyncio.TimeoutError:
            return False

    async def run_until(self, aw: Awaitable) -> Any:
        return await aw


class FakeClock(Clock):
    """Deterministic virtual time driven by :meth:`run_until`.

    Tasks that ``await clock.sleep(dt)`` park a future on a heap keyed by
    ``(wake_time, seq)``; the driver advances ``now`` to the earliest
    pending wake time only once the event loop is idle (every task blocked
    on a future), then resolves that one sleeper and lets the loop settle
    again. Ties fire in sleep order and asyncio's ready queue is FIFO, so
    runs are bit-for-bit repeatable.
    """

    # Safety bound on settle iterations: a genuine ping-pong livelock
    # (two tasks re-scheduling each other forever without blocking)
    # should fail loudly rather than hang the test suite.
    MAX_SETTLE = 100_000

    def __init__(self, start: float = 0.0) -> None:
        self._now = start
        self._heap: List[Tuple[float, int, asyncio.Future]] = []
        self._seq = itertools.count()

    def now(self) -> float:
        return self._now

    async def sleep(self, seconds: float) -> None:
        if seconds <= 0:
            await asyncio.sleep(0)
            return
        fut = asyncio.get_running_loop().create_future()
        heapq.heappush(self._heap, (self._now + seconds, next(self._seq), fut))
        await fut

    async def wait(self, event: asyncio.Event, timeout: Optional[float]) -> bool:
        if timeout is None:
            await event.wait()
            return True
        if event.is_set():
            return True
        sleeper = asyncio.ensure_future(self.sleep(timeout))
        waiter = asyncio.ensure_future(event.wait())
        done, pending = await asyncio.wait(
            {sleeper, waiter}, return_when=asyncio.FIRST_COMPLETED
        )
        for p in pending:
            p.cancel()
        for p in pending:
            try:
                await p
            except asyncio.CancelledError:
                pass
        return event.is_set()

    async def _settle(self) -> None:
        """Yield until the event loop has no immediately-runnable callback.

        Relies on CPython's ``loop._ready`` deque when available: after our
        own ``sleep(0)`` resumes, an empty ready queue means every other
        task is blocked on a future, so it is safe to advance time. Falls
        back to a fixed number of yields on loops without ``_ready``.
        """
        loop = asyncio.get_running_loop()
        ready = getattr(loop, "_ready", None)
        if ready is None:
            for _ in range(64):
                await asyncio.sleep(0)
            return
        for _ in range(self.MAX_SETTLE):
            if not ready:
                return
            await asyncio.sleep(0)
        raise RuntimeError(
            "FakeClock: event loop never went idle (runnable-task livelock?)"
        )

    async def run_until(self, aw: Awaitable) -> Any:
        task = asyncio.ensure_future(aw)
        heap = self._heap
        while True:
            await self._settle()
            if task.done():
                break
            while heap and heap[0][2].done():  # cancelled/stale sleepers
                heapq.heappop(heap)
            if not heap:
                raise RuntimeError(
                    "FakeClock deadlock: tasks pending but no timer to advance"
                )
            t, _, fut = heapq.heappop(heap)
            if t > self._now:
                self._now = t
            fut.set_result(None)
        return task.result()


def run(clock: Clock, main: Coroutine) -> Any:
    """Run ``main`` to completion under ``clock`` in a fresh event loop."""
    return asyncio.run(clock.run_until(main))
