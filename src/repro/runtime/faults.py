"""Deterministic chaos injection for the live runtime.

:class:`FaultyTarget` wraps any :class:`~repro.runtime.targets.DispatchTarget`
and injects the five fault kinds the sim-world chaos suite
(``experiments/scenarios.py``, PR 2) models on the platform side:

``crash``
    The container dies before producing a result: sleep ``crash_latency``
    (the time the proxy waits before the failure surfaces), then raise
    :class:`CrashFault`. The inner target is never invoked.
``timeout``
    The upstream stalls and the platform's gateway answers 504: sleep
    ``timeout_stall`` — burning real deadline budget — then raise
    :class:`UpstreamTimeout`. The inner target is never invoked.
``straggler``
    A cold-start / noisy-neighbour slowdown: sleep ``straggler_delay``
    extra, then run the inner target normally. No error is raised —
    stragglers exercise hedging and deadline budgets, not retries.
``partial``
    The batch executes but a fraction of its results are unusable (e.g.
    a worker crashed mid-batch after partial writeback): run the inner
    target to completion, then raise :class:`PartialBatchFault`. The
    proxy retries the *whole* batch — the simple policy that keeps
    exactly-once accounting trivial (no per-request splits mid-flight).
``preempt``
    The platform reclaims the container mid-execution: race the inner
    target against a ``preempt_after`` timer; if the timer wins, cancel
    the inner call and raise :class:`PreemptedFault`.

Determinism: faults are drawn from a dedicated seeded RNG stream (the
third :class:`numpy.random.SeedSequence` child, mirroring the simulator's
``arrivals``/``service``/``faults`` split) with exactly one uniform draw
at call entry, in dispatch order. Under
:class:`~repro.runtime.clock.FakeClock` dispatch order is deterministic,
so the full fault schedule — recorded in :attr:`FaultyTarget.fault_log` —
is bit-identical across runs with the same seed.
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.request import Batch
from repro.runtime.clock import Clock
from repro.runtime.targets import DispatchTarget

#: The five injectable fault kinds, in cumulative-probability order.
FAULT_KINDS: Tuple[str, ...] = (
    "crash", "timeout", "straggler", "partial", "preempt"
)


class InjectedFault(RuntimeError):
    """Base class of every fault :class:`FaultyTarget` injects."""


class CrashFault(InjectedFault):
    """The (simulated) container crashed before producing a result."""


class UpstreamTimeout(InjectedFault):
    """The (simulated) upstream stalled until the platform gateway gave up."""


class PartialBatchFault(InjectedFault):
    """The batch executed but some results were lost; retry the whole batch."""


class PreemptedFault(InjectedFault):
    """The platform reclaimed the container mid-execution."""


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Injection probabilities and timing of each fault kind.

    Probabilities are per dispatch attempt and mutually exclusive (one
    uniform draw selects at most one kind); their sum must be <= 1.
    """

    #: P(container crash) and how long the crash takes to surface.
    crash_prob: float = 0.0
    crash_latency: float = 0.005
    #: P(upstream stall -> gateway 504) and how long the stall burns.
    timeout_prob: float = 0.0
    timeout_stall: float = 0.5
    #: P(straggler) and the extra delay added before a normal completion.
    straggler_prob: float = 0.0
    straggler_delay: float = 0.5
    #: P(partial-batch failure); the whole batch is retried (see module doc).
    partial_prob: float = 0.0
    #: P(preemption) and how far into execution the container is reclaimed.
    preempt_prob: float = 0.0
    preempt_after: float = 0.01
    #: Seed of the dedicated fault stream (see :func:`fault_rng`).
    seed: int = 0

    def __post_init__(self) -> None:
        probs = (self.crash_prob, self.timeout_prob, self.straggler_prob,
                 self.partial_prob, self.preempt_prob)
        if any(p < 0 for p in probs) or sum(probs) > 1.0 + 1e-12:
            raise ValueError(
                f"fault probabilities must be >= 0 and sum to <= 1, got "
                f"{probs}"
            )
        for what, v in (("crash_latency", self.crash_latency),
                        ("timeout_stall", self.timeout_stall),
                        ("straggler_delay", self.straggler_delay),
                        ("preempt_after", self.preempt_after)):
            if v < 0:
                raise ValueError(f"{what} must be >= 0, got {v}")

    @property
    def total_prob(self) -> float:
        return (self.crash_prob + self.timeout_prob + self.straggler_prob
                + self.partial_prob + self.preempt_prob)


def fault_rng(seed: int) -> np.random.Generator:
    """The named fault stream: third SeedSequence child of ``seed``.

    Mirrors the simulator's ``arrivals``/``service``/``faults`` stream
    split (and :func:`~repro.runtime.loadgen._spawn_streams`, which takes
    children 0 and 1), so a live run seeded like a sim run draws its
    faults from the same stream the platform's chaos would.
    """
    streams: Sequence[np.random.SeedSequence] = \
        np.random.SeedSequence(seed).spawn(3)
    return np.random.default_rng(streams[2])


class FaultyTarget(DispatchTarget):
    """Chaos wrapper around any :class:`DispatchTarget` (see module doc).

    Exposes the inner target's ``max_batch``/``batch_buckets`` unchanged
    so policy-cap clamping and bucket-aware packing behave identically
    with and without the wrapper.
    """

    def __init__(self, inner: DispatchTarget, clock: Clock,
                 config: FaultConfig,
                 rng: Optional[np.random.Generator] = None,
                 tracer=None) -> None:
        self.inner = inner
        self.clock = clock
        self.config = config
        self.tracer = tracer
        self.rng = rng if rng is not None else fault_rng(config.seed)
        # mirror the inner target's shape contract so cap clamping and
        # bucket-aware packing behave identically through the wrapper
        self.max_batch = inner.max_batch
        self.batch_buckets = getattr(inner, "batch_buckets", None)
        # cumulative selection edges, in FAULT_KINDS order
        probs = (config.crash_prob, config.timeout_prob,
                 config.straggler_prob, config.partial_prob,
                 config.preempt_prob)
        edges: List[Tuple[float, str]] = []
        acc = 0.0
        for p, kind in zip(probs, FAULT_KINDS):
            acc += p
            edges.append((acc, kind))
        self._edges = edges
        self.calls = 0
        #: injections per kind (plus "ok" for clean passes) — lifetime.
        self.injected = {kind: 0 for kind in FAULT_KINDS}
        self.injected["ok"] = 0
        #: (call index, clock time, kind) per dispatch attempt, including
        #: clean ones — the byte-identity artifact of the determinism tests.
        self.fault_log: List[Tuple[int, float, str]] = []

    # --------------------------------------------------------------- metrics
    def register_metrics(self, registry, prefix: str = "chaos") -> None:
        """Bind the injection ledger into a MetricsRegistry."""
        b = registry.bind
        b(f"{prefix}.calls", lambda: self.calls)
        for kind in (*FAULT_KINDS, "ok"):
            b(f"{prefix}.injected.{kind}",
              lambda k=kind: self.injected[k])

    # --------------------------------------------------------------- helpers
    def _draw(self) -> str:
        """One uniform draw at call entry selects the fault kind (or 'ok')."""
        if self.config.total_prob <= 0.0:
            # zero-fault config: skip the draw entirely so a wrapped target
            # is RNG-identical to the bare one (the no-fault byte-identity
            # guarantee the bench asserts)
            return "ok"
        u = float(self.rng.random())
        for edge, kind in self._edges:
            if u < edge:
                return kind
        return "ok"

    async def _invoke(self, batch: Batch, deadline: Optional[float]):
        return await self.inner(batch, deadline=deadline)

    # --------------------------------------------------------------- dispatch
    async def __call__(self, batch: Batch,
                       deadline: Optional[float] = None):
        idx = self.calls
        self.calls += 1
        kind = self._draw()
        self.injected[kind] += 1
        now = self.clock.now()
        self.fault_log.append((idx, now, kind))
        if self.tracer is not None:
            self.tracer.emit(now, "attempt", batch.endpoint,
                             batch=batch.trace_id, size=batch.size,
                             detail=kind)
        cfg = self.config
        if kind == "crash":
            await self.clock.sleep(cfg.crash_latency)
            raise CrashFault(
                f"injected container crash on call {idx} "
                f"(batch of {batch.size})"
            )
        if kind == "timeout":
            await self.clock.sleep(cfg.timeout_stall)
            raise UpstreamTimeout(
                f"injected upstream stall of {cfg.timeout_stall}s on call "
                f"{idx} (batch of {batch.size})"
            )
        if kind == "straggler":
            await self.clock.sleep(cfg.straggler_delay)
            return await self._invoke(batch, deadline)
        if kind == "partial":
            result = await self._invoke(batch, deadline)
            del result  # results discarded: the whole batch is retried
            raise PartialBatchFault(
                f"injected partial-batch failure on call {idx} "
                f"(batch of {batch.size})"
            )
        if kind == "preempt":
            loop = asyncio.get_running_loop()
            work = loop.create_task(self._invoke(batch, deadline))
            timer = loop.create_task(self.clock.sleep(cfg.preempt_after))
            try:
                await asyncio.wait({work, timer},
                                   return_when=asyncio.FIRST_COMPLETED)
            except asyncio.CancelledError:
                # outer cancellation (drain timeout / losing hedge): tear
                # down both children before propagating
                for t in (work, timer):
                    t.cancel()
                await asyncio.gather(work, timer, return_exceptions=True)
                raise
            if work.done():
                timer.cancel()
                await asyncio.gather(timer, return_exceptions=True)
                return work.result()
            work.cancel()
            await asyncio.gather(work, return_exceptions=True)
            raise PreemptedFault(
                f"injected preemption after {cfg.preempt_after}s on call "
                f"{idx} (batch of {batch.size})"
            )
        return await self._invoke(batch, deadline)
