"""Dispatch targets — where the live runtime sends a dispatched batch.

A target is an async callable ``await target(batch)``; the server measures
the wall(-virtual) time around the await and that measurement IS the
upstream latency the policy's monitor learns from (the paper's measured
feedback loop, §2.2). Two implementations:

* :class:`SyntheticTarget` — models the upstream with any
  :class:`~repro.serverless.latency.LatencyModel`: samples a service time
  (per the batch's endpoint-aware ``sample_batch`` hook) and sleeps it on
  the runtime clock. An optional concurrency cap queues excess batches,
  so queueing delay shows up in the measured latency exactly like the
  platform's activator queue does in the simulator.
* :class:`EngineTarget` — the real data plane: adapts
  :class:`~repro.serving.batcher.ReplicaPoolTarget` (bucketed JAX
  prefill/decode on a :class:`~repro.serving.engine.ReplicaPool`), running
  the blocking engine call in a worker thread so the event loop keeps
  serving arrivals while a batch computes.

Both expose ``max_batch`` (None = unbounded) so the server can clamp a
policy's batch-size cap to the largest engine bucket at *config* time
instead of discovering the mismatch mid-dispatch.
"""
from __future__ import annotations

import asyncio
import functools
import inspect
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.request import Batch
from repro.runtime.clock import Clock
from repro.serverless.latency import LatencyModel


class DispatchTarget:
    """Protocol: awaitable batch executor with an optional size ceiling.

    ``deadline`` is the batch's tightest remaining absolute deadline on
    the runtime clock (None = no member carries one). Targets are free to
    ignore it; real HTTP/gRPC upstreams would map it onto a request
    timeout header so the whole serving chain stays SLO-accountable.
    """

    #: Largest batch the target can execute in one call (None = unbounded).
    max_batch: Optional[int] = None
    #: Compiled batch buckets of a fixed-shape backend (None = shapeless).
    #: The server's ``add_endpoint(pack=True)`` reads this to turn on
    #: bucket-aware packing in the owning policy.
    batch_buckets: Optional[Tuple[int, ...]] = None

    async def __call__(self, batch: Batch,
                       deadline: Optional[float] = None) -> None:
        raise NotImplementedError


class SyntheticTarget(DispatchTarget):
    """Async-sleep upstream parameterized by any :class:`LatencyModel`.

    ``concurrency`` > 0 bounds simultaneous batch executions with a
    semaphore (a fixed-size container fleet); the wait for a slot is part
    of the measured upstream latency, mirroring platform-side queueing.
    """

    def __init__(self, latency_model: LatencyModel, clock: Clock,
                 rng: Optional[np.random.Generator] = None,
                 concurrency: int = 0,
                 batch_buckets: Optional[Sequence[int]] = None) -> None:
        self.latency = latency_model
        self.clock = clock
        # an optional bucket grid makes the synthetic upstream behave like
        # a fixed-shape engine for packing experiments (latency models
        # already price batches by Batch.effective_size)
        self.batch_buckets = tuple(batch_buckets) if batch_buckets else None
        self.rng = rng if rng is not None else np.random.default_rng(0)
        self.concurrency = concurrency
        self._sem = asyncio.Semaphore(concurrency) if concurrency > 0 else None
        self.batches = 0
        self.requests = 0
        self.cancelled = 0
        #: calls that began executing (>= batches: a preempted / hedged /
        #: drain-cancelled call starts but never completes)
        self.started = 0
        #: tightest deadline of the most recent call (propagation probe)
        self.last_deadline: Optional[float] = None

    async def __call__(self, batch: Batch,
                       deadline: Optional[float] = None) -> None:
        # Sample BEFORE awaiting the slot: service-time draws happen in
        # dispatch order, so the stream stays deterministic under FakeClock
        # regardless of how long slot waits interleave.
        self.started += 1
        self.last_deadline = deadline
        service = float(self.latency.sample_batch(batch, self.rng))
        try:
            if self._sem is not None:
                async with self._sem:
                    await self.clock.sleep(service)
            else:
                await self.clock.sleep(service)
        except asyncio.CancelledError:
            # hedge loser / drain-timeout straggler: slot freed, no count
            self.cancelled += 1
            raise
        self.batches += 1
        self.requests += batch.size


class TieredTarget(DispatchTarget):
    """Fan-out target: one inner :class:`DispatchTarget` per fleet tier.

    The live-world counterpart of
    :class:`~repro.serverless.tiers.TieredPlatform`: batches arrive
    already stamped with ``batch.tier`` by the endpoint's
    :class:`~repro.core.frontend.SpilloverRouter` (the same router seam
    the simulator uses, so routing decisions agree across worlds) and
    are forwarded to that tier's target. Unstamped batches fall back to
    the cheapest tier, so a router-less endpoint degrades to a
    single-tier fleet instead of erroring.

    Per-tier busy-seconds are integrated around each call and combined
    through ``cost_weights`` into :attr:`cost_integral` — the live
    analogue of the platform's billable-seconds cost metric (billing
    here follows *execution* time, as serverless per-invocation billing
    does, rather than provisioned-fleet time).
    """

    def __init__(self, targets, clock: Clock,
                 cost_weights: Optional[dict] = None) -> None:
        if not targets:
            raise ValueError("TieredTarget needs at least one tier")
        self.targets = dict(targets)
        self.clock = clock
        weights = cost_weights or {}
        self.cost_weights = {
            n: float(weights.get(n, 1.0)) for n in self.targets}
        # cheapest tier is the fallback (first wins on cost ties)
        self.default_tier = min(self.targets,
                                key=lambda n: self.cost_weights[n])
        # conservative ceiling: the smallest per-tier cap must hold for
        # every tier a batch might land on
        caps = [t.max_batch for t in self.targets.values()
                if t.max_batch is not None]
        self.max_batch = min(caps) if caps else None
        buckets = {t.batch_buckets for t in self.targets.values()}
        self.batch_buckets = (buckets.pop() if len(buckets) == 1 else None)
        self._takes_deadline = {}
        for name, t in self.targets.items():
            try:
                sig = inspect.signature(
                    t.__call__ if hasattr(t, "__call__") else t)
                self._takes_deadline[name] = "deadline" in sig.parameters
            except (TypeError, ValueError):
                self._takes_deadline[name] = False
        self.calls = {n: 0 for n in self.targets}
        self.requests = {n: 0 for n in self.targets}
        self.busy_seconds = {n: 0.0 for n in self.targets}
        self.default_routed = 0  # batches that arrived with no tier stamp

    @property
    def cost_integral(self) -> float:
        """Weighted busy-seconds: Σ tier ``cost_weight × busy_seconds``."""
        return sum(self.cost_weights[n] * s
                   for n, s in self.busy_seconds.items())

    def stats(self) -> dict:
        """Per-tier call/billing breakdown for the server summary."""
        return {
            "default_routed": self.default_routed,
            "cost_integral": self.cost_integral,
            "tiers": {
                n: {
                    "calls": self.calls[n],
                    "requests": self.requests[n],
                    "busy_seconds": self.busy_seconds[n],
                    "cost_weight": self.cost_weights[n],
                    "cost_integral": (self.cost_weights[n]
                                      * self.busy_seconds[n]),
                }
                for n in self.targets
            },
        }

    async def __call__(self, batch: Batch,
                       deadline: Optional[float] = None) -> None:
        tier = batch.tier
        if tier is None:
            batch.tier = tier = self.default_tier
            self.default_routed += 1
        try:
            target = self.targets[tier]
        except KeyError:
            raise KeyError(f"batch stamped with unknown tier {tier!r}; "
                           f"fleet has {sorted(self.targets)}") from None
        t0 = self.clock.now()
        try:
            if self._takes_deadline[tier]:
                await target(batch, deadline=deadline)
            else:
                await target(batch)
        finally:
            # billed while running — a cancelled straggler still accrues
            self.busy_seconds[tier] += float(self.clock.now() - t0)
        self.calls[tier] += 1
        self.requests[tier] += batch.size


class EngineTarget(DispatchTarget):
    """Real JAX engine upstream via :class:`ReplicaPoolTarget`.

    The blocking pool call runs in ``asyncio``'s default thread-pool
    executor, keeping the proxy loop responsive. Concurrency defaults to
    the pool's replica count — the pool's per-replica locks let that many
    dispatches overlap on distinct replicas, so the runtime no longer
    serializes a multi-replica pool behind one slot. Oversized batches
    are chunked by the pool target (see ``serving/batcher.py``), so a
    policy whose cap exceeds the largest engine bucket degrades to
    multiple engine calls instead of raising mid-dispatch.
    """

    def __init__(self, pool_target,
                 max_concurrent: Optional[int] = None,
                 clock: Optional[Clock] = None) -> None:
        # `pool_target` is a ReplicaPoolTarget (imported lazily by callers
        # so this module stays importable without JAX).
        self.pool_target = pool_target
        # the runtime clock deadlines are absolute on; required to forward
        # deadlines (the pool target's measurement clock has a different
        # epoch, so the absolute value must be translated, not passed raw)
        self.clock = clock
        buckets = pool_target.pool.engine_cfg.batch_buckets
        self.max_batch = max(buckets)
        self.batch_buckets = tuple(buckets)
        if max_concurrent is None:
            max_concurrent = max(1, len(pool_target.pool.replicas))
        self._sem = asyncio.Semaphore(max_concurrent)
        # Older pool targets predate the ``deadline=`` parameter.
        try:
            sig = inspect.signature(pool_target.__call__)
            self._takes_deadline = "deadline" in sig.parameters
        except (TypeError, ValueError):
            self._takes_deadline = False

    def _pool_deadline(self, deadline: Optional[float]) -> Optional[float]:
        """Translate a runtime-clock deadline onto the pool's clock.

        Both are absolute instants but on different epochs (the runtime
        clock zeroes at server start; the pool's measurement clock is raw
        monotonic), so the *remaining budget* is carried across:
        ``pool_now + (deadline - runtime_now)``. Without a runtime clock
        there is no sound translation — forward None rather than a
        wrong-epoch value that would abort every follow-up chunk.
        """
        if deadline is None or self.clock is None:
            return None
        pool_clock = getattr(self.pool_target, "clock", None)
        if pool_clock is None:
            return None
        return pool_clock() + (deadline - self.clock.now())

    async def __call__(self, batch: Batch,
                       deadline: Optional[float] = None) -> None:
        # The deadline is forwarded to the pool target, whose chunked
        # path aborts unexecuted chunks once it passes (a chunk already
        # running is not interruptible mid-kernel).
        loop = asyncio.get_running_loop()
        if self._takes_deadline:
            call = functools.partial(self.pool_target, batch,
                                     deadline=self._pool_deadline(deadline))
        else:
            call = functools.partial(self.pool_target, batch)
        async with self._sem:
            await loop.run_in_executor(None, call)
