"""Per-endpoint circuit breaker for the live runtime.

Classic three-state machine driven by a *windowed* failure rate over the
last ``window`` dispatch attempts:

* **closed** — normal operation. Every attempt outcome enters the window;
  once it holds at least ``min_samples`` outcomes and the failure
  fraction reaches ``failure_threshold``, the breaker opens.
* **open** — the endpoint is presumed down. Dispatches wait (the server
  parks the batch task on the clock until the probe instant) and
  admission switches to brownout shedding. After ``open_duration``
  seconds the breaker lazily transitions to half-open.
* **half-open** — probe mode: a SINGLE probe attempt goes out (the herd
  of parked batches keeps waiting — with faults, failures surface faster
  than successes, so letting everyone probe at once would let one fast
  failure re-open the breaker before any success lands); ``close_after``
  probe successes close the breaker (window cleared — the outage's
  failures must not instantly re-trip it), any failure re-opens it.

The breaker is **clock-free** (callers pass ``now``) and keeps **no
timer tasks**: the open→half-open transition is computed lazily from
``opened_at + open_duration`` on every query. That makes it trivially
deterministic under :class:`~repro.runtime.clock.FakeClock` and means
``drain(timeout=)`` has no breaker-owned timers to chase — the only
parked sleeper is the batch task itself, which drain already cancels.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional, Tuple

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half-open"


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Knobs of one endpoint's circuit breaker."""

    #: Size of the sliding outcome window (count-based, not time-based:
    #: deterministic and O(1) regardless of traffic rate).
    window: int = 20
    #: Minimum outcomes in the window before the breaker may open — a
    #: single early failure must not trip a cold endpoint.
    min_samples: int = 5
    #: Windowed failure fraction at which the breaker opens.
    failure_threshold: float = 0.5
    #: Seconds the breaker stays open before probing (half-open).
    open_duration: float = 5.0
    #: Consecutive half-open successes required to close.
    close_after: int = 1
    #: How often a half-open waiter re-checks for the free probe slot
    #: (the probe's completion time is unknowable in advance, so waiters
    #: poll on the clock at this cadence — deterministic under FakeClock).
    probe_interval: float = 0.1

    def __post_init__(self) -> None:
        if self.window < 1 or self.min_samples < 1:
            raise ValueError("window and min_samples must be >= 1")
        if self.min_samples > self.window:
            raise ValueError(
                f"min_samples ({self.min_samples}) cannot exceed the "
                f"window ({self.window})"
            )
        if not 0.0 < self.failure_threshold <= 1.0:
            raise ValueError(
                f"failure_threshold must be in (0, 1], got "
                f"{self.failure_threshold}"
            )
        if self.open_duration <= 0:
            raise ValueError("open_duration must be > 0")
        if self.close_after < 1:
            raise ValueError("close_after must be >= 1")
        if self.probe_interval <= 0:
            raise ValueError("probe_interval must be > 0")


class CircuitBreaker:
    """Windowed-failure-rate breaker (see module doc for the state machine)."""

    def __init__(self, config: BreakerConfig) -> None:
        self.config = config
        self._outcomes: Deque[bool] = collections.deque(maxlen=config.window)
        self._state = CLOSED
        self._opened_at: Optional[float] = None
        self._half_open_successes = 0
        self._probe_inflight = False
        # lifetime transition counters (stats/reporting)
        self.opened = 0    # closed -> open trips
        self.reopened = 0  # half-open probe failures
        self.closed = 0    # half-open -> closed recoveries
        #: (time, new state) transition log — determinism/debug artifact.
        self.transitions: List[Tuple[float, str]] = []

    # --------------------------------------------------------------- queries
    def _promote(self, now: float) -> None:
        """Lazy open → half-open once the open interval has elapsed."""
        if (self._state == OPEN and self._opened_at is not None
                and now >= self._opened_at + self.config.open_duration):
            self._state = HALF_OPEN
            self._half_open_successes = 0
            self._probe_inflight = False
            self.transitions.append((now, HALF_OPEN))

    def state(self, now: float) -> str:
        self._promote(now)
        return self._state

    def blocked_until(self, now: float) -> Optional[float]:
        """Earliest instant a probe may go out (None = not blocked)."""
        self._promote(now)
        if self._state != OPEN or self._opened_at is None:
            return None
        return self._opened_at + self.config.open_duration

    def failure_rate(self) -> float:
        """Failure fraction of the current outcome window."""
        if not self._outcomes:
            return 0.0
        return sum(self._outcomes) / len(self._outcomes)

    def try_probe(self, now: float) -> bool:
        """Claim the dispatch slot: True = the caller may attempt now.

        Closed state always admits; half-open admits exactly one probe at
        a time (released by the next recorded outcome); open admits
        nothing — callers should wait until :meth:`blocked_until`.
        """
        self._promote(now)
        if self._state == CLOSED:
            return True
        if self._state == HALF_OPEN and not self._probe_inflight:
            self._probe_inflight = True
            return True
        return False

    # --------------------------------------------------------------- updates
    def record_success(self, now: float) -> None:
        self._promote(now)
        self._outcomes.append(False)
        if self._state == HALF_OPEN:
            self._probe_inflight = False
            self._half_open_successes += 1
            if self._half_open_successes >= self.config.close_after:
                self._state = CLOSED
                self._opened_at = None
                # the outage's failures must not instantly re-trip a
                # freshly recovered endpoint
                self._outcomes.clear()
                self.closed += 1
                self.transitions.append((now, CLOSED))

    def record_failure(self, now: float) -> bool:
        """Record one failed attempt; returns True when this failure
        transitioned the breaker into the open state (the caller's cue to
        brownout-shed the endpoint's queue)."""
        self._promote(now)
        self._outcomes.append(True)
        cfg = self.config
        if self._state == HALF_OPEN:
            # probe failed: back to open for another full interval
            self._state = OPEN
            self._opened_at = now
            self._probe_inflight = False
            self.reopened += 1
            self.transitions.append((now, OPEN))
            return True
        if (self._state == CLOSED
                and len(self._outcomes) >= cfg.min_samples
                and self.failure_rate() >= cfg.failure_threshold):
            self._state = OPEN
            self._opened_at = now
            self.opened += 1
            self.transitions.append((now, OPEN))
            return True
        return False

    # ----------------------------------------------------------------- stats
    def register_metrics(self, registry, prefix: str = "breaker") -> None:
        """Bind the lifetime transition counters into a MetricsRegistry."""
        b = registry.bind
        b(f"{prefix}.opened", lambda: self.opened)
        b(f"{prefix}.reopened", lambda: self.reopened)
        b(f"{prefix}.closed", lambda: self.closed)
        b(f"{prefix}.transitions", lambda: len(self.transitions))

    def stats(self, now: float) -> dict:
        return {
            "state": self.state(now),
            "failure_rate": self.failure_rate(),
            "opened": self.opened,
            "reopened": self.reopened,
            "closed": self.closed,
        }
