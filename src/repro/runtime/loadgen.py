"""Async load generation — replay the simulator's arrival processes live.

:class:`LoadGenerator` materializes an
:class:`~repro.simulation.arrivals.ArrivalProcess` into a concrete
schedule (same vectorized window sweep the simulator's arrival pump uses)
and submits one request per instant on the runtime clock, so the *same
workload* — Poisson, MMPP2, trace-modulated, or an explicit
:class:`~repro.simulation.arrivals.Schedule` — drives both the
discrete-event simulator and the wall-clock runtime.

:func:`run_replay` is the one-call harness the parity bench and tests
build on: construct a server + synthetic target + load generator for one
endpoint, run arrivals to exhaustion, drain, and hand back the summary.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.config import SLAConfig
from repro.core.request import Request
from repro.runtime.clock import Clock, FakeClock, run
from repro.runtime.server import AsyncProxyServer, RequestTicket, RuntimeConfig
from repro.runtime.targets import DispatchTarget, SyntheticTarget
from repro.serverless.latency import LatencyModel
from repro.simulation.arrivals import ArrivalProcess, Schedule, sample_schedule


class LoadGenerator:
    """Replays one arrival process against one server endpoint."""

    def __init__(self, server: AsyncProxyServer, arrivals: ArrivalProcess, *,
                 duration: float, rng=0, endpoint: Optional[str] = None,
                 payload_fn=None) -> None:
        if isinstance(arrivals, Schedule):
            times = arrivals.times[arrivals.times < duration]
        else:
            times = sample_schedule(arrivals, rng, duration)
        self.times = np.asarray(times, dtype=np.float64)
        self.server = server
        self.endpoint = endpoint
        self.payload_fn = payload_fn
        self.tickets: List[RequestTicket] = []

    async def run(self) -> List[RequestTicket]:
        """Submit every scheduled arrival at its instant; returns tickets."""
        clock = self.server.clock
        submit = self.server.submit
        for t in self.times:
            dt = t - clock.now()
            if dt > 0:
                await clock.sleep(dt)
            now = clock.now()
            payload = self.payload_fn() if self.payload_fn is not None else None
            req = Request(arrival_time=now, payload=payload,
                          endpoint=self.endpoint)
            self.tickets.append(submit(req, endpoint=self.endpoint))
        return self.tickets


@dataclasses.dataclass
class ReplayResult:
    """Outcome of one :func:`run_replay`."""

    summary: dict
    e2e_latencies: np.ndarray
    dispatch_log: list
    bucket_samples: Dict[int, List[float]]
    conservation: dict


def _spawn_streams(
        seed: int) -> Tuple[np.random.Generator, np.random.Generator]:
    """(arrivals, service) generators — mirrors the simulator's split."""
    arr_ss, svc_ss = np.random.SeedSequence(seed).spawn(2)
    return np.random.default_rng(arr_ss), np.random.default_rng(svc_ss)


def run_replay(*, policy: str, sla: SLAConfig, arrivals: ArrivalProcess,
               duration: float, workload: Optional[LatencyModel] = None,
               target: Optional[DispatchTarget] = None,
               target_concurrency: int = 0,
               policy_kwargs: Optional[dict] = None,
               config: Optional[RuntimeConfig] = None,
               clock: Optional[Clock] = None, seed: int = 0,
               endpoint: str = "ep", pack: bool = False) -> ReplayResult:
    """Run one endpoint's workload through the live runtime, start to drain.

    Either pass a ready ``target`` or a ``workload`` latency model (wrapped
    in a :class:`SyntheticTarget` on the service RNG stream). ``clock``
    defaults to :class:`FakeClock` — deterministic and faster than real
    time; pass :class:`~repro.runtime.clock.WallClock` for a true
    wall-clock run (the CI smoke does).
    """
    clk = clock if clock is not None else FakeClock()
    arr_rng, svc_rng = _spawn_streams(seed)
    server = AsyncProxyServer(clock=clk, config=config)
    if target is None:
        if workload is None:
            raise ValueError("need either target= or workload=")
        target = SyntheticTarget(workload, clk, rng=svc_rng,
                                 concurrency=target_concurrency)
    server.add_endpoint(endpoint, sla=sla, target=target, policy=policy,
                        policy_kwargs=policy_kwargs, pack=pack)
    gen = LoadGenerator(server, arrivals, duration=duration, rng=arr_rng,
                        endpoint=endpoint)

    async def main() -> None:
        await server.start()
        await gen.run()
        await server.drain()

    run(clk, main())
    return ReplayResult(
        summary=server.summary(),
        e2e_latencies=server.completions[endpoint].e2e.view().copy(),
        dispatch_log=list(server.dispatch_log),
        bucket_samples={b: list(v)
                        for b, v in server.bucket_samples[endpoint].items()},
        conservation=server.conservation(),
    )
