"""Sim↔real calibration bridge: measured batch latencies → simulator models.

Closes the loop :class:`~repro.serverless.latency.MeasuredLatency` was
designed for. A live source — an
:class:`~repro.runtime.server.AsyncProxyServer` run (its
``bucket_samples``), a real :class:`~repro.serving.engine.InferenceEngine`
profile, or a ``bench_batch_scaling.py`` CSV — yields per-bucket batch
latencies; :class:`Calibration` fits them into
:class:`~repro.serverless.latency.AffineLatency` /
:class:`~repro.serverless.latency.MeasuredLatency` parameters and
round-trips through a JSON document the simulator can load, so simulated
studies run against *measured* service-time curves instead of assumed
ones (the validation methodology of LazyBatching / HarmonyBatch).

Calibration JSON format (versioned; documented in README "Live runtime"):

.. code-block:: json

    {
      "version": 1,
      "source": "live:ep",
      "buckets": [
        {"bucket": 1, "n": 42, "mean_s": 0.021, "p95_s": 0.030},
        {"bucket": 4, "n": 17, "mean_s": 0.034, "p95_s": 0.047}
      ],
      "affine": {"a": 0.018, "c": 0.004},
      "noise_cv": 0.11
    }
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serverless.latency import AffineLatency, LatencyModel, MeasuredLatency

CALIBRATION_VERSION = 1


@dataclasses.dataclass
class BucketStat:
    """Summary of one bucket's measured batch latencies."""

    bucket: int
    n: int
    mean_s: float
    p95_s: Optional[float] = None


@dataclasses.dataclass
class Calibration:
    """Fitted per-bucket latency profile, serializable to/from JSON."""

    source: str
    buckets: List[BucketStat]
    affine_a: float
    affine_c: float
    noise_cv: float

    # ------------------------------------------------------------- builders
    @classmethod
    def from_samples(cls, samples: Dict[int, Sequence[float]],
                     source: str = "live") -> "Calibration":
        """Fit raw per-bucket samples (bucket → measured seconds list).

        Per-bucket means and the pooled noise CV come from the one
        canonical fit, :meth:`MeasuredLatency.from_samples`; this adds the
        per-bucket sample counts / P95s and the affine fit on top.
        """
        fitted = MeasuredLatency.from_samples(samples)
        means = dict(fitted.points)
        stats: List[BucketStat] = []
        for b, vals in sorted(samples.items()):
            arr = np.asarray([float(v) for v in vals], dtype=np.float64)
            if not len(arr):
                continue
            stats.append(BucketStat(
                bucket=int(b), n=int(len(arr)), mean_s=means[int(b)],
                p95_s=float(np.percentile(arr, 95)),
            ))
        affine = AffineLatency.fit([(s.bucket, s.mean_s) for s in stats])
        return cls(source=source, buckets=stats, affine_a=affine.a,
                   affine_c=affine.c, noise_cv=fitted.noise_cv)

    @classmethod
    def from_batch_scaling_csv(cls, path: str, workload: str) -> "Calibration":
        """Load one workload's curve from ``bench_batch_scaling.py`` output
        (columns ``workload, batch_size, rt_ms``)."""
        import csv

        samples: Dict[int, List[float]] = {}
        with open(path, newline="") as f:
            for row in csv.DictReader(f):
                if row["workload"] != workload:
                    continue
                samples.setdefault(int(row["batch_size"]), []).append(
                    float(row["rt_ms"]) / 1000.0
                )
        if not samples:
            raise ValueError(f"no rows for workload {workload!r} in {path}")
        return cls.from_samples(samples, source=f"bench:{workload}")

    # --------------------------------------------------------------- models
    def points(self) -> List[Tuple[int, float]]:
        return [(s.bucket, s.mean_s) for s in self.buckets]

    def measured_model(self, noise_cv: Optional[float] = None) -> MeasuredLatency:
        """The fitted piecewise-linear model the simulator should load."""
        return MeasuredLatency(
            points=self.points(),
            noise_cv=self.noise_cv if noise_cv is None else noise_cv,
            name=f"calibrated:{self.source}",
        )

    def affine_model(self, noise_cv: Optional[float] = None) -> AffineLatency:
        """The fitted affine model (the paper's primary s(b) = a + c·b)."""
        return AffineLatency(
            a=self.affine_a, c=self.affine_c,
            noise_cv=self.noise_cv if noise_cv is None else noise_cv,
            name=f"calibrated:{self.source}",
        )

    # ----------------------------------------------------------------- JSON
    def to_json(self) -> dict:
        return {
            "version": CALIBRATION_VERSION,
            "source": self.source,
            "buckets": [dataclasses.asdict(s) for s in self.buckets],
            "affine": {"a": self.affine_a, "c": self.affine_c},
            "noise_cv": self.noise_cv,
        }

    @classmethod
    def from_json(cls, doc: dict) -> "Calibration":
        if doc.get("version") != CALIBRATION_VERSION:
            raise ValueError(
                f"unsupported calibration version {doc.get('version')!r}"
            )
        return cls(
            source=doc["source"],
            buckets=[BucketStat(**s) for s in doc["buckets"]],
            affine_a=float(doc["affine"]["a"]),
            affine_c=float(doc["affine"]["c"]),
            noise_cv=float(doc["noise_cv"]),
        )

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str) -> "Calibration":
        with open(path) as f:
            return cls.from_json(json.load(f))

    # ----------------------------------------------------------- round-trip
    def roundtrip_errors(self, model: Optional[LatencyModel] = None, *,
                         seed: int = 0, reps: int = 400) -> Dict[int, float]:
        """Relative error, per bucket, of the fitted model's *simulated*
        mean batch latency against the measured mean.

        Draws ``reps`` samples per bucket through ``model.sample`` — the
        exact call the simulated platform makes — so the check covers the
        noise model as well as the mean curve (measure → fit → simulate).
        """
        model = model if model is not None else self.measured_model()
        rng = np.random.default_rng(seed)
        errors: Dict[int, float] = {}
        for s in self.buckets:
            sim_mean = float(np.mean(
                [model.sample(s.bucket, rng) for _ in range(reps)]
            ))
            errors[s.bucket] = abs(sim_mean - s.mean_s) / max(s.mean_s, 1e-12)
        return errors

    def verify_roundtrip(self, rtol: float = 0.10, **kw) -> Dict[int, float]:
        """Assert the measure→fit→simulate round-trip reproduces measured
        means within ``rtol`` on every bucket; returns per-bucket errors."""
        errors = self.roundtrip_errors(**kw)
        bad = {b: e for b, e in errors.items() if e > rtol}
        if bad:
            raise AssertionError(
                f"calibration round-trip outside {rtol:.0%}: {bad}"
            )
        return errors


def measure_engine(engine, *, prompt_len: int = 16,
                   gen_len: Optional[int] = None, repeats: int = 3,
                   seed: int = 0) -> Calibration:
    """Profile a real :class:`InferenceEngine` across its batch buckets.

    The live-hardware entry point of the bridge (requires JAX; not used by
    tests). Runs ``repeats`` generations per compiled bucket and fits the
    measured wall seconds.
    """
    rng = np.random.default_rng(seed)
    samples: Dict[int, List[float]] = {}
    for bucket in engine.ecfg.batch_buckets:
        for _ in range(repeats):
            prompts = rng.integers(
                0, engine.cfg.vocab_size, size=(bucket, prompt_len)
            ).astype(np.int32)
            _, timing = engine.generate(prompts, gen_len=gen_len)
            samples.setdefault(bucket, []).append(float(timing["latency_s"]))
    return Calibration.from_samples(samples, source=f"engine:{engine.cfg.name}")
