"""Wall-clock asyncio serving runtime — the live mirror of the simulator.

Layer map (README "Live runtime"): the same routing/policy/queue core the
simulators drive (`ProxyFrontend` → `Policy` → `BatchQueue`) is driven
here by real timers (:mod:`repro.runtime.clock`), real dispatch execution
against pluggable targets (:mod:`repro.runtime.targets`), replayed
arrival processes (:mod:`repro.runtime.loadgen`) and the sim↔real
calibration bridge (:mod:`repro.runtime.calibrate`).
"""
from repro.runtime.calibrate import BucketStat, Calibration, measure_engine
from repro.runtime.clock import Clock, FakeClock, WallClock, run
from repro.runtime.loadgen import (LoadGenerator, ReplayResult, run_replay)
from repro.runtime.server import (AsyncProxyServer, DeadlineExceeded,
                                  DrainTimeout, RequestTicket,
                                  RuntimeConfig, clamp_policy_kwargs)
from repro.runtime.targets import DispatchTarget, EngineTarget, SyntheticTarget

__all__ = [
    "AsyncProxyServer",
    "BucketStat",
    "Calibration",
    "Clock",
    "DeadlineExceeded",
    "DispatchTarget",
    "DrainTimeout",
    "EngineTarget",
    "FakeClock",
    "LoadGenerator",
    "ReplayResult",
    "RequestTicket",
    "RuntimeConfig",
    "SyntheticTarget",
    "WallClock",
    "clamp_policy_kwargs",
    "measure_engine",
    "run",
    "run_replay",
]
