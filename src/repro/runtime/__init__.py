"""Wall-clock asyncio serving runtime — the live mirror of the simulator.

Layer map (README "Live runtime"): the same routing/policy/queue core the
simulators drive (`ProxyFrontend` → `Policy` → `BatchQueue`) is driven
here by real timers (:mod:`repro.runtime.clock`), real dispatch execution
against pluggable targets (:mod:`repro.runtime.targets`), replayed
arrival processes (:mod:`repro.runtime.loadgen`), the sim↔real
calibration bridge (:mod:`repro.runtime.calibrate`), and the fault
tolerance layer — deterministic chaos injection
(:mod:`repro.runtime.faults`) and per-endpoint circuit breaking
(:mod:`repro.runtime.breaker`).
"""
from repro.runtime.breaker import BreakerConfig, CircuitBreaker
from repro.runtime.calibrate import BucketStat, Calibration, measure_engine
from repro.runtime.clock import Clock, FakeClock, WallClock, run
from repro.runtime.faults import (CrashFault, FaultConfig, FaultyTarget,
                                  InjectedFault, PartialBatchFault,
                                  PreemptedFault, UpstreamTimeout, fault_rng)
from repro.runtime.loadgen import (LoadGenerator, ReplayResult, run_replay)
from repro.runtime.server import (AsyncProxyServer, BrownoutShed,
                                  DeadlineExceeded, DrainTimeout,
                                  RequestTicket, RuntimeConfig, TargetError,
                                  clamp_policy_kwargs)
from repro.runtime.targets import DispatchTarget, EngineTarget, SyntheticTarget

__all__ = [
    "AsyncProxyServer",
    "BreakerConfig",
    "BrownoutShed",
    "BucketStat",
    "Calibration",
    "CircuitBreaker",
    "Clock",
    "CrashFault",
    "DeadlineExceeded",
    "DispatchTarget",
    "DrainTimeout",
    "EngineTarget",
    "FakeClock",
    "FaultConfig",
    "FaultyTarget",
    "InjectedFault",
    "LoadGenerator",
    "PartialBatchFault",
    "PreemptedFault",
    "ReplayResult",
    "RequestTicket",
    "RuntimeConfig",
    "SyntheticTarget",
    "TargetError",
    "UpstreamTimeout",
    "WallClock",
    "clamp_policy_kwargs",
    "fault_rng",
    "measure_engine",
    "run",
    "run_replay",
]
