"""AsyncProxyServer — the wall-clock reverse-proxy runtime.

This is the live counterpart of the discrete-event drivers in
``simulation/simulator.py``: the same ONE batching core — a
:class:`~repro.core.frontend.ProxyFrontend` routing over
:class:`~repro.core.batch_queue.Policy` instances on the shared
:class:`~repro.core.batch_queue.BatchQueue` — driven by real asyncio
timers instead of a simulated event heap. Policies are clock-free
(callers pass ``now``), so MLProxy and all four baselines run here
**unmodified**; the runtime contributes only:

* the **timer loop** — one task that sleeps until the frontend's merged
  ``next_event_time`` (woken early by arrivals/completions/shutdown) and
  fires ``on_timer``, exactly the role the simulator's generation-stamped
  timer events play;
* **dispatch execution** — every batch a policy dispatches becomes an
  asyncio task awaiting a :class:`~repro.runtime.targets.DispatchTarget`;
  the measured await time is the upstream latency fed back through
  ``on_response`` (the paper's measured feedback loop);
* **admission control / backpressure** — optional caps on per-endpoint
  queue depth and total outstanding requests; excess submissions are
  rejected at the door and accounted for;
* **graceful drain** — ``drain()`` stops admissions, flushes every queue,
  awaits in-flight work and asserts the runtime conservation invariant
  (``submitted == completed + rejected``, zero lost — the live mirror of
  the platform's ``assert_conserved``).

All interaction with the server must happen on its event loop (asyncio is
single-threaded; policies are not thread-safe).
"""
from __future__ import annotations

import asyncio
import dataclasses
import math
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.config import SLAConfig
from repro.core.frontend import ProxyFrontend
from repro.core.request import Batch, Request
from repro.runtime.clock import Clock, WallClock
from repro.runtime.targets import DispatchTarget
from repro.simulation.stats import CompletionLog


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Knobs of the live runtime (all independent of any policy)."""

    #: Per-endpoint pending-queue cap; a submission that would grow the
    #: policy queue past this is rejected. 0 = unlimited.
    max_queue: int = 0
    #: Cap on outstanding requests (accepted, not yet completed) across
    #: the whole server — the backpressure valve. 0 = unlimited.
    max_outstanding: int = 0
    #: Re-check cadence of the timer loop when no policy deadline is
    #: pending (it is otherwise woken by arrivals/completions).
    timer_idle: float = 1.0
    #: Floor between consecutive timer firings; guards against a policy
    #: whose ``next_event_time`` returns the same instant repeatedly
    #: (mirrors the simulator driver's ``min_time`` guard).
    min_timer_tick: float = 1e-6
    #: How policy batch caps exceeding a target's ``max_batch`` are
    #: handled at ``add_endpoint`` time: "clamp" rewrites the policy's cap
    #: down to the largest bucket; "error" raises immediately.
    oversize: str = "clamp"

    def __post_init__(self) -> None:
        if self.oversize not in ("clamp", "error"):
            raise ValueError(f"unknown oversize mode {self.oversize!r}")


class RequestTicket:
    """Handle returned by :meth:`AsyncProxyServer.submit`.

    ``future`` resolves when the request completes (or immediately, with
    ``rejected=True``, when admission control turns it away).
    """

    __slots__ = ("request", "future", "rejected", "endpoint")

    def __init__(self, request: Request, future: asyncio.Future,
                 endpoint: str, rejected: bool = False) -> None:
        self.request = request
        self.future = future
        self.endpoint = endpoint
        self.rejected = rejected

    @property
    def e2e_latency(self) -> Optional[float]:
        return self.request.e2e_latency


def clamp_policy_kwargs(policy: str, policy_kwargs: Optional[dict],
                        max_batch: int, mode: str = "clamp") -> dict:
    """Reconcile a policy's batch-size cap with an engine bucket ceiling.

    Policies dispatch up to their own cap (MLProxy's
    ``OptimizerConfig.max_bs_cap``, the baselines' ``batch_size``/
    ``max_cap``); a fixed-shape engine can only execute up to its largest
    compiled bucket. ``mode="clamp"`` rewrites the cap down to
    ``max_batch``; ``mode="error"`` raises so the mismatch fails at config
    time. (Dispatch-time chunking in ``serving/batcher.py`` is the safety
    net either way.)
    """
    kw = dict(policy_kwargs or {})

    def resolve(current: int, what: str) -> int:
        if current <= max_batch:
            return current
        if mode == "error":
            raise ValueError(
                f"{what} {current} exceeds the largest engine bucket "
                f"{max_batch}; lower the cap or add buckets"
            )
        return max_batch

    if policy == "mlproxy":
        from repro.core.config import OptimizerConfig, ProxyConfig

        pc: Optional[ProxyConfig] = kw.get("proxy_config")
        opt: OptimizerConfig = (
            pc.optimizer if pc is not None
            else kw.get("optimizer") or OptimizerConfig()
        )
        cap = resolve(opt.max_bs_cap, "mlproxy max_bs_cap")
        if cap != opt.max_bs_cap:
            opt = dataclasses.replace(opt, max_bs_cap=cap,
                                      initial_max_bs=min(opt.initial_max_bs, cap))
            if pc is not None:
                kw["proxy_config"] = dataclasses.replace(pc, optimizer=opt)
            else:
                kw["optimizer"] = opt
    elif policy == "static":
        if "batch_size" in kw:
            kw["batch_size"] = resolve(kw["batch_size"], "static batch_size")
    elif policy in ("clipper", "oracle"):
        kw["max_cap"] = resolve(kw.get("max_cap", 256), f"{policy} max_cap")
    return kw


class AsyncProxyServer:
    """Asyncio reverse proxy running the shared batching core live."""

    def __init__(self, clock: Optional[Clock] = None,
                 config: Optional[RuntimeConfig] = None) -> None:
        self.clock = clock if clock is not None else WallClock()
        self.config = config or RuntimeConfig()
        self.frontend = ProxyFrontend()
        self._targets: Dict[str, DispatchTarget] = {}

        # conservation ledger
        self.submitted = 0
        self.completed = 0
        self.rejected = 0
        self.failed = 0  # target raised; requests resolved with the error
        self._tickets: Dict[int, RequestTicket] = {}  # req_id → outstanding

        # dispatch bookkeeping
        self._batch_tasks: Set[asyncio.Task] = set()
        self.inflight_batches = 0
        #: (dispatch time, endpoint, size, effective size, cause) per batch
        #: — the decision log the determinism tests replay.
        self.dispatch_log: List[Tuple[float, str, int, int, str]] = []
        #: per-endpoint {bucket → [measured upstream seconds]} — the raw
        #: material of ``runtime/calibrate.py``.
        self.bucket_samples: Dict[str, Dict[int, List[float]]] = {}
        self.completions: Dict[str, CompletionLog] = {}

        self._wake = asyncio.Event()
        self._accepting = True
        self._running = False
        self._timer_task: Optional[asyncio.Task] = None

    # ------------------------------------------------------------- topology
    def add_endpoint(self, name: str, *, sla: SLAConfig,
                     target: DispatchTarget, policy: str = "mlproxy",
                     policy_kwargs: Optional[dict] = None) -> None:
        """Register an endpoint backed by ``target``.

        If the target declares a ``max_batch`` (fixed-shape engines), the
        policy's batch-size cap is reconciled with it per
        ``RuntimeConfig.oversize`` before the policy is built.
        """
        if target.max_batch is not None:
            policy_kwargs = clamp_policy_kwargs(
                policy, policy_kwargs, target.max_batch, self.config.oversize
            )
        self._targets[name] = target
        self.completions[name] = CompletionLog()
        self.bucket_samples[name] = {}

        def dispatch(batch: Batch, _name: str = name) -> None:
            self._on_dispatch(_name, batch)

        self.frontend.add_endpoint(name, sla=sla, dispatch_fn=dispatch,
                                   policy=policy, policy_kwargs=policy_kwargs)

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> None:
        if self._running:
            return
        self._running = True
        self._accepting = True
        self._timer_task = asyncio.get_running_loop().create_task(
            self._timer_loop()
        )

    async def drain(self) -> None:
        """Graceful shutdown: stop admissions, flush, await in-flight work.

        On return the conservation invariant holds in its drained form:
        every submitted request was completed (or rejected at the door),
        nothing queued, nothing in flight, nothing lost.
        """
        self._accepting = False
        self.frontend.flush(self.clock.now())
        while self._batch_tasks:
            await asyncio.gather(*list(self._batch_tasks),
                                 return_exceptions=True)
        self._running = False
        self._wake.set()
        if self._timer_task is not None:
            await self._timer_task
            self._timer_task = None
        self.assert_conserved(require_drained=True)

    # -------------------------------------------------------------- ingress
    def submit(self, request: Optional[Request] = None, *,
               endpoint: Optional[str] = None, payload=None) -> RequestTicket:
        """Admit one request (event-loop thread only); returns its ticket."""
        now = self.clock.now()
        if request is None:
            request = Request(arrival_time=now, payload=payload)
        ep = self.frontend.resolve(endpoint or request.endpoint)
        loop = asyncio.get_running_loop()
        future: asyncio.Future = loop.create_future()
        self.submitted += 1

        cfg = self.config
        outstanding = self.submitted - self.completed - self.rejected \
            - self.failed - 1  # excluding this request
        reject = (
            not self._accepting
            or (cfg.max_outstanding > 0 and outstanding >= cfg.max_outstanding)
            or (cfg.max_queue > 0 and ep.policy.queue_len >= cfg.max_queue)
        )
        if reject:
            self.rejected += 1
            ticket = RequestTicket(request, future, ep.name, rejected=True)
            future.set_result(ticket)
            return ticket

        ticket = RequestTicket(request, future, ep.name)
        self._tickets[request.req_id] = ticket
        self.frontend.on_request(request, now, endpoint=ep.name)
        self._wake.set()  # deadline may have changed
        return ticket

    # ------------------------------------------------------------- dispatch
    def _on_dispatch(self, name: str, batch: Batch) -> None:
        """Policy handed us a batch (synchronously, on the loop thread)."""
        now = self.clock.now()
        self.dispatch_log.append(
            (now, name, batch.size, batch.effective_size, batch.cause)
        )
        self.inflight_batches += 1
        task = asyncio.get_running_loop().create_task(
            self._run_batch(name, batch, now)
        )
        self._batch_tasks.add(task)
        task.add_done_callback(self._batch_tasks.discard)

    async def _run_batch(self, name: str, batch: Batch, t0: float) -> None:
        target = self._targets[name]
        error: Optional[BaseException] = None
        try:
            await target(batch)
        except Exception as exc:  # noqa: BLE001 — resolved into tickets
            error = exc
        now = self.clock.now()
        self.inflight_batches -= 1
        if error is None:
            latency = now - t0
            self.frontend.on_response(batch, latency, now)
            self.bucket_samples[name].setdefault(
                batch.effective_size, []
            ).append(latency)
            log = self.completions[name]
            for r in batch.requests:
                log.append(now, now - r.arrival_time, r.arrival_time)
                ticket = self._tickets.pop(r.req_id, None)
                if ticket is not None and not ticket.future.done():
                    ticket.future.set_result(ticket)
            self.completed += batch.size
        else:
            for r in batch.requests:
                ticket = self._tickets.pop(r.req_id, None)
                if ticket is not None and not ticket.future.done():
                    ticket.future.set_exception(error)
            self.failed += batch.size
        self._wake.set()

    # ---------------------------------------------------------------- timer
    async def _timer_loop(self) -> None:
        cfg = self.config
        while self._running:
            now = self.clock.now()
            self.frontend.on_timer(now)
            nxt = self.frontend.next_event_time(now)
            if nxt is None:
                timeout: Optional[float] = cfg.timer_idle
            else:
                timeout = max(nxt - now, cfg.min_timer_tick)
            await self.clock.wait(self._wake, timeout)
            self._wake.clear()

    # ---------------------------------------------------------- conservation
    def conservation(self) -> dict:
        queue_len = sum(
            ep["queue_len"]
            for ep in self.frontend.stats(self.clock.now())["endpoints"].values()
        )
        outstanding = len(self._tickets)
        lost = (self.submitted - self.completed - self.rejected
                - self.failed - outstanding)
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "failed": self.failed,
            "outstanding": outstanding,
            "queued": queue_len,
            "inflight_batches": self.inflight_batches,
            "lost": lost,
        }

    def assert_conserved(self, require_drained: bool = False) -> dict:
        """Raise ``AssertionError`` on any broken runtime invariant.

        Mirrors ``ServerlessPlatform.assert_conserved``: nothing lost at
        any instant; with ``require_drained``, nothing outstanding either
        (``submitted == completed + rejected``, zero failed).
        """
        c = self.conservation()
        if c["lost"] != 0:
            raise AssertionError(f"runtime lost requests: {c}")
        if require_drained:
            if c["outstanding"] or c["queued"] or c["inflight_batches"]:
                raise AssertionError(f"undrained work at shutdown: {c}")
            if c["failed"]:
                raise AssertionError(f"failed dispatches at shutdown: {c}")
            if c["submitted"] != c["completed"] + c["rejected"]:
                raise AssertionError(f"conservation imbalance: {c}")
        return c

    # --------------------------------------------------------------- metrics
    def summary(self) -> dict:
        """Fleet summary with the same headline keys as ``SimResult``."""
        now = self.clock.now()
        fstats = self.frontend.stats(now)
        per: Dict[str, dict] = {}
        all_e2e: List[np.ndarray] = []
        total_viol = 0.0
        for name in self.frontend.names:
            ep = self.frontend.endpoint(name)
            e2e = self.completions[name].e2e.view()
            all_e2e.append(e2e)
            viol = (float(np.mean(e2e > ep.sla.slo_target))
                    if len(e2e) else 0.0)
            total_viol += viol * len(e2e)
            st = fstats["endpoints"][name]
            per[name] = {
                "completed": float(len(e2e)),
                "slo_target": ep.sla.slo_target,
                "violation_rate": viol,
                "violation_pct": 100.0 * viol,
                "p50": float(np.percentile(e2e, 50)) if len(e2e) else math.nan,
                "p95": float(np.percentile(e2e, 95)) if len(e2e) else math.nan,
                "mean_latency": float(e2e.mean()) if len(e2e) else math.nan,
                "avg_batch_size": st.get("avg_batch_size", 0.0),
                "dispatched_batches": float(st.get("dispatched_batches", 0)),
                "max_bs": float(st.get("max_bs", 1)),
                "retry_rate": float(st.get("retry_rate", 0.0)),
            }
        e2e = np.concatenate(all_e2e) if all_e2e else np.empty(0)
        n = len(e2e)
        cons = self.conservation()
        summary = {
            "completed": float(n),
            "violation_rate": total_viol / n if n else 0.0,
            "violation_pct": 100.0 * total_viol / n if n else 0.0,
            "p50": float(np.percentile(e2e, 50)) if n else math.nan,
            "p95": float(np.percentile(e2e, 95)) if n else math.nan,
            "p99": float(np.percentile(e2e, 99)) if n else math.nan,
            "mean_latency": float(e2e.mean()) if n else math.nan,
            "avg_batch_size": fstats["aggregate"]["avg_batch_size"],
            "dispatched_batches": float(
                fstats["aggregate"]["dispatched_batches"]
            ),
            "submitted": float(cons["submitted"]),
            "rejected": float(cons["rejected"]),
            "lost": float(cons["lost"]),
            "throughput": n / now if now > 0 else 0.0,
            "endpoints": per,
        }
        return summary
