"""AsyncProxyServer — the wall-clock reverse-proxy runtime.

This is the live counterpart of the discrete-event drivers in
``simulation/simulator.py``: the same ONE batching core — a
:class:`~repro.core.frontend.ProxyFrontend` routing over
:class:`~repro.core.batch_queue.Policy` instances on the shared
:class:`~repro.core.batch_queue.BatchQueue` — driven by real asyncio
timers instead of a simulated event heap. Policies are clock-free
(callers pass ``now``), so MLProxy and all four baselines run here
**unmodified**; the runtime contributes only:

* the **timer loop** — one task that sleeps until the frontend's merged
  ``next_event_time`` (woken early by arrivals/completions/shutdown) and
  fires ``on_timer``, exactly the role the simulator's generation-stamped
  timer events play;
* **dispatch execution** — every batch a policy dispatches becomes an
  asyncio task awaiting a :class:`~repro.runtime.targets.DispatchTarget`;
  the measured await time is the upstream latency fed back through
  ``on_response`` (the paper's measured feedback loop);
* **admission control / backpressure** — optional caps on per-endpoint
  queue depth and total outstanding requests; excess submissions are
  rejected at the door and accounted for;
* **deadline enforcement** — requests carry an absolute deadline
  (client-supplied or derived from the endpoint SLA); the shared
  ``BatchQueue`` expiry sweep evicts dead requests before batch
  formation, their tickets resolve with a :class:`DeadlineExceeded`
  result, and the batch's tightest remaining deadline is propagated to
  the dispatch target;
* **proxy-tier straggler hedging** — a dispatched batch that exceeds the
  configured quantile of its bucket's measured latency is re-issued to
  the target; first completion wins and the loser is cancelled (the
  proxy-side mirror of the platform's hedge ledger);
* **deadline-aware retries** — a failed dispatch attempt is retried with
  capped exponential backoff plus seeded jitter, but never past the
  batch's tightest deadline: leftover budget resolves the tickets
  ``timed_out`` (the SLA already lost), an exhausted retry budget
  resolves them ``failed`` with a :class:`TargetError`;
* **circuit breaking + brownout shedding** — an optional per-endpoint
  :class:`~repro.runtime.breaker.CircuitBreaker` opens on a windowed
  failure rate; while it is not closed, admission runs in brownout
  (tightened ``max_queue``/``max_outstanding`` caps) and the open
  transition sheds the endpoint's lowest-slack queued requests — both
  accounted in the dedicated ``shed`` ledger class, distinct from
  ``rejected`` (hard caps) and ``timed_out`` (deadlines);
* **graceful drain** — ``drain(timeout=...)`` stops admissions, flushes
  every queue, awaits in-flight work (cancelling stragglers — including
  batches parked on a retry backoff or a breaker probe wait — at the
  timeout) and asserts the runtime conservation invariant
  (``submitted == completed + rejected + shed + timed_out + failed``,
  zero lost — the live mirror of the platform's ``assert_conserved``).

All interaction with the server must happen on its event loop (asyncio is
single-threaded; policies are not thread-safe).
"""
from __future__ import annotations

import asyncio
import contextlib
import dataclasses
import inspect
import math
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.config import SLAConfig
from repro.core.frontend import ProxyFrontend, SpilloverRouter
from repro.obs.metrics import MetricsRegistry
from repro.core.request import Batch, Request
from repro.runtime.breaker import CLOSED, BreakerConfig, CircuitBreaker
from repro.runtime.clock import Clock, WallClock
from repro.runtime.targets import DispatchTarget
from repro.simulation.stats import CompletionLog


class DeadlineExceeded(Exception):
    """A request's deadline passed while it was still queued at the proxy.

    Its ticket resolves normally (``ticket.timed_out`` is True and
    ``ticket.error`` carries this exception); the request was never
    dispatched or billed.
    """


class DrainTimeout(Exception):
    """A dispatched batch was cancelled because ``drain(timeout=...)``
    expired before its target completed; its requests are accounted as
    ``failed`` and their tickets resolve with this error."""


class TargetError(Exception):
    """A dispatch target kept failing until the retry budget ran out.

    The final upstream exception is chained as ``__cause__``; the batch's
    requests are accounted as ``failed`` and their tickets resolve with
    this error — a buggy target degrades one batch, not the whole drain.
    """

    def __init__(self, message: str, attempts: int = 1) -> None:
        super().__init__(message)
        self.attempts = attempts


class BrownoutShed(Exception):
    """A request was shed by brownout admission control: its endpoint's
    circuit breaker is not closed, so the proxy is deliberately dropping
    load it cannot serve within SLA. The ticket resolves normally with
    ``shed=True`` and this error attached; the request was never
    dispatched or billed (a distinct ledger class from ``rejected``)."""


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Knobs of the live runtime (all independent of any policy)."""

    #: Per-endpoint pending-queue cap; a submission that would grow the
    #: policy queue past this is rejected. 0 = unlimited.
    max_queue: int = 0
    #: Cap on outstanding requests (accepted, not yet completed) across
    #: the whole server — the backpressure valve. 0 = unlimited.
    max_outstanding: int = 0
    #: Re-check cadence of the timer loop when no policy deadline is
    #: pending (it is otherwise woken by arrivals/completions).
    timer_idle: float = 1.0
    #: Floor between consecutive timer firings; guards against a policy
    #: whose ``next_event_time`` returns the same instant repeatedly
    #: (mirrors the simulator driver's ``min_time`` guard).
    min_timer_tick: float = 1e-6
    #: How policy batch caps exceeding a target's ``max_batch`` are
    #: handled at ``add_endpoint`` time: "clamp" rewrites the policy's cap
    #: down to the largest bucket; "error" raises immediately.
    oversize: str = "clamp"
    #: Proxy-tier straggler hedging: a dispatched batch still unfinished
    #: after the ``hedge_quantile``-th percentile of its bucket's measured
    #: upstream latency is re-issued to the target; first completion wins,
    #: the loser is cancelled. Percentile units (e.g. 95.0); <= 0 disables.
    hedge_quantile: float = 0.0
    #: Minimum in-window latency samples for a bucket before hedging arms
    #: (a cold bucket has no trustworthy straggler threshold).
    hedge_min_samples: int = 10
    #: Proxy-tier retry budget per batch: a failed dispatch attempt is
    #: retried up to this many times with capped exponential backoff,
    #: never past the batch's tightest deadline. 0 disables retries (a
    #: failed batch resolves immediately — the pre-fault-tolerance
    #: behaviour, and the byte-identity default).
    max_retries: int = 0
    #: Backoff before the first retry; attempt k waits
    #: ``min(retry_backoff * 2**(k-1), retry_backoff_cap)`` seconds.
    retry_backoff: float = 0.05
    retry_backoff_cap: float = 2.0
    #: Uniform jitter fraction multiplied onto each backoff (decorrelates
    #: retry storms); drawn from the seeded retry stream, one draw per
    #: retry actually scheduled, so no-retry runs never touch the stream.
    retry_jitter: float = 0.1
    #: Seed of the retry-jitter stream.
    retry_seed: int = 0
    #: Per-endpoint circuit breaker; None disables breaking (and with it
    #: brownout shedding).
    breaker: Optional[BreakerConfig] = None
    #: Brownout queue cap while an endpoint's breaker is not closed: the
    #: endpoint's pending queue is held at this depth (excess submissions
    #: are shed, and the open transition sheds queued requests down to
    #: it, lowest slack first). 0 disables queue brownout.
    brownout_queue: int = 4
    #: Brownout cap on total outstanding requests while ANY breaker is
    #: not closed. 0 disables outstanding brownout.
    brownout_outstanding: int = 0

    def __post_init__(self) -> None:
        if self.oversize not in ("clamp", "error"):
            raise ValueError(f"unknown oversize mode {self.oversize!r}")
        if self.hedge_quantile > 100 or 0 < self.hedge_quantile < 1:
            # fractions like 0.95 would silently hedge at the bucket
            # MINIMUM (rank ⌈0.0095·n⌉), doubling upstream load
            raise ValueError(
                f"hedge_quantile is in percentile units ((1, 100], e.g. "
                f"95.0; <= 0 disables), got {self.hedge_quantile}"
            )
        if self.hedge_min_samples < 1:
            raise ValueError("hedge_min_samples must be >= 1")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.retry_backoff <= 0 or self.retry_backoff_cap <= 0:
            raise ValueError("retry backoffs must be > 0")
        if self.retry_jitter < 0:
            raise ValueError("retry_jitter must be >= 0")
        if self.brownout_queue < 0 or self.brownout_outstanding < 0:
            raise ValueError("brownout caps must be >= 0 (0 disables)")


class RequestTicket:
    """Handle returned by :meth:`AsyncProxyServer.submit`.

    ``future`` resolves with the ticket when the request completes — or
    immediately with ``rejected=True`` when admission control turns it
    away, with ``shed=True`` (and ``error`` set to a
    :class:`BrownoutShed`) when brownout admission dropped it, or with
    ``timed_out=True`` (and ``error`` set to a :class:`DeadlineExceeded`)
    when the request's deadline expired while it was still queued.
    """

    __slots__ = ("request", "future", "rejected", "endpoint", "timed_out",
                 "shed", "error")

    def __init__(self, request: Request, future: asyncio.Future,
                 endpoint: str, rejected: bool = False) -> None:
        self.request = request
        self.future = future
        self.endpoint = endpoint
        self.rejected = rejected
        self.timed_out = False
        self.shed = False
        self.error: Optional[BaseException] = None

    @property
    def e2e_latency(self) -> Optional[float]:
        return self.request.e2e_latency


def clamp_policy_kwargs(policy: str, policy_kwargs: Optional[dict],
                        max_batch: int, mode: str = "clamp") -> dict:
    """Reconcile a policy's batch-size cap with an engine bucket ceiling.

    Policies dispatch up to their own cap (MLProxy's
    ``OptimizerConfig.max_bs_cap``, the baselines' ``batch_size``/
    ``max_cap``); a fixed-shape engine can only execute up to its largest
    compiled bucket. ``mode="clamp"`` rewrites the cap down to
    ``max_batch``; ``mode="error"`` raises so the mismatch fails at config
    time. (Dispatch-time chunking in ``serving/batcher.py`` is the safety
    net either way.)
    """
    kw = dict(policy_kwargs or {})

    def resolve(current: int, what: str) -> int:
        if current <= max_batch:
            return current
        if mode == "error":
            raise ValueError(
                f"{what} {current} exceeds the largest engine bucket "
                f"{max_batch}; lower the cap or add buckets"
            )
        return max_batch

    if policy == "mlproxy":
        from repro.core.config import OptimizerConfig, ProxyConfig

        pc: Optional[ProxyConfig] = kw.get("proxy_config")
        opt: OptimizerConfig = (
            pc.optimizer if pc is not None
            else kw.get("optimizer") or OptimizerConfig()
        )
        cap = resolve(opt.max_bs_cap, "mlproxy max_bs_cap")
        if cap != opt.max_bs_cap:
            opt = dataclasses.replace(opt, max_bs_cap=cap,
                                      initial_max_bs=min(opt.initial_max_bs, cap))
            if pc is not None:
                kw["proxy_config"] = dataclasses.replace(pc, optimizer=opt)
            else:
                kw["optimizer"] = opt
    elif policy == "static":
        if "batch_size" in kw:
            kw["batch_size"] = resolve(kw["batch_size"], "static batch_size")
    elif policy in ("clipper", "oracle"):
        if "max_cap" in kw:
            # the caller chose this cap: clamp or error per `mode`
            kw["max_cap"] = resolve(kw["max_cap"], f"{policy} max_cap")
        else:
            # The caller never set a cap — the policy's own default
            # applies. Lower it silently if it exceeds the engine bucket
            # (a default is not a caller choice, so `mode="error"` must
            # not raise, and clamping must never *raise* the cap).
            from repro.core.policies import DEFAULT_MAX_CAP

            if DEFAULT_MAX_CAP > max_batch:
                kw["max_cap"] = max_batch
    return kw


class AsyncProxyServer:
    """Asyncio reverse proxy running the shared batching core live."""

    def __init__(self, clock: Optional[Clock] = None,
                 config: Optional[RuntimeConfig] = None,
                 tracer=None, recorder=None) -> None:
        self.clock = clock if clock is not None else WallClock()
        self.config = config or RuntimeConfig()
        # Observability plane (both optional and zero-cost when None):
        # ``tracer`` (repro.obs.trace.Tracer) records lifecycle spans,
        # ``recorder`` (repro.obs.recorder.FlightRecorder) keeps the
        # bounded postmortem ring dumped on conservation failure, drain
        # timeout, or breaker-open.
        self.tracer = tracer
        self.recorder = recorder
        self.frontend = ProxyFrontend(tracer=tracer)
        self._targets: Dict[str, DispatchTarget] = {}
        self._target_takes_deadline: Dict[str, bool] = {}

        # conservation ledger:
        #   submitted == completed + rejected + shed + timed_out + failed
        #                + outstanding   (drained: outstanding == 0)
        self.submitted = 0
        self.completed = 0
        self.rejected = 0
        self.shed = 0  # brownout admission drop; never dispatched
        self.timed_out = 0  # deadline expired while queued; never dispatched
        self.failed = 0  # target raised; requests resolved with the error
        # Subset of `failed` that drain(timeout=) itself cancelled, and the
        # subset a target's exhausted retry budget produced (TargetError).
        # A clean shutdown tolerates exactly their sum — any OTHER failure
        # at drain still trips assert_conserved, preserving the "lost
        # accounting cannot slip through drain()" signal.
        self.drain_cancelled = 0
        self.target_failures = 0
        self._tickets: Dict[int, RequestTicket] = {}  # req_id → outstanding

        # active-window anchors for summary() throughput (the clock may
        # predate the server, and summaries may run after idle gaps)
        self._first_submit: Optional[float] = None
        self._last_completion: Optional[float] = None

        # proxy-tier straggler hedging
        self.hedged_batches = 0  # duplicates issued
        self.hedge_wins = 0      # duplicates that finished first
        self._hedged_by_ep: Dict[str, int] = {}
        self._hedge_wins_by_ep: Dict[str, int] = {}
        # per-endpoint admissions (the sim surfaces submitted_requests per
        # endpoint; key-parity requires the live summary to match)
        self._submitted_by_ep: Dict[str, int] = {}

        # proxy-tier retries + circuit breaking (fault tolerance)
        self.retried_batches = 0    # batches that needed >= 1 proxy retry
        self.retry_exhausted = 0    # batches whose retry budget ran out
        self.faulted_batches = 0    # batches with >= 1 failed attempt
        self.recovered_batches = 0  # faulted batches that still completed
        # completions whose ticket was already resolved — must stay 0;
        # the "zero duplicate completions" half of the chaos invariant
        self.duplicate_completions = 0
        #: (time, endpoint, batch size, failure #, backoff, error type)
        #: per retry actually scheduled — the fault-determinism artifact.
        self.retry_log: List[Tuple[float, str, int, int, float, str]] = []
        self._breakers: Dict[str, CircuitBreaker] = {}
        # seeded retry-jitter stream; drawn once per scheduled retry, in
        # scheduling order, so FakeClock runs stay bit-identical
        self._retry_rng = np.random.default_rng(
            np.random.SeedSequence(self.config.retry_seed))

        # dispatch bookkeeping
        self._batch_tasks: Set[asyncio.Task] = set()
        self.inflight_batches = 0
        #: (dispatch time, endpoint, size, effective size, cause) per batch
        #: — the decision log the determinism tests replay.
        self.dispatch_log: List[Tuple[float, str, int, int, str]] = []
        #: per-endpoint {bucket → [measured upstream seconds]} — the raw
        #: material of ``runtime/calibrate.py``.
        self.bucket_samples: Dict[str, Dict[int, List[float]]] = {}
        self.completions: Dict[str, CompletionLog] = {}

        # event-loop work counter: one tick per handled event (admission,
        # dispatch, expiry sweep, batch resolution, timer pass) — the live
        # mirror of the simulator drivers' ``events_processed``
        self.events_processed = 0

        self._wake = asyncio.Event()
        self._accepting = True
        self._running = False
        self._timer_task: Optional[asyncio.Task] = None

        # Central metrics surface: every hand-rolled ledger counter above
        # is bound (read-only, zero hot-path cost) into one registry.
        self.metrics = MetricsRegistry()
        self.register_metrics(self.metrics)

    def register_metrics(self, registry: "MetricsRegistry",
                         prefix: str = "server") -> None:
        """Bind the runtime ledger into a MetricsRegistry.

        Enforced by the ``unregistered-counter`` reprolint rule: every
        monotonic counter this class increments must be bound here (or
        carry an explicit suppression)."""
        b = registry.bind
        b(f"{prefix}.submitted", lambda: self.submitted)
        b(f"{prefix}.completed", lambda: self.completed)
        b(f"{prefix}.rejected", lambda: self.rejected)
        b(f"{prefix}.shed", lambda: self.shed)
        b(f"{prefix}.timed_out", lambda: self.timed_out)
        b(f"{prefix}.failed", lambda: self.failed)
        b(f"{prefix}.drain_cancelled", lambda: self.drain_cancelled)
        b(f"{prefix}.target_failures", lambda: self.target_failures)
        b(f"{prefix}.hedged_batches", lambda: self.hedged_batches)
        b(f"{prefix}.hedge_wins", lambda: self.hedge_wins)
        b(f"{prefix}.retried_batches", lambda: self.retried_batches)
        b(f"{prefix}.retry_exhausted", lambda: self.retry_exhausted)
        b(f"{prefix}.faulted_batches", lambda: self.faulted_batches)
        b(f"{prefix}.recovered_batches", lambda: self.recovered_batches)
        b(f"{prefix}.duplicate_completions",
          lambda: self.duplicate_completions)
        b(f"{prefix}.inflight_batches", lambda: self.inflight_batches)
        b(f"{prefix}.events_processed", lambda: self.events_processed)

    # ------------------------------------------------------------- topology
    def add_endpoint(self, name: str, *, sla: SLAConfig,
                     target: DispatchTarget, policy: str = "mlproxy",
                     policy_kwargs: Optional[dict] = None,
                     pack: bool = False,
                     router: Optional["SpilloverRouter"] = None) -> None:
        """Register an endpoint backed by ``target``.

        If the target declares a ``max_batch`` (fixed-shape engines), the
        policy's batch-size cap is reconciled with it per
        ``RuntimeConfig.oversize`` before the policy is built.

        ``pack=True`` turns on bucket-aware packing against the target's
        ``batch_buckets``: the policy's full-trigger rounds its batch
        target up to the next engine bucket edge and dispatches exactly at
        it, so "full" batches execute with zero padding (the padding-waste
        stat in :meth:`summary` shows the effect).

        ``router`` attaches a :class:`~repro.core.frontend.SpilloverRouter`
        that stamps ``batch.tier`` at dispatch; pair it with a
        :class:`~repro.runtime.targets.TieredTarget` whose tier names
        match the router's so stamped batches land on the right fleet.
        """
        if pack:
            buckets = getattr(target, "batch_buckets", None)
            if not buckets:
                raise ValueError(
                    f"pack=True needs a target exposing batch_buckets; "
                    f"{type(target).__name__} has none")
            policy_kwargs = dict(policy_kwargs or {})
            if policy == "mlproxy" and "proxy_config" in policy_kwargs:
                pc = policy_kwargs["proxy_config"]
                policy_kwargs["proxy_config"] = dataclasses.replace(
                    pc, pack_buckets=tuple(buckets))
            else:
                policy_kwargs.setdefault("pack_buckets", tuple(buckets))
        if target.max_batch is not None:
            policy_kwargs = clamp_policy_kwargs(
                policy, policy_kwargs, target.max_batch, self.config.oversize
            )
        self._targets[name] = target
        # Older/external targets may predate the ``deadline=`` parameter;
        # probe once at config time instead of discovering mid-dispatch.
        try:
            params = inspect.signature(target.__call__).parameters
            takes_deadline = ("deadline" in params or any(
                p.kind is inspect.Parameter.VAR_KEYWORD
                for p in params.values()))
        except (TypeError, ValueError):
            takes_deadline = False
        self._target_takes_deadline[name] = takes_deadline
        self.completions[name] = CompletionLog()
        self.bucket_samples[name] = {}
        self._submitted_by_ep[name] = 0
        self._hedged_by_ep[name] = 0
        self._hedge_wins_by_ep[name] = 0
        if self.config.breaker is not None:
            self._breakers[name] = CircuitBreaker(self.config.breaker)
            self._breakers[name].register_metrics(
                self.metrics, prefix=f"endpoint.{name}.breaker")

        def dispatch(batch: Batch, _name: str = name) -> None:
            self._on_dispatch(_name, batch)

        def expire(requests: List[Request], now: float,
                   _name: str = name) -> None:
            self._on_expired(_name, requests, now)

        ep = self.frontend.add_endpoint(
            name, sla=sla, dispatch_fn=dispatch,
            policy=policy, policy_kwargs=policy_kwargs, expire_fn=expire,
            router=router)
        if router is not None:
            router.register_metrics(self.metrics,
                                    prefix=f"endpoint.{name}.router")
        monitor = getattr(ep.policy, "monitor", None)
        if monitor is not None:
            monitor.register_metrics(self.metrics,
                                     prefix=f"endpoint.{name}")
        queue = getattr(
            getattr(ep.policy, "scheduler", ep.policy), "queue", None)
        if queue is not None:
            queue.register_metrics(self.metrics,
                                   prefix=f"endpoint.{name}.queue")

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> None:
        if self._running:
            return
        self._running = True
        self._accepting = True
        self._timer_task = asyncio.get_running_loop().create_task(
            self._timer_loop()
        )

    async def drain(self, timeout: Optional[float] = None) -> None:
        """Graceful shutdown: stop admissions, flush, await in-flight work.

        ``timeout`` (seconds on the runtime clock) bounds the wait for
        in-flight batches: stragglers still running when it expires are
        cancelled, their tickets resolve with a :class:`DrainTimeout`
        error, and their requests are accounted as ``failed`` — a stuck
        upstream can no longer hang the process. ``None`` waits
        indefinitely (the pre-deadline behaviour).

        On return the conservation invariant holds in its drained form:
        every submitted request was completed, rejected at the door,
        timed out on its deadline, or failed — nothing queued, nothing in
        flight, nothing lost.
        """
        self._accepting = False
        self.frontend.flush(self.clock.now())
        if timeout is None:
            while self._batch_tasks:
                await asyncio.gather(*list(self._batch_tasks),
                                     return_exceptions=True)
        else:
            await self._drain_bounded(timeout)
        self._running = False
        self._wake.set()
        if self._timer_task is not None:
            await self._timer_task
            self._timer_task = None
        self.assert_conserved(require_drained=True)

    async def _drain_bounded(self, timeout: float) -> None:
        """Await in-flight batches up to ``timeout``, then cancel the rest."""
        # Let freshly created batch tasks take their first step so each
        # one owns its bookkeeping before any cancellation can reach it.
        await asyncio.sleep(0)
        loop = asyncio.get_running_loop()

        async def settle() -> None:
            while self._batch_tasks:
                await asyncio.gather(*list(self._batch_tasks),
                                     return_exceptions=True)

        waiter = loop.create_task(settle())
        timer = loop.create_task(self.clock.sleep(timeout))
        await asyncio.wait({waiter, timer},
                           return_when=asyncio.FIRST_COMPLETED)
        if waiter.done():
            await self._cancel(timer)
            return
        await self._cancel(waiter)
        stragglers = list(self._batch_tasks)
        if stragglers and self.recorder is not None:
            self.recorder.dump("drain_timeout", now=self.clock.now(),
                               extra={"stragglers": len(stragglers),
                                      "timeout": timeout})
        for t in stragglers:
            t.cancel()
        # _run_batch converts the cancellation into failed-accounting and
        # finishes normally; gather collects stragglers either way.
        await asyncio.gather(*stragglers, return_exceptions=True)

    # -------------------------------------------------------------- ingress
    def submit(self, request: Optional[Request] = None, *,
               endpoint: Optional[str] = None, payload=None) -> RequestTicket:
        """Admit one request (event-loop thread only); returns its ticket.

        Raises ``ValueError`` if ``request.req_id`` is already
        outstanding: silently overwriting the old ticket would leak a
        never-resolving future and break the conservation ledger.
        """
        now = self.clock.now()
        if request is None:
            request = Request(arrival_time=now, payload=payload)
        elif request.req_id in self._tickets:
            raise ValueError(
                f"request {request.req_id} is already outstanding; "
                "submit a fresh Request per attempt"
            )
        ep = self.frontend.resolve(endpoint or request.endpoint)
        loop = asyncio.get_running_loop()
        future: asyncio.Future = loop.create_future()
        self.submitted += 1
        self._submitted_by_ep[ep.name] = \
            self._submitted_by_ep.get(ep.name, 0) + 1
        self.events_processed += 1
        if self._first_submit is None:
            self._first_submit = now

        cfg = self.config
        if cfg.max_queue > 0 or self._breakers:
            # dead requests the timer hasn't swept yet must not count
            # toward the queue cap (they would spuriously reject this one)
            ep.policy.expire(now)
        outstanding = self.submitted - self.completed - self.rejected \
            - self.shed - self.timed_out - self.failed - 1  # excl. this one
        reject = (
            not self._accepting
            or (cfg.max_outstanding > 0 and outstanding >= cfg.max_outstanding)
            or (cfg.max_queue > 0 and ep.policy.queue_len >= cfg.max_queue)
        )
        if reject:
            self.rejected += 1
            if self.tracer is not None:
                self.tracer.emit(now, "rejected", ep.name,
                                 req_id=request.req_id)
            ticket = RequestTicket(request, future, ep.name, rejected=True)
            future.set_result(ticket)
            return ticket

        # Brownout admission: while this endpoint's breaker is not closed
        # the queue cap tightens to brownout_queue, and while ANY breaker
        # is not closed the outstanding cap tightens to
        # brownout_outstanding. A submission admitted under the normal
        # caps but dropped by the tightened ones is `shed`, not
        # `rejected` — a deliberate brownout decision, not backpressure.
        breaker = self._breakers.get(ep.name)
        browned_ep = breaker is not None and breaker.state(now) != CLOSED
        drop = (
            browned_ep and cfg.brownout_queue > 0
            and ep.policy.queue_len >= cfg.brownout_queue
        )
        if (not drop and cfg.brownout_outstanding > 0
                and outstanding >= cfg.brownout_outstanding):
            drop = any(b.state(now) != CLOSED for b in self._breakers.values())
        if drop:
            self.shed += 1
            if self.tracer is not None:
                self.tracer.emit(now, "shed", ep.name,
                                 req_id=request.req_id, detail="brownout")
            ticket = RequestTicket(request, future, ep.name)
            ticket.shed = True
            ticket.error = BrownoutShed(
                f"request {request.req_id} shed at t={now:.6f}: endpoint "
                f"{ep.name!r} is browned out (breaker "
                f"{breaker.state(now) if breaker else 'n/a'})"
            )
            future.set_result(ticket)
            return ticket

        ticket = RequestTicket(request, future, ep.name)
        self._tickets[request.req_id] = ticket
        self.frontend.on_request(request, now, endpoint=ep.name)
        self._wake.set()  # deadline may have changed
        return ticket

    # ------------------------------------------------------------- dispatch
    def _on_dispatch(self, name: str, batch: Batch) -> None:
        """Policy handed us a batch (synchronously, on the loop thread)."""
        now = self.clock.now()
        self.dispatch_log.append(
            (now, name, batch.size, batch.effective_size, batch.cause)
        )
        self.inflight_batches += 1
        self.events_processed += 1
        if self.recorder is not None:
            self.recorder.note(now, "dispatch", endpoint=name,
                               batch=batch.trace_id, size=batch.size,
                               cause=batch.cause)
        task = asyncio.get_running_loop().create_task(
            self._run_batch(name, batch, now)
        )
        self._batch_tasks.add(task)
        task.add_done_callback(self._batch_tasks.discard)

    def _on_expired(self, name: str, requests: List[Request],
                    now: float) -> None:
        """Expiry sweep evicted ``requests``: resolve their tickets.

        The requests were never dispatched (and never will be); their
        tickets resolve with ``timed_out=True`` and a
        :class:`DeadlineExceeded` error attached.
        """
        for r in requests:
            ticket = self._tickets.pop(r.req_id, None)
            if ticket is not None and not ticket.future.done():
                ticket.timed_out = True
                ticket.error = DeadlineExceeded(
                    f"request {r.req_id} expired at t={now:.6f} "
                    f"(deadline {r.deadline:.6f}) while queued on "
                    f"{name!r}"
                )
                ticket.future.set_result(ticket)
        self.timed_out += len(requests)
        self.events_processed += 1
        self._wake.set()

    def _hedge_threshold(self, name: str, batch: Batch) -> Optional[float]:
        """Straggler threshold for ``batch``: the configured quantile of
        its bucket's measured upstream latency (None = hedging off or the
        bucket is still cold)."""
        q = self.config.hedge_quantile
        if q <= 0:
            return None
        monitor = getattr(self.frontend.endpoint(name).policy, "monitor", None)
        if monitor is None:
            return None
        return monitor.bucket_quantile(
            batch.effective_size, q, self.clock.now(),
            self.config.hedge_min_samples,
        )

    async def _execute_hedged(self, name: str, batch: Batch,
                              deadline: Optional[float]) -> int:
        """Run ``batch`` on its target with optional straggler hedging.

        Returns the number of attempts issued (1, or 2 when hedged).
        First completion wins; the other attempt is cancelled. If the
        first finisher raised while its sibling is still running, the
        sibling is awaited as the fallback before giving up.
        """
        target = self._targets[name]
        loop = asyncio.get_running_loop()
        if self._target_takes_deadline[name]:
            start = lambda: loop.create_task(target(batch, deadline=deadline))  # noqa: E731
        else:
            start = lambda: loop.create_task(target(batch))  # noqa: E731
        children: Set[asyncio.Task] = set()
        try:
            primary = start()
            children.add(primary)
            threshold = self._hedge_threshold(name, batch)
            if threshold is None:
                await primary
                return 1

            timer = loop.create_task(self.clock.sleep(threshold))
            children.add(timer)
            await asyncio.wait({primary, timer},
                               return_when=asyncio.FIRST_COMPLETED)
            if primary.done():
                await self._cancel(timer)
                children.discard(timer)
                primary.result()  # re-raise a target error
                return 1

            # Straggler: re-issue to the target; first completion wins.
            await self._cancel(timer)
            children.discard(timer)
            self.hedged_batches += 1
            self._hedged_by_ep[name] = self._hedged_by_ep.get(name, 0) + 1
            if self.tracer is not None:
                self.tracer.emit(self.clock.now(), "hedge", name,
                                 batch=batch.trace_id, size=batch.size,
                                 value=threshold)
            hedge = start()
            children.add(hedge)
            done, pending = await asyncio.wait(
                {primary, hedge}, return_when=asyncio.FIRST_COMPLETED)
            ok = [t for t in done if t.exception() is None]
            if ok:
                winner = primary if primary in ok else hedge
            elif pending:
                # sole finisher failed — fall back to the live sibling
                winner = next(iter(pending))
                await asyncio.wait({winner})
                if winner.exception() is not None:
                    next(iter(done)).result()  # raise the FIRST error
            else:
                primary.result()  # both done, both failed
                raise primary.exception()  # pragma: no cover (unreachable)
            for t in (primary, hedge):
                if t is not winner:
                    await self._cancel(t)
                    children.discard(t)
            if winner is hedge:
                self.hedge_wins += 1
                self._hedge_wins_by_ep[name] = \
                    self._hedge_wins_by_ep.get(name, 0) + 1
            winner.result()
            return 2
        except asyncio.CancelledError:
            # drain(timeout=) cancelled us: tear down every live attempt
            for t in children:
                t.cancel()
            await asyncio.gather(*children, return_exceptions=True)
            raise

    @staticmethod
    async def _cancel(task: asyncio.Task) -> None:
        task.cancel()
        with contextlib.suppress(asyncio.CancelledError, Exception):
            await task

    def _brownout_shed(self, name: str, now: float) -> None:
        """Breaker opened on ``name``: shed its queue down to the brownout
        cap, lowest-slack first, and resolve the victims' tickets."""
        keep = self.config.brownout_queue
        if keep <= 0:
            return
        victims = self.frontend.endpoint(name).policy.shed(now, keep)
        for r in victims:
            ticket = self._tickets.pop(r.req_id, None)
            if ticket is not None and not ticket.future.done():
                ticket.shed = True
                ticket.error = BrownoutShed(
                    f"request {r.req_id} shed at t={now:.6f}: endpoint "
                    f"{name!r} circuit opened"
                )
                ticket.future.set_result(ticket)
        self.shed += len(victims)
        if victims:
            self._wake.set()

    def _record_failure(self, name: str, batch: Batch, now: float) -> None:
        """One dispatch attempt failed: feed the monitor's failure stats
        and the breaker; an opening breaker triggers brownout shedding."""
        monitor = getattr(self.frontend.endpoint(name).policy, "monitor", None)
        if monitor is not None:
            monitor.record_failure(batch.effective_size, now)
        breaker = self._breakers.get(name)
        if breaker is not None and breaker.record_failure(now):
            if self.tracer is not None:
                self.tracer.emit(now, "breaker_open", name,
                                 batch=batch.trace_id)
            if self.recorder is not None:
                self.recorder.note(now, "breaker_open", endpoint=name)
                self.recorder.dump("breaker_open", now=now,
                                   extra={"endpoint": name})
            self._brownout_shed(name, now)

    def _backoff(self, failures: int) -> float:
        """Capped exponential backoff before retry #``failures``, with
        seeded uniform jitter (one stream draw per scheduled retry)."""
        cfg = self.config
        backoff = min(cfg.retry_backoff_cap,
                      cfg.retry_backoff * (2.0 ** (failures - 1)))
        if cfg.retry_jitter > 0:
            backoff *= 1.0 + cfg.retry_jitter * float(self._retry_rng.random())
        return backoff

    async def _breaker_gate(self, name: str,
                            deadline: Optional[float],
                            trace_id: int = -1) -> bool:
        """Park until ``name``'s breaker admits a dispatch attempt.

        While open, sleeps to the probe instant; while half-open with the
        single probe slot taken, polls at ``probe_interval`` until the
        probe's outcome settles the state. Returns False when the next
        admissible attempt instant already lies past ``deadline`` — the
        batch cannot possibly complete in time, so the caller resolves it
        ``timed_out`` instead of waiting. The waits are plain clock sleeps
        inside the batch task, so ``drain(timeout=)`` cancels them like
        any other parked sleeper. The loop is bounded by the breaker's
        own dynamics (each pass sleeps a full open interval or a probe
        beat) and by the deadline cutoff.
        """
        breaker = self._breakers.get(name)
        if breaker is None:
            return True
        while True:
            now = self.clock.now()
            until = breaker.blocked_until(now)
            if until is not None:
                # open: sleep out the remaining interval
                if deadline is not None and until >= deadline:
                    return False
                if self.tracer is not None:
                    self.tracer.emit(now, "breaker_wait", name,
                                     batch=trace_id, value=until - now,
                                     detail="open")
                await self.clock.sleep(until - now)
                continue
            if breaker.try_probe(now):
                return True
            # half-open, probe slot taken: wait a beat for its verdict
            beat = breaker.config.probe_interval
            if deadline is not None and now + beat >= deadline:
                return False
            if self.tracer is not None:
                self.tracer.emit(now, "breaker_wait", name,
                                 batch=trace_id, value=beat,
                                 detail="half_open")
            await self.clock.sleep(beat)

    async def _run_batch(self, name: str, batch: Batch, t0: float) -> None:
        cfg = self.config
        breaker = self._breakers.get(name)
        deadline = batch.tightest_deadline
        error: Optional[BaseException] = None
        timed_out = False
        attempts = 0
        failures = 0
        retries_issued = 0
        try:
            while True:  # bounded by max_retries and the batch deadline
                if not await self._breaker_gate(name, deadline,
                                                batch.trace_id):
                    # every admissible probe instant is past the deadline:
                    # the SLA is already lost, stop burning the upstream
                    timed_out = True
                    break
                try:
                    attempts += await self._execute_hedged(
                        name, batch, deadline)
                    error = None
                    if breaker is not None:
                        breaker.record_success(self.clock.now())
                    break
                except asyncio.CancelledError:
                    raise
                except Exception as exc:  # noqa: BLE001 — retried/resolved
                    attempts += 1
                    failures += 1
                    error = exc
                    now = self.clock.now()
                    if self.tracer is not None:
                        self.tracer.emit(now, "fault", name,
                                         batch=batch.trace_id,
                                         size=batch.size,
                                         detail=type(exc).__name__)
                    self._record_failure(name, batch, now)
                    if failures > cfg.max_retries:
                        self.retry_exhausted += 1
                        break
                    backoff = self._backoff(failures)
                    if deadline is not None and now + backoff >= deadline:
                        # leftover budget cannot fit another attempt:
                        # deadline semantics win over retry semantics
                        timed_out = True
                        break
                    retries_issued += 1
                    self.retry_log.append(
                        (now, name, batch.size, failures, backoff,
                         type(exc).__name__)
                    )
                    if self.tracer is not None:
                        self.tracer.emit(now, "retry", name,
                                         batch=batch.trace_id,
                                         size=batch.size, value=backoff,
                                         detail=type(exc).__name__)
                    if self.recorder is not None:
                        self.recorder.note(now, "retry", endpoint=name,
                                           batch=batch.trace_id,
                                           failures=failures,
                                           backoff=backoff,
                                           error=type(exc).__name__)
                    await self.clock.sleep(backoff)
        except asyncio.CancelledError:
            # drain(timeout=) gave up on this batch — possibly mid-attempt,
            # parked on a retry backoff, or waiting out an open breaker:
            # account its requests as failed rather than hanging the
            # process (the task itself completes normally so drain's
            # gather() can collect it).
            error = DrainTimeout(
                f"batch of {batch.size} on {name!r} cancelled at drain "
                "timeout"
            )
            timed_out = False
            self.drain_cancelled += batch.size
        now = self.clock.now()
        self.inflight_batches -= 1
        self.events_processed += 1
        if failures:
            self.faulted_batches += 1
        if retries_issued:
            self.retried_batches += 1
        # The success path releases the router's in-flight slot through
        # frontend.on_response -> router.on_batch_done; the terminal
        # failure paths below never reach it, so release here or the
        # tier's inflight count leaks and the cap wedges shut.
        _router = self.frontend.endpoint(name).router
        if (_router is not None and batch.tier is not None
                and (timed_out or error is not None)):
            _router.release(batch.tier)
        if timed_out:
            # the batch was never completed by the upstream; its requests
            # exhaust their deadline exactly like a queue expiry would
            for r in batch.requests:
                ticket = self._tickets.pop(r.req_id, None)
                if ticket is not None and not ticket.future.done():
                    ticket.timed_out = True
                    ticket.error = DeadlineExceeded(
                        f"request {r.req_id} ran out of deadline budget at "
                        f"t={now:.6f} after {failures} failed dispatch "
                        f"attempt(s) on {name!r}"
                    )
                    ticket.future.set_result(ticket)
            self.timed_out += batch.size
            if self.tracer is not None:
                self.tracer.emit(now, "timed_out", name,
                                 batch=batch.trace_id, size=batch.size)
            self._wake.set()
            return
        if error is None:
            batch.attempts = max(1, attempts)
            if failures:
                self.recovered_batches += 1
            latency = now - t0
            self.frontend.on_response(batch, latency, now)
            self.bucket_samples[name].setdefault(
                batch.effective_size, []
            ).append(latency)
            log = self.completions[name]
            for r in batch.requests:
                log.append(now, now - r.arrival_time, r.arrival_time)
                ticket = self._tickets.pop(r.req_id, None)
                if ticket is not None and not ticket.future.done():
                    ticket.future.set_result(ticket)
                else:
                    # a completion with no live ticket means the request
                    # was resolved twice — the invariant chaos must not
                    # be able to break
                    self.duplicate_completions += 1
            self.completed += batch.size
            self._last_completion = now
            if self.tracer is not None:
                self.tracer.emit(now, "completed", name,
                                 batch=batch.trace_id, size=batch.size,
                                 value=latency)
        else:
            if not isinstance(error, DrainTimeout):
                # exhausted retry budget: classify as a target failure so
                # the drained assert can tell it from lost accounting
                wrapped = TargetError(
                    f"batch of {batch.size} on {name!r} failed after "
                    f"{max(1, attempts)} attempt(s): {error!r}",
                    attempts=max(1, attempts),
                )
                wrapped.__cause__ = error
                error = wrapped
                self.target_failures += batch.size
            for r in batch.requests:
                ticket = self._tickets.pop(r.req_id, None)
                if ticket is not None and not ticket.future.done():
                    ticket.error = error
                    ticket.future.set_exception(error)
            self.failed += batch.size
            if self.tracer is not None:
                self.tracer.emit(now, "failed", name,
                                 batch=batch.trace_id, size=batch.size,
                                 detail=type(error).__name__)
        self._wake.set()

    # ---------------------------------------------------------------- timer
    async def _timer_loop(self) -> None:
        cfg = self.config
        while self._running:
            now = self.clock.now()
            self.events_processed += 1
            self.frontend.on_timer(now)
            nxt = self.frontend.next_event_time(now)
            if nxt is None:
                timeout: Optional[float] = cfg.timer_idle
            else:
                timeout = max(nxt - now, cfg.min_timer_tick)
            await self.clock.wait(self._wake, timeout)
            self._wake.clear()

    # ---------------------------------------------------------- conservation
    def conservation(self) -> dict:
        queue_len = sum(
            ep["queue_len"]
            for ep in self.frontend.stats(self.clock.now())["endpoints"].values()
        )
        outstanding = len(self._tickets)
        lost = (self.submitted - self.completed - self.rejected - self.shed
                - self.timed_out - self.failed - outstanding)
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "shed": self.shed,
            "timed_out": self.timed_out,
            "failed": self.failed,
            "drain_cancelled": self.drain_cancelled,
            "target_failures": self.target_failures,
            "outstanding": outstanding,
            "queued": queue_len,
            "inflight_batches": self.inflight_batches,
            "hedged_batches": self.hedged_batches,
            "retried_batches": self.retried_batches,
            "retry_exhausted": self.retry_exhausted,
            "faulted_batches": self.faulted_batches,
            "recovered_batches": self.recovered_batches,
            "duplicate_completions": self.duplicate_completions,
            "lost": lost,
        }

    def assert_conserved(self, require_drained: bool = False) -> dict:
        """Raise ``AssertionError`` on any broken runtime invariant.

        Mirrors ``ServerlessPlatform.assert_conserved``: nothing lost and
        nothing completed twice at any instant; with ``require_drained``,
        nothing outstanding either (``submitted == completed + rejected +
        shed + timed_out + failed`` — every terminal state explicitly
        accounted, zero lost) and every failure is *classified*: either
        ``drain(timeout=)`` cancelled it or an exhausted retry budget
        resolved it as a :class:`TargetError`. An unclassified failure at
        drain still trips the assert — lost accounting cannot slip
        through shutdown.
        """
        c = self.conservation()

        def trip(reason: str) -> AssertionError:
            # the flight recorder dumps its ring BEFORE the raise so the
            # postmortem survives even if the caller swallows the error
            if self.recorder is not None:
                self.recorder.dump(f"conservation-{reason}",
                                   now=self.clock.now(), extra=c)
            return AssertionError(f"{reason}: {c}")

        if c["lost"] != 0:
            raise trip("runtime lost requests")
        if c["duplicate_completions"] != 0:
            raise trip("duplicate completions")
        if require_drained:
            if c["outstanding"] or c["queued"] or c["inflight_batches"]:
                raise trip("undrained work at shutdown")
            if c["failed"] != c["drain_cancelled"] + c["target_failures"]:
                raise trip("unclassified failed dispatches at shutdown")
            if c["submitted"] != (c["completed"] + c["rejected"] + c["shed"]
                                  + c["timed_out"] + c["failed"]):
                raise trip("conservation imbalance")
        return c

    # --------------------------------------------------------------- metrics
    def summary(self) -> dict:
        """Fleet summary with the same headline keys as ``SimResult``."""
        now = self.clock.now()
        fstats = self.frontend.stats(now)
        per: Dict[str, dict] = {}
        all_e2e: List[np.ndarray] = []
        total_viol = 0.0
        for name in self.frontend.names:
            ep = self.frontend.endpoint(name)
            e2e = self.completions[name].e2e.view()
            all_e2e.append(e2e)
            viol = (float(np.mean(e2e > ep.sla.slo_target))
                    if len(e2e) else 0.0)
            total_viol += viol * len(e2e)
            st = fstats["endpoints"][name]
            per[name] = {
                "completed": float(len(e2e)),
                "slo_target": ep.sla.slo_target,
                "violation_rate": viol,
                "violation_pct": 100.0 * viol,
                "p50": float(np.percentile(e2e, 50)) if len(e2e) else math.nan,
                "p95": float(np.percentile(e2e, 95)) if len(e2e) else math.nan,
                "mean_latency": float(e2e.mean()) if len(e2e) else math.nan,
                "avg_batch_size": st.get("avg_batch_size", 0.0),
                "dispatched_batches": float(st.get("dispatched_batches", 0)),
                "max_bs": float(st.get("max_bs", 1)),
                "upstream_batches": float(st.get("upstream_batches", 0)),
                "retried_batches": float(st.get("retried_batches", 0)),
                "retry_rate": float(st.get("retry_rate", 0.0)),
                "failure_rate": float(st.get("failure_rate", 0.0)),
                "timed_out": float(st.get("expired", 0)),
                "shed": float(st.get("shed", 0)),
                "padding_waste": float(st.get("padding_waste", 0.0)),
                "submitted_requests": float(
                    self._submitted_by_ep.get(name, 0)),
                "queue_depth_hwm": float(st.get("queue_depth_hwm", 0)),
                "burn_rate_fast": float(st.get("burn_rate_fast", 0.0)),
                "burn_rate_slow": float(st.get("burn_rate_slow", 0.0)),
                "hedged_batches": float(self._hedged_by_ep.get(name, 0)),
                "hedge_wins": float(self._hedge_wins_by_ep.get(name, 0)),
            }
            breaker = self._breakers.get(name)
            if breaker is not None:
                per[name]["breaker"] = breaker.stats(now)
            # Tiered endpoints only: extra keys would break the strict
            # dict-equality checks untiered parity tests rely on.
            if ep.router is not None:
                per[name]["router"] = ep.router.stats()
            target = self._targets.get(name)
            tier_stats = getattr(target, "stats", None)
            if tier_stats is not None and hasattr(target, "cost_integral"):
                per[name]["tiers"] = tier_stats()
                per[name]["cost_integral"] = float(target.cost_integral)
        e2e = np.concatenate(all_e2e) if all_e2e else np.empty(0)
        n = len(e2e)
        cons = self.conservation()
        # Throughput over the active window (first submit → last
        # completion), not the raw clock: a clock predating the server or
        # a summary taken after an idle gap must not deflate it.
        if (self._first_submit is not None
                and self._last_completion is not None
                and self._last_completion > self._first_submit):
            throughput = n / (self._last_completion - self._first_submit)
        else:
            throughput = 0.0
        summary = {
            "completed": float(n),
            "violation_rate": total_viol / n if n else 0.0,
            "violation_pct": 100.0 * total_viol / n if n else 0.0,
            "p50": float(np.percentile(e2e, 50)) if n else math.nan,
            "p95": float(np.percentile(e2e, 95)) if n else math.nan,
            "p99": float(np.percentile(e2e, 99)) if n else math.nan,
            "mean_latency": float(e2e.mean()) if n else math.nan,
            "avg_batch_size": fstats["aggregate"]["avg_batch_size"],
            "dispatched_batches": float(
                fstats["aggregate"]["dispatched_batches"]
            ),
            "submitted": float(cons["submitted"]),
            "rejected": float(cons["rejected"]),
            "shed": float(cons["shed"]),
            "timed_out": float(cons["timed_out"]),
            "failed": float(cons["failed"]),
            "hedged_batches": float(self.hedged_batches),
            "hedge_wins": float(self.hedge_wins),
            "retried_batches": float(self.retried_batches),
            "retry_exhausted": float(self.retry_exhausted),
            "faulted_batches": float(self.faulted_batches),
            "recovered_batches": float(self.recovered_batches),
            "duplicate_completions": float(self.duplicate_completions),
            "padding_waste": fstats["aggregate"]["padding_waste"],
            "lost": float(cons["lost"]),
            "throughput": throughput,
            "events_processed": float(self.events_processed),
            "queue_depth_hwm": float(
                fstats["aggregate"]["queue_depth_hwm"]),
            "burn_rate_fast": fstats["aggregate"]["burn_rate_fast"],
            "burn_rate_slow": fstats["aggregate"]["burn_rate_slow"],
            "endpoints": per,
        }
        return summary
