"""Batching policies: MLProxy plus the baselines it is compared against.

Every policy implements the formal :class:`~repro.core.batch_queue.Policy`
protocol (`on_request`, `on_response`, `on_timer`, `next_event_time`,
`flush`, `stats`, `snapshot`/`restore`, `max_bs`), so the simulator, the
serving engine, and the multi-endpoint
:class:`~repro.core.frontend.ProxyFrontend` can swap them freely.

All queue/dispatch mechanics (pending FIFO, first-arrival anchor, deadline,
bucketing, counters, snapshot of that state) live in the one shared
:class:`~repro.core.batch_queue.BatchQueue`; each policy here contributes
only its decision logic — a target batch size and a queue timeout:

* ``PassthroughPolicy`` — the paper's "MLProxy off" baseline: every request
  is forwarded upstream immediately as a batch of one (what a stock API
  gateway does).
* ``StaticBatchPolicy`` — fixed max batch size + fixed queue timeout
  (what naive middleware does; no SLA awareness).
* ``ClipperAIMDPolicy`` — Clipper-style adaptive batching (Crankshaw et al.,
  NSDI'17): AIMD directly on the batch size driven only by whether the
  latency SLO was met, with a fixed small queue timeout.
* ``OracleStaticPolicy`` — BATCH-style profiled baseline (Ali et al.,
  SC'20): given an offline-profiled latency curve, pick the largest batch
  size whose predicted latency fits under the SLO and derive the timeout
  from the leftover budget. Requires prior profiling — exactly the
  requirement MLProxy removes.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence

from repro.core.batch_queue import BatchQueue, ExpireFn
from repro.core.config import (MonitorConfig, ProxyConfig, SLAConfig,
                               bucket_of, validate_buckets)
from repro.core.monitor import SmartMonitor
from repro.core.proxy import MLProxy
from repro.core.request import Batch, Request

#: Default batch-size ceiling of the cap-carrying baselines (clipper /
#: oracle). Module-level so config-time reconciliation against engine
#: buckets (``runtime.server.clamp_policy_kwargs``) can tell "policy
#: default" apart from "caller choice" without signature introspection.
DEFAULT_MAX_CAP = 256


class BatchingPolicy:
    """Decision logic + shared :class:`BatchQueue` for non-MLProxy policies.

    ``pack_buckets`` (the engine's ``batch_buckets``) turns on bucket-aware
    packing: the full-trigger threshold rounds the policy's target up to
    the next bucket edge and dispatches exactly at it, so "full" batches
    execute with zero padding. Latency within a bucket is the padded
    bucket's latency (the monitor keys by it), so the extra requests ride
    in slots that would otherwise be padding. Timeout/flush dispatches
    still flush the whole queue — SLA pressure beats packing efficiency.
    Setting ``pack_buckets`` without ``bucketing`` implies
    ``bucketing = pack_buckets``.
    """

    def __init__(self, sla: SLAConfig, dispatch_fn: Callable[[Batch], None],
                 monitor_config: Optional[MonitorConfig] = None,
                 bucketing=None,
                 expire_fn: Optional[ExpireFn] = None,
                 pack_buckets: Optional[Sequence[int]] = None,
                 tracer=None) -> None:
        self.sla = sla
        if pack_buckets is not None:
            pack_buckets = validate_buckets(pack_buckets, "pack_buckets")
            if bucketing is None:
                bucketing = pack_buckets
        self.pack_buckets = pack_buckets
        self.monitor = SmartMonitor(monitor_config or MonitorConfig(), sla)
        self.queue = BatchQueue(dispatch_fn, self.monitor, bucketing=bucketing,
                                expire_fn=expire_fn, tracer=tracer)

    # -------- subclass interface ------------------------------------------
    def target_batch_size(self, now: float) -> int:
        raise NotImplementedError

    def queue_timeout(self, now: float) -> Optional[float]:
        """Relative timeout measured from first-request arrival, or None."""
        raise NotImplementedError

    # -------- shared machinery --------------------------------------------
    @property
    def queue_len(self) -> int:
        return self.queue.queue_len

    @property
    def next_deadline(self) -> Optional[float]:
        return self.queue.next_deadline

    @property
    def dispatched_batches(self) -> int:
        return self.queue.dispatched_batches

    @property
    def dispatched_requests(self) -> int:
        return self.queue.dispatched_requests

    def packed_target(self, now: float) -> int:
        """Full-trigger threshold: the raw target, rounded up to the next
        bucket edge when packing is on (clamped to the largest bucket)."""
        target = max(1, self.target_batch_size(now))
        if self.pack_buckets is not None:
            target = bucket_of(target, self.pack_buckets)
        return target

    def on_request(self, request: Request, now: float) -> None:
        self.queue.expire(now)  # evict dead requests before sizing the batch
        self.queue.append(request, now)
        if self.pack_buckets is None:
            if self.queue.queue_len >= max(1, self.target_batch_size(now)):
                self.queue._dispatch(now, "full")
                return
        else:
            # packed full-trigger: dispatch exactly at the bucket edge;
            # any backlog beyond it (e.g. after restore) stays queued and
            # falls through to re-arm the timeout below
            target = self.packed_target(now)
            while self.queue.queue_len >= target:
                if self.queue._dispatch(now, "full", limit=target) is None:
                    break
                target = self.packed_target(now)
            if not self.queue.queue_len:
                return
        to = self.queue_timeout(now)
        if to is None:
            self.queue.next_deadline = None
        else:
            # anchor on the oldest queued request (frt handles the
            # first_arrival == 0.0 case an `or now` fallback would drop)
            deadline = (now - self.queue.frt(now)) + to
            if deadline <= now:
                self.queue._dispatch(now, "timeout")
            else:
                self.queue.next_deadline = deadline

    def on_timer(self, now: float) -> None:
        # Expiry first: the merged timer also wakes for request expiries,
        # which must never be batched into the timeout dispatch below.
        self.queue.expire(now)
        if self.queue.next_deadline is not None and now + 1e-12 >= self.queue.next_deadline:
            if self.queue.queue_len:
                self.queue._dispatch(now, "timeout")
            else:
                self.queue.next_deadline = None

    def on_response(self, batch: Batch, upstream_latency: float, now: float) -> None:
        self.monitor.record_upstream(batch.effective_size, upstream_latency, now,
                                     attempts=batch.attempts)
        batch.complete(now)
        for r in batch.requests:
            self.monitor.record_e2e(r.e2e_latency, now)

    def expire(self, now: float) -> List[Request]:
        """Evict deadline-expired queued requests (O(1) when none)."""
        return self.queue.expire(now)

    def shed(self, now: float, keep: int) -> List[Request]:
        """Evict queued requests beyond ``keep``, lowest slack first."""
        return self.queue.shed(now, keep)

    def next_event_time(self, now: float) -> Optional[float]:
        # dispatch deadline merged with the earliest request expiry
        return self.queue.next_event_time()

    def flush(self, now: float) -> None:
        if self.queue.queue_len:
            self.queue._dispatch(now, "flush")

    @property
    def max_bs(self) -> int:
        return self.target_batch_size(0.0)

    def stats(self, now: float) -> dict:
        # One canonical key set for every policy — see BatchQueue.stats.
        # Baselines have no AIMD fractional state, so raw == effective.
        target = self.target_batch_size(now)
        return self.queue.stats(self.monitor, now,
                                max_bs=target, max_bs_raw=float(target))

    def snapshot(self) -> dict:
        return {
            "monitor": self.monitor.snapshot(),
            "queue": self.queue.snapshot(),
        }

    def restore(self, state: dict) -> None:
        self.monitor.restore(state["monitor"])
        if "counts" in state:  # pre-BatchQueue snapshot layout
            self.queue.restore({
                "queue": state["queue"],
                "first_arrival": state["first_arrival"],
                "next_deadline": state["next_deadline"],
                "dispatched_batches": state["counts"][0],
                "dispatched_requests": state["counts"][1],
            })
        else:
            self.queue.restore(state["queue"])


class PassthroughPolicy(BatchingPolicy):
    """No batching: forward every request immediately (stock API gateway)."""

    def target_batch_size(self, now: float) -> int:
        return 1

    def queue_timeout(self, now: float) -> Optional[float]:
        return 0.0


class StaticBatchPolicy(BatchingPolicy):
    """Fixed batch size and fixed queue timeout."""

    def __init__(self, sla, dispatch_fn, batch_size: int, timeout: float, **kw) -> None:
        super().__init__(sla, dispatch_fn, **kw)
        self._bs = batch_size
        self._to = timeout

    def target_batch_size(self, now: float) -> int:
        return self._bs

    def queue_timeout(self, now: float) -> Optional[float]:
        return self._to


class ClipperAIMDPolicy(BatchingPolicy):
    """Clipper-style AIMD: grow batch size additively while the windowed
    latency percentile meets the SLO; back off multiplicatively otherwise.
    The queue timeout is a fixed fraction of the SLO budget."""

    def __init__(self, sla, dispatch_fn, inc: int = 1, dec_mult: float = 0.9,
                 update_interval: float = 10.0, timeout_frac: float = 0.25,
                 max_cap: int = DEFAULT_MAX_CAP, **kw) -> None:
        super().__init__(sla, dispatch_fn, **kw)
        self.inc = inc
        self.dec_mult = dec_mult
        self.update_interval = update_interval
        self.timeout_frac = timeout_frac
        self.max_cap = max_cap
        self._bs = 1.0
        self._last_update: Optional[float] = None

    def target_batch_size(self, now: float) -> int:
        return max(1, min(self.max_cap, int(self._bs)))

    def queue_timeout(self, now: float) -> Optional[float]:
        return self.sla.slo_target * self.timeout_frac

    def on_timer(self, now: float) -> None:
        super().on_timer(now)
        if self._last_update is None:
            self._last_update = now
            return
        # epsilon tolerance: without it a timer that fires a float-ulp
        # before the interval boundary never advances _last_update while
        # next_event_time keeps returning the same instant (spin)
        if now - self._last_update >= self.update_interval - 1e-9:
            p = self.monitor.e2e_percentile(now)
            if p is not None and p > self.sla.slo_target:
                self._bs = max(1.0, self._bs * self.dec_mult)
            else:
                self._bs = min(float(self.max_cap), self._bs + self.inc)
            self._last_update = now

    def next_event_time(self, now: float) -> Optional[float]:
        nxt = (self._last_update + self.update_interval
               if self._last_update is not None
               else now + self.update_interval)
        queue_next = self.queue.next_event_time()
        if queue_next is not None:
            return min(queue_next, nxt)
        return nxt

    def snapshot(self) -> dict:
        state = super().snapshot()
        state["aimd"] = (self._bs, self._last_update)
        return state

    def restore(self, state: dict) -> None:
        super().restore(state)
        if "aimd" in state:
            self._bs, self._last_update = state["aimd"]


class OracleStaticPolicy(BatchingPolicy):
    """BATCH-style profiled baseline: requires an offline latency model
    ``latency_model(bs) -> p95 seconds`` (the profiling step MLProxy
    removes) and solves for the largest SLO-feasible batch size."""

    def __init__(self, sla, dispatch_fn, latency_model: Callable[[int], float],
                 headroom: float = 0.9, max_cap: int = DEFAULT_MAX_CAP,
                 **kw) -> None:
        super().__init__(sla, dispatch_fn, **kw)
        self.latency_model = latency_model
        budget = sla.slo_target * headroom
        bs = 1
        for cand in range(1, max_cap + 1):
            if latency_model(cand) <= budget:
                bs = cand
            else:
                break
        self._bs = bs
        self._to = max(0.0, budget - latency_model(bs))

    def target_batch_size(self, now: float) -> int:
        return self._bs

    def queue_timeout(self, now: float) -> Optional[float]:
        return self._to


def make_policy(name: str, sla: SLAConfig, dispatch_fn,
                expire_fn: Optional[ExpireFn] = None, tracer=None, **kwargs):
    """Factory used by the simulator, the frontend, and benchmarks.

    ``expire_fn(requests, now)`` (optional) is invoked by the policy's
    queue whenever the expiry sweep evicts already-dead requests.
    ``tracer`` (optional :class:`repro.obs.trace.Tracer`) turns on
    lifecycle span emission in the policy's queue.
    """
    if name == "mlproxy":
        proxy_cfg = kwargs.pop("proxy_config", None) or ProxyConfig(sla=sla, **kwargs)
        return MLProxy(proxy_cfg, dispatch_fn, expire_fn=expire_fn,
                       tracer=tracer)
    if name == "passthrough":
        return PassthroughPolicy(sla, dispatch_fn, expire_fn=expire_fn,
                                 tracer=tracer, **kwargs)
    if name == "static":
        return StaticBatchPolicy(sla, dispatch_fn, expire_fn=expire_fn,
                                 tracer=tracer, **kwargs)
    if name == "clipper":
        return ClipperAIMDPolicy(sla, dispatch_fn, expire_fn=expire_fn,
                                 tracer=tracer, **kwargs)
    if name == "oracle":
        return OracleStaticPolicy(sla, dispatch_fn, expire_fn=expire_fn,
                                  tracer=tracer, **kwargs)
    raise ValueError(f"unknown policy {name!r}")
