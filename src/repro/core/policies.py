"""Batching policies: MLProxy plus the baselines it is compared against.

Every policy exposes the same event-driven surface as :class:`MLProxy`
(`on_request`, `on_response`, `on_timer`, `next_event_time`, `flush`,
`stats`, `snapshot`/`restore`), so the simulator and the serving engine can
swap them freely:

* ``PassthroughPolicy`` — the paper's "MLProxy off" baseline: every request
  is forwarded upstream immediately as a batch of one (what a stock API
  gateway does).
* ``StaticBatchPolicy`` — fixed max batch size + fixed queue timeout
  (what naive middleware does; no SLA awareness).
* ``ClipperAIMDPolicy`` — Clipper-style adaptive batching (Crankshaw et al.,
  NSDI'17): AIMD directly on the batch size driven only by whether the
  latency SLO was met, with a fixed small queue timeout.
* ``OracleStaticPolicy`` — BATCH-style profiled baseline (Ali et al.,
  SC'20): given an offline-profiled latency curve, pick the largest batch
  size whose predicted latency fits under the SLO and derive the timeout
  from the leftover budget. Requires prior profiling — exactly the
  requirement MLProxy removes.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.config import MonitorConfig, ProxyConfig, SLAConfig, bucket_of
from repro.core.monitor import SmartMonitor
from repro.core.proxy import MLProxy
from repro.core.request import Batch, Request


class BatchingPolicy:
    """Common bookkeeping for non-MLProxy policies."""

    def __init__(self, sla: SLAConfig, dispatch_fn: Callable[[Batch], None],
                 monitor_config: Optional[MonitorConfig] = None,
                 bucketing: Optional[str] = None) -> None:
        self.sla = sla
        self.dispatch_fn = dispatch_fn
        self.monitor = SmartMonitor(monitor_config or MonitorConfig(), sla)
        self.bucketing = bucketing
        self._queue = []
        self._first_arrival: Optional[float] = None
        self.next_deadline: Optional[float] = None
        self.dispatched_batches = 0
        self.dispatched_requests = 0

    # -------- subclass interface ------------------------------------------
    def target_batch_size(self, now: float) -> int:
        raise NotImplementedError

    def queue_timeout(self, now: float) -> Optional[float]:
        """Relative timeout measured from first-request arrival, or None."""
        raise NotImplementedError

    # -------- shared machinery --------------------------------------------
    def on_request(self, request: Request, now: float) -> None:
        if not self._queue:
            self._first_arrival = now
        self._queue.append(request)
        if len(self._queue) >= max(1, self.target_batch_size(now)):
            self._dispatch(now, "full")
            return
        to = self.queue_timeout(now)
        if to is None:
            self.next_deadline = None
        else:
            deadline = (self._first_arrival or now) + to
            if deadline <= now:
                self._dispatch(now, "timeout")
            else:
                self.next_deadline = deadline

    def on_timer(self, now: float) -> None:
        if self.next_deadline is not None and now + 1e-12 >= self.next_deadline:
            if self._queue:
                self._dispatch(now, "timeout")
            else:
                self.next_deadline = None

    def on_response(self, batch: Batch, upstream_latency: float, now: float) -> None:
        self.monitor.record_upstream(batch.effective_size, upstream_latency, now)
        batch.complete(now)
        for r in batch.requests:
            self.monitor.record_e2e(r.e2e_latency, now)

    def next_event_time(self, now: float) -> Optional[float]:
        return self.next_deadline

    def flush(self, now: float) -> None:
        if self._queue:
            self._dispatch(now, "flush")

    def _dispatch(self, now: float, cause: str) -> None:
        batch = Batch(requests=self._queue, dispatch_time=now, cause=cause)
        if self.bucketing is not None:
            batch.bucket_size = bucket_of(batch.size, self.bucketing)
        for r in batch.requests:
            r.dispatch_time = now
        self._queue = []
        self._first_arrival = None
        self.next_deadline = None
        self.dispatched_batches += 1
        self.dispatched_requests += batch.size
        self.monitor.record_dispatch(batch.size, cause)
        self.dispatch_fn(batch)

    @property
    def max_bs(self) -> int:
        return self.target_batch_size(0.0)

    def stats(self, now: float) -> dict:
        return {
            "max_bs": self.target_batch_size(now),
            "queue_len": len(self._queue),
            "dispatched_batches": self.dispatched_batches,
            "dispatched_requests": self.dispatched_requests,
            "avg_batch_size": (
                self.dispatched_requests / self.dispatched_batches
                if self.dispatched_batches else 0.0
            ),
            "e2e_p": self.monitor.e2e_percentile(now),
            "violation_rate": self.monitor.violation_rate(),
            "timeout_ratio": self.monitor.timeout_ratio(),
        }

    def snapshot(self) -> dict:
        return {
            "monitor": self.monitor.snapshot(),
            "queue": list(self._queue),
            "first_arrival": self._first_arrival,
            "next_deadline": self.next_deadline,
            "counts": (self.dispatched_batches, self.dispatched_requests),
        }

    def restore(self, state: dict) -> None:
        self.monitor.restore(state["monitor"])
        self._queue = list(state["queue"])
        self._first_arrival = state["first_arrival"]
        self.next_deadline = state["next_deadline"]
        self.dispatched_batches, self.dispatched_requests = state["counts"]


class PassthroughPolicy(BatchingPolicy):
    """No batching: forward every request immediately (stock API gateway)."""

    def target_batch_size(self, now: float) -> int:
        return 1

    def queue_timeout(self, now: float) -> Optional[float]:
        return 0.0


class StaticBatchPolicy(BatchingPolicy):
    """Fixed batch size and fixed queue timeout."""

    def __init__(self, sla, dispatch_fn, batch_size: int, timeout: float, **kw) -> None:
        super().__init__(sla, dispatch_fn, **kw)
        self._bs = batch_size
        self._to = timeout

    def target_batch_size(self, now: float) -> int:
        return self._bs

    def queue_timeout(self, now: float) -> Optional[float]:
        return self._to


class ClipperAIMDPolicy(BatchingPolicy):
    """Clipper-style AIMD: grow batch size additively while the windowed
    latency percentile meets the SLO; back off multiplicatively otherwise.
    The queue timeout is a fixed fraction of the SLO budget."""

    def __init__(self, sla, dispatch_fn, inc: int = 1, dec_mult: float = 0.9,
                 update_interval: float = 10.0, timeout_frac: float = 0.25,
                 max_cap: int = 256, **kw) -> None:
        super().__init__(sla, dispatch_fn, **kw)
        self.inc = inc
        self.dec_mult = dec_mult
        self.update_interval = update_interval
        self.timeout_frac = timeout_frac
        self.max_cap = max_cap
        self._bs = 1.0
        self._last_update: Optional[float] = None

    def target_batch_size(self, now: float) -> int:
        return max(1, min(self.max_cap, int(self._bs)))

    def queue_timeout(self, now: float) -> Optional[float]:
        return self.sla.slo_target * self.timeout_frac

    def on_timer(self, now: float) -> None:
        super().on_timer(now)
        if self._last_update is None:
            self._last_update = now
            return
        # epsilon tolerance: without it a timer that fires a float-ulp
        # before the interval boundary never advances _last_update while
        # next_event_time keeps returning the same instant (spin)
        if now - self._last_update >= self.update_interval - 1e-9:
            p = self.monitor.e2e_percentile(now)
            if p is not None and p > self.sla.slo_target:
                self._bs = max(1.0, self._bs * self.dec_mult)
            else:
                self._bs = min(float(self.max_cap), self._bs + self.inc)
            self._last_update = now

    def next_event_time(self, now: float) -> Optional[float]:
        nxt = (self._last_update + self.update_interval
               if self._last_update is not None
               else now + self.update_interval)
        if self.next_deadline is not None:
            return min(self.next_deadline, nxt)
        return nxt


class OracleStaticPolicy(BatchingPolicy):
    """BATCH-style profiled baseline: requires an offline latency model
    ``latency_model(bs) -> p95 seconds`` (the profiling step MLProxy
    removes) and solves for the largest SLO-feasible batch size."""

    def __init__(self, sla, dispatch_fn, latency_model: Callable[[int], float],
                 headroom: float = 0.9, max_cap: int = 256, **kw) -> None:
        super().__init__(sla, dispatch_fn, **kw)
        self.latency_model = latency_model
        budget = sla.slo_target * headroom
        bs = 1
        for cand in range(1, max_cap + 1):
            if latency_model(cand) <= budget:
                bs = cand
            else:
                break
        self._bs = bs
        self._to = max(0.0, budget - latency_model(bs))

    def target_batch_size(self, now: float) -> int:
        return self._bs

    def queue_timeout(self, now: float) -> Optional[float]:
        return self._to


def make_policy(name: str, sla: SLAConfig, dispatch_fn, **kwargs):
    """Factory used by the simulator and benchmarks."""
    if name == "mlproxy":
        proxy_cfg = kwargs.pop("proxy_config", None) or ProxyConfig(sla=sla, **kwargs)
        return MLProxy(proxy_cfg, dispatch_fn)
    if name == "passthrough":
        return PassthroughPolicy(sla, dispatch_fn, **kwargs)
    if name == "static":
        return StaticBatchPolicy(sla, dispatch_fn, **kwargs)
    if name == "clipper":
        return ClipperAIMDPolicy(sla, dispatch_fn, **kwargs)
    if name == "oracle":
        return OracleStaticPolicy(sla, dispatch_fn, **kwargs)
    raise ValueError(f"unknown policy {name!r}")
