"""ProxyFrontend — the multi-endpoint routing layer.

The paper deploys one MLProxy per serverless endpoint; a production fleet
serves many models with many SLA classes through one proxy process. The
frontend owns N named endpoints — each with its own
:class:`~repro.core.batch_queue.Policy` (MLProxy or any baseline), its own
:class:`~repro.core.config.SLAConfig`, and its own dispatch target — and:

* routes arrivals by endpoint key (``request.endpoint`` or an explicit
  argument),
* stamps every outgoing :class:`~repro.core.request.Batch` with its
  endpoint name so shared dispatch targets can demultiplex,
* merges every endpoint's ``next_event_time`` into one timer so the caller
  (simulator or wall-clock serving loop) runs a single clock,
* exposes aggregated and per-endpoint ``stats``/``snapshot``/``restore``.

The frontend is clock-free like the policies beneath it: callers pass
``now`` into every method.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.batch_queue import DispatchFn, ExpireFn, Policy
from repro.core.config import SLAConfig
from repro.core.request import Batch, Request


@dataclasses.dataclass(frozen=True)
class TierRoute:
    """Router-facing view of one fleet tier.

    The router prefers tiers in ascending ``cost_weight`` order and
    escalates past a tier when any enabled guard trips (0 disables a
    guard): ``max_inflight`` caps batches dispatched-but-unresolved on
    the tier, ``queue_depth_max`` bounds the tier's backend queue as
    seen through the router's queue probe, and ``latency_threshold``
    bounds the tier's recent (EWMA) upstream latency.
    """

    name: str
    cost_weight: float = 1.0
    max_inflight: int = 0
    queue_depth_max: int = 0
    latency_threshold: float = 0.0


class SpilloverRouter:
    """Cost-aware tier selection at batch dispatch time.

    One router per endpoint. The frontend calls :meth:`route` as each
    batch leaves the policy queue (stamping ``batch.tier``) and
    :meth:`on_batch_done` / :meth:`release` as batches resolve, so the
    in-flight and latency signals are maintained entirely at the
    dispatch seam both worlds share — sim and live runs of the same
    schedule make identical decisions.

    Escalation is deterministic: tiers are probed cheapest-first and the
    first tier with no tripped guard wins; if every tier is guarded, the
    most expensive tier takes the batch (``exhausted``). A tier skipped
    for *latency* is deterministically re-probed every ``probe_every``-th
    consecutive skip, so a recovered tier gets fresh samples instead of
    staying escalated on a stale EWMA forever.

    ``queue_probe(tier_name) -> int`` is the pluggable backend-depth
    signal (platform queue in sim, target queue in live); None disables
    queue-depth escalation.
    """

    def __init__(
        self,
        tiers: Sequence[TierRoute],
        *,
        queue_probe: Optional[Callable[[str], int]] = None,
        latency_alpha: float = 0.2,
        probe_every: int = 16,
        tracer=None,
    ) -> None:
        if not tiers:
            raise ValueError("SpilloverRouter needs at least one tier")
        routes = [
            t if isinstance(t, TierRoute) else TierRoute(
                name=t.name, cost_weight=t.cost_weight,
                max_inflight=t.max_inflight,
                queue_depth_max=t.queue_depth_max,
                latency_threshold=t.latency_threshold)
            for t in tiers
        ]
        names = [r.name for r in routes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        # stable sort: equal-cost tiers keep their given order
        self.order: Tuple[TierRoute, ...] = tuple(
            sorted(routes, key=lambda r: r.cost_weight))
        self._queue_probe = queue_probe
        self.latency_alpha = latency_alpha
        self.probe_every = probe_every
        self._tracer = tracer
        self._inflight: Dict[str, int] = {r.name: 0 for r in self.order}
        self._lat_ema: Dict[str, Optional[float]] = {
            r.name: None for r in self.order}
        self._skips: Dict[str, int] = {r.name: 0 for r in self.order}
        self.decisions = 0
        self.spillovers = 0  # batches routed past the cheapest tier
        self.routed: Dict[str, int] = {r.name: 0 for r in self.order}
        self.escalations: Dict[str, int] = {
            "inflight_cap": 0, "queue_depth": 0, "latency": 0}
        # (t, endpoint, size, tier, reason) — the byte-identity artifact
        # tests compare across same-seed runs
        self.decision_log: List[Tuple[float, str, int, str, str]] = []

    @property
    def tier_names(self) -> Tuple[str, ...]:
        return tuple(r.name for r in self.order)

    def _skip_reason(self, r: TierRoute) -> Optional[str]:
        if r.max_inflight > 0 and self._inflight[r.name] >= r.max_inflight:
            return "inflight_cap"
        if (r.queue_depth_max > 0 and self._queue_probe is not None
                and self._queue_probe(r.name) >= r.queue_depth_max):
            return "queue_depth"
        if r.latency_threshold > 0:
            ema = self._lat_ema[r.name]
            if ema is not None and ema > r.latency_threshold:
                return "latency"
        return None

    def route(self, batch: Batch, now: float) -> str:
        """Pick a tier for ``batch`` and stamp ``batch.tier``."""
        chosen: Optional[TierRoute] = None
        reason = "exhausted"
        for idx, r in enumerate(self.order):
            skip = self._skip_reason(r)
            if skip is None:
                self._skips[r.name] = 0
                chosen = r
                reason = "preferred" if idx == 0 else "spillover"
                break
            self._skips[r.name] += 1
            if (skip == "latency" and self.probe_every > 0
                    and self._skips[r.name] % self.probe_every == 0):
                chosen = r
                reason = "probe"
                break
            self.escalations[skip] += 1
        if chosen is None:
            chosen = self.order[-1]
        self.decisions += 1
        if chosen is not self.order[0]:
            self.spillovers += 1
        self._inflight[chosen.name] += 1
        self.routed[chosen.name] += 1
        batch.tier = chosen.name
        self.decision_log.append(
            (now, batch.endpoint or "", batch.size, chosen.name, reason))
        if self._tracer is not None:
            self._tracer.emit(now, "routed", batch.endpoint or "",
                              batch=batch.trace_id, size=batch.size,
                              detail=f"{chosen.name}:{reason}")
        return chosen.name

    def release(self, tier: Optional[str]) -> None:
        """Return one in-flight slot without a latency sample (failure /
        timeout terminals, where no upstream latency exists)."""
        if tier in self._inflight and self._inflight[tier] > 0:
            self._inflight[tier] -= 1

    def on_batch_done(self, tier: Optional[str], upstream_latency: float,
                      now: float) -> None:
        """Completion hook: frees the slot and feeds the latency EWMA."""
        self.release(tier)
        if tier in self._lat_ema and upstream_latency is not None:
            prev = self._lat_ema[tier]
            a = self.latency_alpha
            self._lat_ema[tier] = (
                upstream_latency if prev is None
                else (1.0 - a) * prev + a * upstream_latency)

    def stats(self) -> dict:
        return {
            "decisions": self.decisions,
            "spillovers": self.spillovers,
            "spillover_rate": (self.spillovers / self.decisions
                               if self.decisions else 0.0),
            "routed": dict(self.routed),
            "inflight": dict(self._inflight),
            "escalations": dict(self.escalations),
        }

    def register_metrics(self, registry, prefix: str = "router") -> None:
        """Bind routing counters into a MetricsRegistry."""
        b = registry.bind
        b(f"{prefix}.decisions", lambda: self.decisions)
        b(f"{prefix}.spillovers", lambda: self.spillovers)
        for r in self.order:
            b(f"{prefix}.routed.{r.name}",
              lambda _n=r.name: self.routed[_n])
            b(f"{prefix}.inflight.{r.name}",
              lambda _n=r.name: self._inflight[_n])
        for why in self.escalations:
            b(f"{prefix}.escalations.{why}",
              lambda _w=why: self.escalations[_w])


@dataclasses.dataclass
class Endpoint:
    """One named endpoint: its policy, SLA, and dispatch target."""

    name: str
    policy: Policy
    sla: SLAConfig
    dispatch_fn: DispatchFn  # the unwrapped target (platform, pool, ...)
    # Optional fleet-tier selector; when set, every dispatched batch is
    # stamped with a tier before it reaches dispatch_fn.
    router: Optional[SpilloverRouter] = None

    @property
    def deadline_budget(self) -> Optional[float]:
        """Per-request deadline budget in seconds (None = no deadlines)."""
        return self.sla.deadline_budget


class ProxyFrontend:
    """Routes requests across N endpoints, each with its own policy + SLA.

    ``tracer`` (optional :class:`repro.obs.trace.Tracer`) turns on
    lifecycle span emission: the frontend stamps ``admitted`` at
    admission and hands the tracer down to every endpoint's policy
    queue. None (the default) costs one attribute check per arrival.
    """

    def __init__(self, tracer=None) -> None:
        self._endpoints: Dict[str, Endpoint] = {}
        self._tracer = tracer

    # ------------------------------------------------------------- topology
    def add_endpoint(
        self,
        name: str,
        *,
        sla: SLAConfig,
        dispatch_fn: DispatchFn,
        policy: str = "mlproxy",
        policy_kwargs: Optional[dict] = None,
        expire_fn: Optional[ExpireFn] = None,
        router: Optional[SpilloverRouter] = None,
    ) -> Endpoint:
        """Register an endpoint; ``policy`` is a :func:`make_policy` name.

        The policy's dispatch path is wrapped so every batch is stamped
        with the endpoint name — and, when ``router`` is given, with the
        :class:`SpilloverRouter`'s tier choice — before it reaches
        ``dispatch_fn``. ``expire_fn(requests, now)`` (optional) fires
        whenever the policy's queue evicts deadline-expired requests, so
        the caller can resolve them (the live runtime completes their
        tickets with a ``DeadlineExceeded`` result).
        """
        # deferred import: policies imports proxy which imports batch_queue
        from repro.core.policies import make_policy

        if name in self._endpoints:
            raise ValueError(f"endpoint {name!r} already registered")

        def stamped_dispatch(batch: Batch, _name=name, _fn=dispatch_fn,
                             _router=router) -> None:
            batch.endpoint = _name
            for r in batch.requests:
                r.endpoint = _name
            if _router is not None:
                # dispatch_time IS the policy's `now` for this batch —
                # the router needs no clock of its own
                _router.route(batch, batch.dispatch_time)
            _fn(batch)

        pol = make_policy(policy, sla, stamped_dispatch, expire_fn=expire_fn,
                          tracer=self._tracer, **(policy_kwargs or {}))
        ep = Endpoint(name=name, policy=pol, sla=sla, dispatch_fn=dispatch_fn,
                      router=router)
        self._endpoints[name] = ep
        return ep

    def endpoint(self, name: str) -> Endpoint:
        return self._endpoints[name]

    @property
    def names(self) -> List[str]:
        return list(self._endpoints)

    def __len__(self) -> int:
        return len(self._endpoints)

    # -------------------------------------------------------------- routing
    def resolve(self, key: Optional[str]) -> Endpoint:
        """Public routing lookup: endpoint for ``key`` (None ⇒ the only one)."""
        return self._resolve(key)

    def _resolve(self, key: Optional[str]) -> Endpoint:
        if key is None:
            if len(self._endpoints) == 1:
                return next(iter(self._endpoints.values()))
            raise KeyError(
                "request has no endpoint key and the frontend serves "
                f"{len(self._endpoints)} endpoints"
            )
        try:
            return self._endpoints[key]
        except KeyError:
            raise KeyError(
                f"unknown endpoint {key!r}; registered: {sorted(self._endpoints)}"
            ) from None

    def on_request(self, request: Request, now: float,
                   endpoint: Optional[str] = None) -> None:
        """Route one arrival to its endpoint's policy.

        Admission is where deadlines attach: a client-supplied
        ``request.deadline`` is honored as-is; otherwise, if the
        endpoint's SLA sets ``deadline_factor``, the deadline is derived
        here as ``now + slo_target × deadline_factor``.
        """
        ep = self._resolve(endpoint or request.endpoint)
        request.endpoint = ep.name
        if request.deadline is None and ep.deadline_budget is not None:
            request.deadline = now + ep.deadline_budget
        if self._tracer is not None:
            self._tracer.emit(now, "admitted", ep.name, request.req_id)
        ep.policy.on_request(request, now)

    def on_response(self, batch: Batch, upstream_latency: float, now: float) -> None:
        """Route a completed upstream batch back to the owning policy."""
        ep = self._resolve(batch.endpoint)
        if ep.router is not None and batch.tier is not None:
            ep.router.on_batch_done(batch.tier, upstream_latency, now)
        ep.policy.on_response(batch, upstream_latency, now)

    # --------------------------------------------------------------- timers
    def on_timer(self, now: float) -> None:
        """Fire every endpoint's timer; each policy guards its own deadline."""
        for ep in self._endpoints.values():
            ep.policy.on_timer(now)

    def next_event_time(self, now: float) -> Optional[float]:
        """Merged timer: the earliest ``next_event_time`` across endpoints."""
        times = [
            t for ep in self._endpoints.values()
            if (t := ep.policy.next_event_time(now)) is not None
        ]
        return min(times) if times else None

    def flush(self, now: float) -> None:
        for ep in self._endpoints.values():
            ep.policy.flush(now)

    # -------------------------------------------------------------- metrics
    def stats(self, now: float) -> dict:
        """Per-endpoint stats plus a fleet-level aggregate."""
        per = {name: ep.policy.stats(now) for name, ep in self._endpoints.items()}
        agg_batches = sum(s["dispatched_batches"] for s in per.values())
        agg_requests = sum(s["dispatched_requests"] for s in per.values())
        agg_retried = sum(s.get("retried_batches", 0) for s in per.values())
        agg_upstream = sum(s.get("upstream_batches", 0) for s in per.values())
        agg_slots = sum(s.get("dispatched_slots", 0) for s in per.values())
        agg_padded = sum(s.get("padded_slots", 0) for s in per.values())
        agg_attempts = sum(
            s.get("upstream_attempts", s.get("upstream_batches", 0))
            for s in per.values())
        agg_failed = sum(s.get("failed_attempts", 0) for s in per.values())
        return {
            "endpoints": per,
            "aggregate": {
                "n_endpoints": len(per),
                "queue_len": sum(s["queue_len"] for s in per.values()),
                # deepest any single endpoint queue has been (max, not sum:
                # the HWMs of different endpoints happen at different times)
                "queue_depth_hwm": max(
                    (s.get("queue_depth_hwm", 0) for s in per.values()),
                    default=0),
                "dispatched_batches": agg_batches,
                "dispatched_requests": agg_requests,
                # deadline-expired requests evicted before dispatch
                "expired": sum(s.get("expired", 0) for s in per.values()),
                # brownout-shed requests evicted at admission pressure
                "shed": sum(s.get("shed", 0) for s in per.values()),
                "avg_batch_size": agg_requests / agg_batches if agg_batches else 0.0,
                # upstream completion/attempt ledger (drift-audit parity
                # with the per-endpoint stats surface)
                "upstream_batches": agg_upstream,
                "upstream_attempts": agg_attempts,
                "dispatched_slots": agg_slots,
                "padded_slots": agg_padded,
                # platform-side crash retries / hedges, observed through
                # Batch.attempts on the completion path; rate is over
                # *completed* upstream batches, same as per-endpoint stats
                "retried_batches": agg_retried,
                "retry_rate": agg_retried / agg_upstream if agg_upstream else 0.0,
                # failed upstream attempts (target errors / injected
                # faults), over all attempts that reached the target
                "failed_attempts": agg_failed,
                "failure_rate": (agg_failed / (agg_attempts + agg_failed)
                                 if (agg_attempts + agg_failed) else 0.0),
                # bucket slots burned on padding, over all dispatched slots
                # (0.0 on unbucketed endpoints: every slot is a request)
                "padding_waste": agg_padded / agg_slots if agg_slots else 0.0,
                # worst-endpoint SLO burn (max, not mean: the alerting
                # question is "is ANY endpoint burning its budget")
                "burn_rate_fast": max(
                    (s.get("burn_rate_fast", 0.0) for s in per.values()),
                    default=0.0),
                "burn_rate_slow": max(
                    (s.get("burn_rate_slow", 0.0) for s in per.values()),
                    default=0.0),
            },
        }

    # ------------------------------------------------------ fault tolerance
    def snapshot(self) -> dict:
        return {name: ep.policy.snapshot() for name, ep in self._endpoints.items()}

    def restore(self, state: dict) -> None:
        for name, sub in state.items():
            self._endpoints[name].policy.restore(sub)
