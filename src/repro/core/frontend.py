"""ProxyFrontend — the multi-endpoint routing layer.

The paper deploys one MLProxy per serverless endpoint; a production fleet
serves many models with many SLA classes through one proxy process. The
frontend owns N named endpoints — each with its own
:class:`~repro.core.batch_queue.Policy` (MLProxy or any baseline), its own
:class:`~repro.core.config.SLAConfig`, and its own dispatch target — and:

* routes arrivals by endpoint key (``request.endpoint`` or an explicit
  argument),
* stamps every outgoing :class:`~repro.core.request.Batch` with its
  endpoint name so shared dispatch targets can demultiplex,
* merges every endpoint's ``next_event_time`` into one timer so the caller
  (simulator or wall-clock serving loop) runs a single clock,
* exposes aggregated and per-endpoint ``stats``/``snapshot``/``restore``.

The frontend is clock-free like the policies beneath it: callers pass
``now`` into every method.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core.batch_queue import DispatchFn, ExpireFn, Policy
from repro.core.config import SLAConfig
from repro.core.request import Batch, Request


@dataclasses.dataclass
class Endpoint:
    """One named endpoint: its policy, SLA, and dispatch target."""

    name: str
    policy: Policy
    sla: SLAConfig
    dispatch_fn: DispatchFn  # the unwrapped target (platform, pool, ...)

    @property
    def deadline_budget(self) -> Optional[float]:
        """Per-request deadline budget in seconds (None = no deadlines)."""
        return self.sla.deadline_budget


class ProxyFrontend:
    """Routes requests across N endpoints, each with its own policy + SLA.

    ``tracer`` (optional :class:`repro.obs.trace.Tracer`) turns on
    lifecycle span emission: the frontend stamps ``admitted`` at
    admission and hands the tracer down to every endpoint's policy
    queue. None (the default) costs one attribute check per arrival.
    """

    def __init__(self, tracer=None) -> None:
        self._endpoints: Dict[str, Endpoint] = {}
        self._tracer = tracer

    # ------------------------------------------------------------- topology
    def add_endpoint(
        self,
        name: str,
        *,
        sla: SLAConfig,
        dispatch_fn: DispatchFn,
        policy: str = "mlproxy",
        policy_kwargs: Optional[dict] = None,
        expire_fn: Optional[ExpireFn] = None,
    ) -> Endpoint:
        """Register an endpoint; ``policy`` is a :func:`make_policy` name.

        The policy's dispatch path is wrapped so every batch is stamped
        with the endpoint name before it reaches ``dispatch_fn``.
        ``expire_fn(requests, now)`` (optional) fires whenever the
        policy's queue evicts deadline-expired requests, so the caller
        can resolve them (the live runtime completes their tickets with a
        ``DeadlineExceeded`` result).
        """
        # deferred import: policies imports proxy which imports batch_queue
        from repro.core.policies import make_policy

        if name in self._endpoints:
            raise ValueError(f"endpoint {name!r} already registered")

        def stamped_dispatch(batch: Batch, _name=name, _fn=dispatch_fn) -> None:
            batch.endpoint = _name
            for r in batch.requests:
                r.endpoint = _name
            _fn(batch)

        pol = make_policy(policy, sla, stamped_dispatch, expire_fn=expire_fn,
                          tracer=self._tracer, **(policy_kwargs or {}))
        ep = Endpoint(name=name, policy=pol, sla=sla, dispatch_fn=dispatch_fn)
        self._endpoints[name] = ep
        return ep

    def endpoint(self, name: str) -> Endpoint:
        return self._endpoints[name]

    @property
    def names(self) -> List[str]:
        return list(self._endpoints)

    def __len__(self) -> int:
        return len(self._endpoints)

    # -------------------------------------------------------------- routing
    def resolve(self, key: Optional[str]) -> Endpoint:
        """Public routing lookup: endpoint for ``key`` (None ⇒ the only one)."""
        return self._resolve(key)

    def _resolve(self, key: Optional[str]) -> Endpoint:
        if key is None:
            if len(self._endpoints) == 1:
                return next(iter(self._endpoints.values()))
            raise KeyError(
                "request has no endpoint key and the frontend serves "
                f"{len(self._endpoints)} endpoints"
            )
        try:
            return self._endpoints[key]
        except KeyError:
            raise KeyError(
                f"unknown endpoint {key!r}; registered: {sorted(self._endpoints)}"
            ) from None

    def on_request(self, request: Request, now: float,
                   endpoint: Optional[str] = None) -> None:
        """Route one arrival to its endpoint's policy.

        Admission is where deadlines attach: a client-supplied
        ``request.deadline`` is honored as-is; otherwise, if the
        endpoint's SLA sets ``deadline_factor``, the deadline is derived
        here as ``now + slo_target × deadline_factor``.
        """
        ep = self._resolve(endpoint or request.endpoint)
        request.endpoint = ep.name
        if request.deadline is None and ep.deadline_budget is not None:
            request.deadline = now + ep.deadline_budget
        if self._tracer is not None:
            self._tracer.emit(now, "admitted", ep.name, request.req_id)
        ep.policy.on_request(request, now)

    def on_response(self, batch: Batch, upstream_latency: float, now: float) -> None:
        """Route a completed upstream batch back to the owning policy."""
        self._resolve(batch.endpoint).policy.on_response(batch, upstream_latency, now)

    # --------------------------------------------------------------- timers
    def on_timer(self, now: float) -> None:
        """Fire every endpoint's timer; each policy guards its own deadline."""
        for ep in self._endpoints.values():
            ep.policy.on_timer(now)

    def next_event_time(self, now: float) -> Optional[float]:
        """Merged timer: the earliest ``next_event_time`` across endpoints."""
        times = [
            t for ep in self._endpoints.values()
            if (t := ep.policy.next_event_time(now)) is not None
        ]
        return min(times) if times else None

    def flush(self, now: float) -> None:
        for ep in self._endpoints.values():
            ep.policy.flush(now)

    # -------------------------------------------------------------- metrics
    def stats(self, now: float) -> dict:
        """Per-endpoint stats plus a fleet-level aggregate."""
        per = {name: ep.policy.stats(now) for name, ep in self._endpoints.items()}
        agg_batches = sum(s["dispatched_batches"] for s in per.values())
        agg_requests = sum(s["dispatched_requests"] for s in per.values())
        agg_retried = sum(s.get("retried_batches", 0) for s in per.values())
        agg_upstream = sum(s.get("upstream_batches", 0) for s in per.values())
        agg_slots = sum(s.get("dispatched_slots", 0) for s in per.values())
        agg_padded = sum(s.get("padded_slots", 0) for s in per.values())
        agg_attempts = sum(
            s.get("upstream_attempts", s.get("upstream_batches", 0))
            for s in per.values())
        agg_failed = sum(s.get("failed_attempts", 0) for s in per.values())
        return {
            "endpoints": per,
            "aggregate": {
                "n_endpoints": len(per),
                "queue_len": sum(s["queue_len"] for s in per.values()),
                # deepest any single endpoint queue has been (max, not sum:
                # the HWMs of different endpoints happen at different times)
                "queue_depth_hwm": max(
                    (s.get("queue_depth_hwm", 0) for s in per.values()),
                    default=0),
                "dispatched_batches": agg_batches,
                "dispatched_requests": agg_requests,
                # deadline-expired requests evicted before dispatch
                "expired": sum(s.get("expired", 0) for s in per.values()),
                # brownout-shed requests evicted at admission pressure
                "shed": sum(s.get("shed", 0) for s in per.values()),
                "avg_batch_size": agg_requests / agg_batches if agg_batches else 0.0,
                # upstream completion/attempt ledger (drift-audit parity
                # with the per-endpoint stats surface)
                "upstream_batches": agg_upstream,
                "upstream_attempts": agg_attempts,
                "dispatched_slots": agg_slots,
                "padded_slots": agg_padded,
                # platform-side crash retries / hedges, observed through
                # Batch.attempts on the completion path; rate is over
                # *completed* upstream batches, same as per-endpoint stats
                "retried_batches": agg_retried,
                "retry_rate": agg_retried / agg_upstream if agg_upstream else 0.0,
                # failed upstream attempts (target errors / injected
                # faults), over all attempts that reached the target
                "failed_attempts": agg_failed,
                "failure_rate": (agg_failed / (agg_attempts + agg_failed)
                                 if (agg_attempts + agg_failed) else 0.0),
                # bucket slots burned on padding, over all dispatched slots
                # (0.0 on unbucketed endpoints: every slot is a request)
                "padding_waste": agg_padded / agg_slots if agg_slots else 0.0,
                # worst-endpoint SLO burn (max, not mean: the alerting
                # question is "is ANY endpoint burning its budget")
                "burn_rate_fast": max(
                    (s.get("burn_rate_fast", 0.0) for s in per.values()),
                    default=0.0),
                "burn_rate_slow": max(
                    (s.get("burn_rate_slow", 0.0) for s in per.values()),
                    default=0.0),
            },
        }

    # ------------------------------------------------------ fault tolerance
    def snapshot(self) -> dict:
        return {name: ep.policy.snapshot() for name, ep in self._endpoints.items()}

    def restore(self, state: dict) -> None:
        for name, sub in state.items():
            self._endpoints[name].policy.restore(sub)
