"""MLProxy — the adaptive reverse proxy (Smart Proxy + Smart Monitor).

Wires together the three paper components behind the event-driven
:class:`~repro.core.batch_queue.Policy` protocol:

    proxy = MLProxy(config, dispatch_fn=send_upstream)
    proxy.on_request(req, now)             # arrival path (Algorithm 1)
    proxy.on_response(batch, latency, now) # upstream completion → monitor
    proxy.on_timer(now)                    # timeout + AIMD ticks
    proxy.next_event_time(now)             # earliest time on_timer is needed

The queue/dispatch mechanics under the scheduler live in the shared
:class:`~repro.core.batch_queue.BatchQueue` — the same primitive every
baseline in :mod:`repro.core.policies` runs on — so MLProxy differs from
the baselines only in its decision logic (Algorithms 1 + 2).

``dispatch_fn(batch)`` is the only outbound dependency — the simulator sends
the batch to the modeled serverless platform; the real serving path sends it
to the JAX :class:`~repro.serving.engine.InferenceEngine`. Multiple MLProxy
instances (or baselines) are composed behind one
:class:`~repro.core.frontend.ProxyFrontend` for multi-endpoint serving.
"""
from __future__ import annotations

from typing import Callable, List, Optional

from repro.core.batch_queue import ExpireFn
from repro.core.config import ProxyConfig
from repro.core.monitor import SmartMonitor
from repro.core.optimizer import AIMDBatchOptimizer
from repro.core.request import Batch, Request
from repro.core.scheduler import QueueScheduler


class MLProxy:
    """Single-endpoint adaptive batching proxy (the paper's contribution)."""

    def __init__(self, config: ProxyConfig, dispatch_fn: Callable[[Batch], None],
                 expire_fn: Optional[ExpireFn] = None, tracer=None) -> None:
        self.config = config
        self.monitor = SmartMonitor(config.monitor, config.sla)
        self.optimizer = AIMDBatchOptimizer(config.optimizer, config.sla, self.monitor)
        self.scheduler = QueueScheduler(
            config=config,
            monitor=self.monitor,
            dispatch_fn=dispatch_fn,
            max_bs_fn=lambda: self.optimizer.max_bs,
            expire_fn=expire_fn,
            tracer=tracer,
        )
        self._started = False

    # ------------------------------------------------------------------ api
    def on_request(self, request: Request, now: float) -> None:
        if not self._started:
            # anchor the AIMD interval to first traffic
            self.optimizer.maybe_update(now)
            self._started = True
        self.scheduler.on_arrival(request, now)

    def on_response(self, batch: Batch, upstream_latency: float, now: float) -> None:
        """Record a completed upstream batch; completes every member request."""
        # Monitor keys by the *effective* (padded) size on bucketed backends:
        # that is the size whose latency the next dispatch decision must
        # predict.
        self.monitor.record_upstream(batch.effective_size, upstream_latency, now,
                                     attempts=batch.attempts)
        batch.complete(now)
        for r in batch.requests:
            assert r.e2e_latency is not None
            self.monitor.record_e2e(r.e2e_latency, now)

    def on_timer(self, now: float) -> None:
        self.scheduler.on_timer(now)
        self.optimizer.maybe_update(now)

    def next_event_time(self, now: float) -> Optional[float]:
        """Earliest future time at which :meth:`on_timer` must run.

        Merges the dispatch deadline, the earliest queued-request expiry,
        and the AIMD update tick."""
        deadline = self.scheduler.queue.next_event_time()
        if not self._started:
            return deadline
        update = self.optimizer.next_update_time(now)
        if deadline is None or update < deadline:
            return update
        return deadline

    def expire(self, now: float) -> List[Request]:
        """Evict deadline-expired queued requests (O(1) when none)."""
        return self.scheduler.queue.expire(now)

    def shed(self, now: float, keep: int) -> List[Request]:
        """Evict queued requests beyond ``keep``, lowest slack first."""
        return self.scheduler.queue.shed(now, keep)

    def flush(self, now: float) -> None:
        self.scheduler.flush(now)

    # --------------------------------------------------------------- metrics
    @property
    def max_bs(self) -> int:
        return self.optimizer.max_bs

    @property
    def queue_len(self) -> int:
        return self.scheduler.queue_len

    def stats(self, now: float) -> dict:
        # One canonical key set for every policy — see BatchQueue.stats.
        return self.scheduler.queue.stats(
            self.monitor, now,
            max_bs=self.optimizer.max_bs,
            max_bs_raw=self.optimizer.max_bs_raw)

    # ------------------------------------------------------ fault tolerance
    def snapshot(self) -> dict:
        """Serializable control-plane state (crash/restart resumes warm)."""
        return {
            "monitor": self.monitor.snapshot(),
            "optimizer": self.optimizer.snapshot(),
            "scheduler": self.scheduler.snapshot(),
            "started": self._started,
        }

    def restore(self, state: dict) -> None:
        self.monitor.restore(state["monitor"])
        self.optimizer.restore(state["optimizer"])
        self.scheduler.restore(state["scheduler"])
        self._started = state["started"]
