"""Algorithm 2 — the low-frequency AIMD dynamic batch optimizer.

Every ``update_interval`` seconds (paper: 30 s), compare the monitored
end-to-end response-time percentile and the timeout-dispatch ratio against
their thresholds; on violation apply multiplicative decrease, otherwise
additive increase:

    violation = (TO_ratio > TO_thresh) or (RT_p95 > compliance_factor · SLO)
    Max_BS    = Max_BS × dec_mult      if violation
    Max_BS    = Max_BS + inc_step      otherwise

``Max_BS`` is kept as a float internally (so repeated ×0.8 decreases
compose exactly as in the paper) and exposed as an integer ≥ 1.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.core.config import OptimizerConfig, SLAConfig
from repro.core.monitor import SmartMonitor


class AIMDBatchOptimizer:
    """Paper-faithful AIMD controller for ``Max_BS``."""

    def __init__(
        self,
        config: OptimizerConfig,
        sla: SLAConfig,
        monitor: SmartMonitor,
    ) -> None:
        self.config = config
        self.sla = sla
        self.monitor = monitor
        self._max_bs = float(config.initial_max_bs)
        self._last_update: Optional[float] = None
        self.history: List[Tuple[float, float, bool]] = []  # (t, max_bs, violation)

    # ------------------------------------------------------------------ api
    @property
    def max_bs(self) -> int:
        return max(self.config.min_bs, min(self.config.max_bs_cap, int(self._max_bs)))

    @property
    def max_bs_raw(self) -> float:
        return self._max_bs

    def next_update_time(self, now: float) -> float:
        if self._last_update is None:
            return now + self.config.update_interval
        return self._last_update + self.config.update_interval

    def maybe_update(self, now: float) -> bool:
        """Run one AIMD step if the interval has elapsed. Returns True if run."""
        if self._last_update is None:
            self._last_update = now
            return False
        if now - self._last_update + 1e-12 < self.config.update_interval:
            return False
        self.update(now)
        return True

    def update(self, now: float) -> None:
        """One unconditional AIMD step (lines 5–15 of Algorithm 2)."""
        rt = self.monitor.e2e_percentile(now)
        to_ratio = self.monitor.timeout_ratio()
        violation = to_ratio > self.config.to_thresh or (
            rt is not None and rt > self.sla.compliance_target
        )
        if violation:
            self._max_bs = max(float(self.config.min_bs), self._max_bs * self.config.dec_mult)
        else:
            self._max_bs = min(
                float(self.config.max_bs_cap), self._max_bs + self.config.inc_step
            )
        self._last_update = now
        self.monitor.reset_interval()
        self.history.append((now, self._max_bs, violation))

    # ------------------------------------------------------ fault tolerance
    def snapshot(self) -> dict:
        return {
            "max_bs": self._max_bs,
            "last_update": self._last_update,
            "history": list(self.history),
        }

    def restore(self, state: dict) -> None:
        self._max_bs = state["max_bs"]
        self._last_update = state["last_update"]
        self.history = list(state["history"])
