"""Algorithm 1 — the high-frequency queue scheduler.

Event-driven implementation of the paper's queue scheduler: on every arrival
the dispatch timeout is recomputed from the monitor's latency estimate for a
batch one larger than the current queue,

    DTO = SLO_T − RT_p95[N_q + 1]
    TO  = DTO − FRT        (FRT = age of the oldest queued request)

and the batch is dispatched when (a) it reaches ``Max_BS``, (b) the timeout
fires, or (c) ``TO ≤ 0`` at recomputation time (the paper's "negative DTO →
dispatch immediately" rule, which also covers negative DTO).

The scheduler is clock-free: callers pass ``now`` into every method, and read
``next_deadline`` to know when to call :meth:`on_timer`. This makes the same
object usable from the discrete-event simulator and from a wall-clock loop.
"""
from __future__ import annotations

from typing import Callable, List, Optional

from repro.core.config import ProxyConfig, bucket_of
from repro.core.monitor import SmartMonitor
from repro.core.request import Batch, Request

DispatchFn = Callable[[Batch], None]


class QueueScheduler:
    """Single-endpoint batch queue implementing Algorithm 1."""

    def __init__(
        self,
        config: ProxyConfig,
        monitor: SmartMonitor,
        dispatch_fn: DispatchFn,
        max_bs_fn: Callable[[], int],
    ) -> None:
        self.config = config
        self.monitor = monitor
        self.dispatch_fn = dispatch_fn
        self.max_bs_fn = max_bs_fn
        self._queue: List[Request] = []
        self._first_arrival: Optional[float] = None  # FRT reference point
        self.next_deadline: Optional[float] = None
        # counters for introspection / tests
        self.dispatched_batches = 0
        self.dispatched_requests = 0

    # ------------------------------------------------------------------ api
    @property
    def queue_len(self) -> int:
        return len(self._queue)

    def on_arrival(self, request: Request, now: float) -> None:
        """Handle one request arrival (lines 5–20 of Algorithm 1)."""
        if self._queue:
            # A pending timeout exists; arrival cancels and recomputes it.
            self.next_deadline = None
        else:
            self._first_arrival = now  # "if BS=0 then FRT ← reset"
        self._queue.append(request)

        max_bs = max(1, self.max_bs_fn())
        if len(self._queue) >= max_bs:
            self._dispatch(now, cause="full")
            return

        # DTO = SLO − RT95[N_q + 1]; probing one size larger guards against
        # the latency of the batch after one more arrival (paper eq. 1).
        est = self.monitor.upstream_percentile(len(self._queue) + 1, now)
        dto = self.config.sla.slo_target - est - self.config.dispatch_overhead
        frt = now - (self._first_arrival if self._first_arrival is not None else now)
        to = dto - frt
        if to <= 0:
            # Negative timeout: the queue is already at risk → dispatch now.
            self._dispatch(now, cause="timeout")
        else:
            self.next_deadline = now + to

    def on_timer(self, now: float) -> None:
        """Fire the dispatch timeout if due (lines 21–24 of Algorithm 1)."""
        if self.next_deadline is None or now + 1e-12 < self.next_deadline:
            return
        if self._queue:
            self._dispatch(now, cause="timeout")
        else:  # stale timer
            self.next_deadline = None

    def flush(self, now: float) -> None:
        """Dispatch whatever is queued (shutdown / checkpoint barrier)."""
        if self._queue:
            self._dispatch(now, cause="flush")

    # ------------------------------------------------------------- internals
    def _dispatch(self, now: float, cause: str) -> None:
        batch = Batch(requests=self._queue, dispatch_time=now, cause=cause)
        if self.config.bucketing is not None:
            batch.bucket_size = bucket_of(batch.size, self.config.bucketing)
        for r in batch.requests:
            r.dispatch_time = now
        self._queue = []
        self._first_arrival = None
        self.next_deadline = None
        self.dispatched_batches += 1
        self.dispatched_requests += batch.size
        self.monitor.record_dispatch(batch.size, cause)
        self.dispatch_fn(batch)

    # ------------------------------------------------------ fault tolerance
    def snapshot(self) -> dict:
        return {
            "queue": list(self._queue),
            "first_arrival": self._first_arrival,
            "next_deadline": self.next_deadline,
            "dispatched_batches": self.dispatched_batches,
            "dispatched_requests": self.dispatched_requests,
        }

    def restore(self, state: dict) -> None:
        self._queue = list(state["queue"])
        self._first_arrival = state["first_arrival"]
        self.next_deadline = state["next_deadline"]
        self.dispatched_batches = state["dispatched_batches"]
        self.dispatched_requests = state["dispatched_requests"]
