"""Algorithm 1 — the high-frequency queue scheduler.

Event-driven implementation of the paper's queue scheduler: on every arrival
the dispatch timeout is recomputed from the monitor's latency estimate for a
batch one larger than the current queue,

    DTO = SLO_T − RT_p95[N_q + 1]
    TO  = DTO − FRT        (FRT = age of the oldest queued request)

and the batch is dispatched when (a) it reaches ``Max_BS``, (b) the timeout
fires, or (c) ``TO ≤ 0`` at recomputation time (the paper's "negative DTO →
dispatch immediately" rule, which also covers negative DTO).

The queue/dispatch mechanics (FIFO, FRT anchor, bucketing, counters,
snapshot) live in the shared :class:`~repro.core.batch_queue.BatchQueue`;
this module holds only the Algorithm-1 decision logic on top of it.

The scheduler is clock-free: callers pass ``now`` into every method, and read
``next_deadline`` to know when to call :meth:`on_timer`. This makes the same
object usable from the discrete-event simulator and from a wall-clock loop.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.batch_queue import BatchQueue, DispatchFn, ExpireFn
from repro.core.config import ProxyConfig, bucket_of
from repro.core.monitor import SmartMonitor
from repro.core.request import Request


class QueueScheduler:
    """Single-endpoint batch queue implementing Algorithm 1."""

    def __init__(
        self,
        config: ProxyConfig,
        monitor: SmartMonitor,
        dispatch_fn: DispatchFn,
        max_bs_fn: Callable[[], int],
        expire_fn: Optional[ExpireFn] = None,
        tracer=None,
    ) -> None:
        self.config = config
        self.monitor = monitor
        self.max_bs_fn = max_bs_fn
        self.queue = BatchQueue(dispatch_fn, monitor, bucketing=config.bucketing,
                                expire_fn=expire_fn, tracer=tracer)

    # ------------------------------------------------------------------ api
    @property
    def queue_len(self) -> int:
        return self.queue.queue_len

    @property
    def next_deadline(self) -> Optional[float]:
        return self.queue.next_deadline

    @property
    def dispatched_batches(self) -> int:
        return self.queue.dispatched_batches

    @property
    def dispatched_requests(self) -> int:
        return self.queue.dispatched_requests

    def on_arrival(self, request: Request, now: float) -> None:
        """Handle one request arrival (lines 5–20 of Algorithm 1)."""
        self.queue.expire(now)  # dead requests must not count toward Max_BS
        if self.queue.queue_len:
            # A pending timeout exists; arrival cancels and recomputes it.
            self.queue.next_deadline = None
        self.queue.append(request, now)

        max_bs = max(1, self.max_bs_fn())
        pack = self.config.pack_buckets
        if pack is None:
            if self.queue.queue_len >= max_bs:
                self.queue._dispatch(now, cause="full")
                return
        else:
            # Bucket-aware packing: round Max_BS up to the next engine
            # bucket edge and dispatch exactly at it — a "full" batch then
            # executes with zero padding, and the monitor's RT95[bucket]
            # keying means the timeout math already prices the edge.
            target = bucket_of(max_bs, pack)
            while self.queue.queue_len >= target:
                if self.queue._dispatch(now, cause="full",
                                        limit=target) is None:
                    break
            if not self.queue.queue_len:
                return

        # DTO = SLO − RT95[N_q + 1]; probing one size larger guards against
        # the latency of the batch after one more arrival (paper eq. 1).
        est = self.monitor.upstream_percentile(self.queue.queue_len + 1, now)
        dto = self.config.sla.slo_target - est - self.config.dispatch_overhead
        to = dto - self.queue.frt(now)
        if to <= 0:
            # Negative timeout: the queue is already at risk → dispatch now.
            self.queue._dispatch(now, cause="timeout")
        else:
            self.queue.next_deadline = now + to

    def on_timer(self, now: float) -> None:
        """Fire the dispatch timeout if due (lines 21–24 of Algorithm 1).

        The expiry sweep runs first: a timer may have been armed for a
        request expiry rather than a dispatch deadline."""
        self.queue.expire(now)
        if self.queue.next_deadline is None or now + 1e-12 < self.queue.next_deadline:
            return
        if self.queue.queue_len:
            self.queue._dispatch(now, cause="timeout")
        else:  # stale timer
            self.queue.next_deadline = None

    def flush(self, now: float) -> None:
        """Dispatch whatever is queued (shutdown / checkpoint barrier)."""
        if self.queue.queue_len:
            self.queue._dispatch(now, cause="flush")

    # ------------------------------------------------------ fault tolerance
    def snapshot(self) -> dict:
        return self.queue.snapshot()

    def restore(self, state: dict) -> None:
        self.queue.restore(state)
