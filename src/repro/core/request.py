"""Request / batch data types shared by the proxy, simulator and engine."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, List, Optional

_req_counter = itertools.count()


def _next_req_id() -> int:
    return next(_req_counter)


def reset_request_ids() -> None:
    """Restart the process-global request-id sequence (test seam).

    Request ids record allocation order, not randomness: without a reset,
    two same-seed runs in one process draw disjoint id ranges, which is
    the one thing standing between their span logs and byte-identity.
    Never call this while requests from a previous run are still live.
    """
    global _req_counter
    _req_counter = itertools.count()


@dataclasses.dataclass(slots=True)
class Request:
    """One inference request as seen by the proxy.

    ``slots=True``: requests are created once per simulated arrival — on
    million-request runs the per-instance dict is measurable in both time
    and memory on the event-core hot path.
    """

    arrival_time: float
    payload: Any = None
    req_id: int = dataclasses.field(default_factory=_next_req_id)
    # Routing key used by the multi-endpoint frontend (None on the
    # single-endpoint path).
    endpoint: Optional[str] = None
    # Absolute completion deadline (same clock as ``arrival_time``).
    # Client-supplied, or derived at admission from the endpoint's
    # ``SLAConfig.deadline_factor``; ``None`` = no deadline. A request
    # still queued past its deadline is evicted by the BatchQueue expiry
    # sweep and ends in the ``timed_out`` terminal state.
    deadline: Optional[float] = None
    timed_out: bool = False
    # Filled in on completion:
    dispatch_time: Optional[float] = None
    completion_time: Optional[float] = None

    @property
    def e2e_latency(self) -> Optional[float]:
        if self.completion_time is None:
            return None
        return self.completion_time - self.arrival_time

    @property
    def queue_time(self) -> Optional[float]:
        if self.dispatch_time is None:
            return None
        return self.dispatch_time - self.arrival_time

    def remaining_budget(self, now: float) -> Optional[float]:
        """Seconds until the deadline (negative if past); None if no deadline."""
        if self.deadline is None:
            return None
        return self.deadline - now


@dataclasses.dataclass(slots=True)
class Batch:
    """A dispatched batch of requests."""

    requests: List[Request]
    dispatch_time: float
    cause: str  # 'full' | 'timeout' | 'flush'
    bucket_size: Optional[int] = None  # padded size on fixed-shape backends
    # Stamped by the frontend so shared dispatch targets (and shared
    # platforms) know which endpoint's model a batch belongs to.
    endpoint: Optional[str] = None
    # Fleet tier chosen by the SpilloverRouter at dispatch time (None on
    # single-fleet paths). TieredPlatform / TieredTarget use it to pick
    # the per-tier backend; EndpointRoutedLatency keys on it too.
    tier: Optional[str] = None
    # Stamped by the platform on completion: how many dispatch attempts
    # (crash retries + hedges) this batch took before it finished. The
    # monitor uses it for retry-aware upstream statistics.
    attempts: int = 1
    # Span id stamped by a tracing BatchQueue at dispatch (-1 = untraced);
    # correlates retry/hedge/terminal events in the drivers back to the
    # ``dispatched`` event and its member ``batched`` events.
    trace_id: int = -1

    @property
    def size(self) -> int:
        return len(self.requests)

    @property
    def effective_size(self) -> int:
        return self.bucket_size if self.bucket_size is not None else self.size

    @property
    def oldest_arrival(self) -> float:
        return min(r.arrival_time for r in self.requests)

    @property
    def tightest_deadline(self) -> Optional[float]:
        """Earliest member deadline — what the dispatch path propagates
        upstream (None when no member carries a deadline)."""
        deadline: Optional[float] = None
        for r in self.requests:
            if r.deadline is not None and (deadline is None or r.deadline < deadline):
                deadline = r.deadline
        return deadline

    def complete(self, completion_time: float) -> None:
        for r in self.requests:
            r.completion_time = completion_time
