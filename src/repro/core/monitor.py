"""Smart Monitor — sliding-window latency statistics keyed by batch size.

Implements the paper's monitoring component (§2.2): for every batch size the
proxy has dispatched, keep a sliding window of upstream response times and
expose the windowed 95th percentile (``RT95[bs]``); additionally keep a
window of end-to-end response times (queueing + proxy + upstream) used by
the AIMD optimizer for SLO-compliance decisions.

Beyond the paper, three estimator back-ends are provided (see
``MonitorConfig.estimator``): the paper-faithful per-size windowed
percentile, a robust linear regression over the populated windows (used as
the fallback for batch sizes never observed — the paper is silent on this
cold-start case), and a P² streaming quantile with O(1) memory per size.
"""
from __future__ import annotations

import bisect
import collections
import math
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.config import MonitorConfig, SLAConfig
from repro.obs.burnrate import BurnRateMeter
from repro.obs.metrics import MetricsRegistry


class LatencyWindow:
    """Sliding window of (timestamp, latency) with lazy horizon eviction.

    Percentile queries run off a lazily-built sorted cache that is kept
    incrementally consistent: ``add`` insorts the new sample (removing the
    one the bounded deque evicts), horizon eviction removes aged samples,
    and the winsorized rank is computed with bisection on the cache — so
    the window is sorted once, not on every ``percentile`` call (the
    scheduler queries it on every arrival).
    """

    __slots__ = ("maxlen", "horizon", "_buf", "_sorted")

    def __init__(self, maxlen: int, horizon: float) -> None:
        self.maxlen = maxlen
        self.horizon = horizon
        self._buf: Deque[Tuple[float, float]] = collections.deque(maxlen=maxlen)
        self._sorted: Optional[List[float]] = None  # built on first query

    def add(self, now: float, latency: float) -> None:
        srt = self._sorted
        if srt is not None:
            if len(self._buf) == self.maxlen:  # deque evicts its oldest
                del srt[bisect.bisect_left(srt, self._buf[0][1])]
            bisect.insort(srt, latency)
        self._buf.append((now, latency))

    def _evict(self, now: float) -> None:
        cutoff = now - self.horizon
        buf = self._buf
        srt = self._sorted
        while buf and buf[0][0] < cutoff:
            _, v = buf.popleft()
            if srt is not None:
                del srt[bisect.bisect_left(srt, v)]

    def _sorted_values(self) -> List[float]:
        if self._sorted is None:
            self._sorted = sorted(v for (_, v) in self._buf)
        return self._sorted

    def __len__(self) -> int:
        return len(self._buf)

    def count(self, now: Optional[float] = None) -> int:
        """Number of in-horizon samples (no list materialization)."""
        if now is not None:
            self._evict(now)
        return len(self._buf)

    def values(self, now: Optional[float] = None) -> List[float]:
        if now is not None:
            self._evict(now)
        return [v for (_, v) in self._buf]

    def percentile(self, q: float, now: Optional[float] = None,
                   outlier_mult: float = 0.0) -> Optional[float]:
        """Empirical percentile (nearest-rank, higher interpolation).

        ``outlier_mult > 0`` winsorizes: samples above ``outlier_mult ×
        median`` are dropped before ranking (robustness to cold-start
        storms; see MonitorConfig.outlier_mult).
        """
        if now is not None:
            self._evict(now)
        vals = self._sorted_values()
        n = len(vals)
        if not n:
            return None
        if outlier_mult > 0 and n >= 4:
            # kept == vals[:k] because vals is sorted; no list rebuild
            k = bisect.bisect_right(vals, outlier_mult * vals[n // 2])
            if k > 0:
                n = k
        # Higher interpolation keeps the estimate conservative for SLOs.
        rank = min(n - 1, max(0, math.ceil(q / 100.0 * n) - 1))
        return vals[rank]

    def mean(self, now: Optional[float] = None) -> Optional[float]:
        vals = self.values(now)
        return sum(vals) / len(vals) if vals else None

    def snapshot(self) -> dict:
        return {"maxlen": self.maxlen, "horizon": self.horizon, "buf": list(self._buf)}

    @classmethod
    def restore(cls, state: dict) -> "LatencyWindow":
        w = cls(state["maxlen"], state["horizon"])
        w._buf.extend(state["buf"])
        return w


class P2Quantile:
    """P² streaming quantile estimator (Jain & Chlamtac, 1985).

    O(1) memory per tracked quantile; used as an optional back-end for
    very high-rate endpoints where keeping windows is wasteful.
    """

    __slots__ = ("p", "n", "q", "npos", "dn", "_init")

    def __init__(self, p: float) -> None:
        if not 0 < p < 1:
            raise ValueError("p must be in (0,1)")
        self.p = p
        self._init: List[float] = []
        self.n: List[int] = []
        self.q: List[float] = []
        self.npos: List[float] = []
        self.dn: List[float] = []

    def add(self, x: float) -> None:
        if len(self._init) < 5:
            bisect.insort(self._init, x)
            if len(self._init) == 5:
                self.q = list(self._init)
                self.n = [1, 2, 3, 4, 5]
                p = self.p
                self.npos = [1, 1 + 2 * p, 1 + 4 * p, 3 + 2 * p, 5]
                self.dn = [0, p / 2, p, (1 + p) / 2, 1]
            return
        q, n, npos = self.q, self.n, self.npos
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            for i in range(1, 4):
                if x < q[i]:
                    k = i - 1
                    break
            else:
                k = 3
        for i in range(k + 1, 5):
            n[i] += 1
        for i in range(5):
            npos[i] += self.dn[i]
        for i in range(1, 4):
            d = npos[i] - n[i]
            if (d >= 1 and n[i + 1] - n[i] > 1) or (d <= -1 and n[i - 1] - n[i] < -1):
                d = 1 if d >= 0 else -1
                # parabolic prediction
                qi = q[i] + d / (n[i + 1] - n[i - 1]) * (
                    (n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1])
                )
                if not (q[i - 1] < qi < q[i + 1]):
                    # linear fallback
                    qi = q[i] + d * (q[i + d] - q[i]) / (n[i + d] - n[i])
                q[i] = qi
                n[i] += d

    @property
    def count(self) -> int:
        return self.n[4] if self.n else len(self._init)

    def value(self) -> Optional[float]:
        if self.q:
            return self.q[2]
        if self._init:
            # not enough samples for markers: empirical on what we have
            k = max(0, math.ceil(self.p * len(self._init)) - 1)
            return self._init[k]
        return None

    def snapshot(self) -> dict:
        return {
            "p": self.p,
            "init": list(self._init),
            "n": list(self.n),
            "q": list(self.q),
            "npos": list(self.npos),
            "dn": list(self.dn),
        }

    @classmethod
    def restore(cls, state: dict) -> "P2Quantile":
        est = cls(state["p"])
        est._init = list(state["init"])
        est.n = list(state["n"])
        est.q = list(state["q"])
        est.npos = list(state["npos"])
        est.dn = list(state["dn"])
        return est


def _theil_sen_fit(points: List[Tuple[float, float]]) -> Tuple[float, float]:
    """Robust line fit (median of pairwise slopes). Returns (a, b): y≈a+b·x."""
    if len(points) == 1:
        return points[0][1], 0.0
    slopes = []
    for i in range(len(points)):
        for j in range(i + 1, len(points)):
            (x0, y0), (x1, y1) = points[i], points[j]
            if x1 != x0:
                slopes.append((y1 - y0) / (x1 - x0))
    if not slopes:
        ys = [y for _, y in points]
        return sorted(ys)[len(ys) // 2], 0.0
    slopes.sort()
    b = slopes[len(slopes) // 2]
    residuals = sorted(y - b * x for x, y in points)
    a = residuals[len(residuals) // 2]
    return a, b


class SmartMonitor:
    """Latency statistics provider for the scheduler and AIMD optimizer.

    Responsibilities (paper §2.2):
      * per-batch-size sliding windows of upstream response times →
        ``upstream_percentile(bs)`` (the scheduler's ``RT95[N_q+1]``);
      * sliding window of end-to-end response times → ``e2e_percentile()``;
      * dispatch-cause accounting over the current optimizer interval →
        ``timeout_ratio()``.
    """

    def __init__(self, config: MonitorConfig, sla: SLAConfig) -> None:
        self.config = config
        self.sla = sla
        self._upstream: Dict[int, LatencyWindow] = {}
        self._p2: Dict[int, P2Quantile] = {}
        self._e2e = LatencyWindow(config.window_size * 4, config.e2e_horizon)
        # dispatch-cause counters for the *current* optimizer interval
        self._timeout_dispatches = 0
        self._total_dispatches = 0
        # Lifetime counters, migrated onto typed obs Counters in an owned
        # MetricsRegistry. The `lifetime_*` read surface is preserved as
        # properties below; snapshot/restore keeps the historical tuple
        # format so old snapshots load unchanged.
        self.metrics = MetricsRegistry()
        c = self.metrics.counter
        self._c_dispatches = c("lifetime_dispatches")
        self._c_requests = c("lifetime_requests")
        self._c_violations = c("lifetime_violations")
        # retry-aware upstream accounting (platform-side crash retries and
        # hedges, reported via Batch.attempts)
        self._c_upstream_batches = c("lifetime_upstream_batches")
        self._c_upstream_attempts = c("lifetime_upstream_attempts")
        self._c_retried_batches = c("lifetime_retried_batches")
        # failed dispatch attempts (target raised / injected fault); they
        # never enter the latency windows — there is no completion latency
        # to learn from — but they feed failure_rate()
        self._c_failed_attempts = c("lifetime_failed_attempts")
        # padding accounting on bucketed backends: a dispatch of n requests
        # into a bucket of size b occupies b slots, b - n of them padding
        self._c_dispatched_slots = c("lifetime_dispatched_slots")
        self._c_padded_slots = c("lifetime_padded_slots")
        # SLO burn-rate meter fed by every end-to-end completion: the
        # windowed violation rate over the SLA's error budget, on a fast
        # and a slow window (SRE-style multi-window burn alerting).
        self.burn = BurnRateMeter.for_percentile(
            sla.percentile,
            fast_window=config.burn_fast_window,
            slow_window=config.burn_slow_window)

    # ------------------------------------------------- lifetime read surface
    @property
    def lifetime_dispatches(self) -> int:
        return self._c_dispatches.value

    @property
    def lifetime_requests(self) -> int:
        return self._c_requests.value

    @property
    def lifetime_violations(self) -> int:
        return self._c_violations.value

    @property
    def lifetime_upstream_batches(self) -> int:
        return self._c_upstream_batches.value

    @property
    def lifetime_upstream_attempts(self) -> int:
        return self._c_upstream_attempts.value

    @property
    def lifetime_retried_batches(self) -> int:
        return self._c_retried_batches.value

    @property
    def lifetime_failed_attempts(self) -> int:
        return self._c_failed_attempts.value

    @property
    def lifetime_dispatched_slots(self) -> int:
        return self._c_dispatched_slots.value

    @property
    def lifetime_padded_slots(self) -> int:
        return self._c_padded_slots.value

    def register_metrics(self, registry: MetricsRegistry,
                         prefix: str = "monitor") -> None:
        """Bind this monitor's counters into an external registry.

        Aggregators (the live server, sims) call this with a per-endpoint
        prefix so one registry exposes every endpoint's monitor."""
        for name in self.metrics.names():
            counter = self.metrics.counter(name)
            registry.bind(f"{prefix}.{name}",
                          lambda c=counter: c.value)
        registry.bind(f"{prefix}.interval_timeout_dispatches",
                      lambda: self._timeout_dispatches)
        registry.bind(f"{prefix}.interval_dispatches",
                      lambda: self._total_dispatches)
        registry.bind(f"{prefix}.burn_samples", lambda: self.burn.total)
        registry.bind(f"{prefix}.burn_violations",
                      lambda: self.burn.violations)

    # ---------------------------------------------------------------- record
    def record_upstream(self, batch_size: int, latency: float, now: float,
                        attempts: int = 1) -> None:
        """Record one upstream batch completion.

        ``attempts`` is how many platform-side dispatches (crash retries +
        hedges) the batch took; values > 1 feed the retry-aware counters
        surfaced in :meth:`stats` plumbing (``retry_rate``).
        """
        if batch_size <= 0:
            raise ValueError("batch_size must be >= 1")
        self._c_upstream_batches.inc()
        self._c_upstream_attempts.inc(max(1, attempts))
        if attempts > 1:
            self._c_retried_batches.inc()
        win = self._upstream.get(batch_size)
        if win is None:
            win = LatencyWindow(self.config.window_size, self.config.window_horizon)
            self._upstream[batch_size] = win
        win.add(now, latency)
        if self.config.estimator == "p2":
            est = self._p2.get(batch_size)
            if est is None:
                est = P2Quantile(self.sla.percentile / 100.0)
                self._p2[batch_size] = est
            est.add(latency)

    def record_failure(self, batch_size: int, now: float) -> None:
        """Record one FAILED upstream dispatch attempt.

        The attempt produced no completion latency, so nothing enters the
        per-size windows; only the failure counter moves. ``batch_size``
        and ``now`` mirror :meth:`record_upstream`'s signature for callers
        that treat the two symmetrically (and for future per-size failure
        tracking).
        """
        del batch_size, now
        self._c_failed_attempts.inc()

    def record_e2e(self, latency: float, now: float) -> None:
        """Record one end-to-end (user-observed) response time."""
        self._e2e.add(now, latency)
        self._c_requests.inc()
        violated = latency > self.sla.slo_target
        if violated:
            self._c_violations.inc()
        self.burn.record(now, violated)

    def record_dispatch(self, batch_size: int, cause: str,
                        effective_size: Optional[int] = None) -> None:
        """cause ∈ {'full', 'timeout', 'flush'}.

        ``effective_size`` is the padded bucket the batch executes as on
        fixed-shape backends (defaults to ``batch_size``: no padding);
        the gap feeds the padding-waste counters.
        """
        self._total_dispatches += 1
        self._c_dispatches.inc()
        if cause == "timeout":
            self._timeout_dispatches += 1
        eff = effective_size if effective_size is not None else batch_size
        self._c_dispatched_slots.inc(eff)
        self._c_padded_slots.inc(max(0, eff - batch_size))

    # -------------------------------------------------------------- estimate
    def upstream_percentile(self, batch_size: int, now: float) -> float:
        """Estimated upstream latency percentile for ``batch_size``.

        Paper-faithful path: the windowed empirical percentile for that
        exact batch size. Cold-start/fallback: robust regression over the
        percentiles of every populated window (so unseen sizes interpolate /
        extrapolate sensibly); before *any* observation, an optimistic
        default that makes the scheduler batch until data arrives.
        """
        cfg = self.config
        if cfg.estimator == "p2":
            est = self._p2.get(batch_size)
            if est is not None and est.count >= cfg.min_samples:
                v = est.value()
                if v is not None:
                    return v
        else:
            win = self._upstream.get(batch_size)
            if win is not None and win.count(now) >= cfg.min_samples:
                # count(now) already evicted: query without re-evicting
                v = win.percentile(self.sla.percentile,
                                   outlier_mult=cfg.outlier_mult)
                if v is not None:
                    return v
        return self._regression_estimate(batch_size, now)

    def _regression_estimate(self, batch_size: int, now: float) -> float:
        points: List[Tuple[float, float]] = []
        for bs, win in self._upstream.items():
            if win.count(now) > 0:
                p = win.percentile(self.sla.percentile, now)
                if p is not None:
                    points.append((float(bs), p))
        if not points:
            return self.config.optimistic_default
        if len(points) == 1:
            # single observed size: assume flat (sub-linear optimism); the
            # AIMD loop corrects any resulting violation.
            return points[0][1]
        a, b = _theil_sen_fit(points)
        est = a + b * batch_size
        # Extrapolation floor: never negative, and never below half the
        # cheapest observed percentile — a downhill fit extrapolated far
        # past the data must not promise near-free large batches.
        lo = min(y for _, y in points)
        return max(est, 0.5 * lo, 0.0)

    def bucket_quantile(self, batch_size: int, q: float, now: float,
                        min_samples: int = 1) -> Optional[float]:
        """Raw windowed quantile of one bucket's upstream latency.

        Unlike :meth:`upstream_percentile` this never falls back to the
        regression estimate and never winsorizes — it is the straggler
        detector behind proxy-tier hedging, where the tail *is* the
        signal. Returns None until the bucket has ``min_samples``
        in-horizon observations (hedging stays off while cold).
        """
        win = self._upstream.get(batch_size)
        if win is None or win.count(now) < max(1, min_samples):
            return None
        return win.percentile(q)

    def e2e_percentile(self, now: float) -> Optional[float]:
        return self._e2e.percentile(self.sla.percentile, now)

    def e2e_mean(self, now: float) -> Optional[float]:
        return self._e2e.mean(now)

    def timeout_ratio(self) -> float:
        if self._total_dispatches == 0:
            return 0.0
        return self._timeout_dispatches / self._total_dispatches

    def reset_interval(self) -> None:
        """Called by the optimizer at the end of each update interval."""
        self._timeout_dispatches = 0
        self._total_dispatches = 0

    # --------------------------------------------------------------- metrics
    def violation_rate(self) -> float:
        if self.lifetime_requests == 0:
            return 0.0
        return self.lifetime_violations / self.lifetime_requests

    def retry_rate(self) -> float:
        """Fraction of completed upstream batches that needed > 1 attempt."""
        if self.lifetime_upstream_batches == 0:
            return 0.0
        return self.lifetime_retried_batches / self.lifetime_upstream_batches

    def failure_rate(self) -> float:
        """Fraction of all upstream dispatch attempts that failed."""
        total = self.lifetime_upstream_attempts + self.lifetime_failed_attempts
        if total == 0:
            return 0.0
        return self.lifetime_failed_attempts / total

    def padding_waste(self) -> float:
        """Lifetime fraction of dispatched bucket slots that were padding."""
        if self.lifetime_dispatched_slots == 0:
            return 0.0
        return self.lifetime_padded_slots / self.lifetime_dispatched_slots

    def observed_batch_sizes(self) -> List[int]:
        return sorted(self._upstream)

    # ------------------------------------------------------- fault tolerance
    def snapshot(self) -> dict:
        return {
            "upstream": {bs: w.snapshot() for bs, w in self._upstream.items()},
            "p2": {bs: e.snapshot() for bs, e in self._p2.items()},
            "e2e": self._e2e.snapshot(),
            "timeout_dispatches": self._timeout_dispatches,
            "total_dispatches": self._total_dispatches,
            "lifetime": (
                self.lifetime_dispatches,
                self.lifetime_requests,
                self.lifetime_violations,
            ),
            "lifetime_upstream": (
                self.lifetime_upstream_batches,
                self.lifetime_upstream_attempts,
                self.lifetime_retried_batches,
            ),
            "lifetime_failed_attempts": self.lifetime_failed_attempts,
            "lifetime_padding": (
                self.lifetime_dispatched_slots,
                self.lifetime_padded_slots,
            ),
            "burn": self.burn.snapshot(),
        }

    def restore(self, state: dict) -> None:
        self._upstream = {
            int(bs): LatencyWindow.restore(s) for bs, s in state["upstream"].items()
        }
        self._p2 = {int(bs): P2Quantile.restore(s) for bs, s in state["p2"].items()}
        self._e2e = LatencyWindow.restore(state["e2e"])
        self._timeout_dispatches = state["timeout_dispatches"]
        self._total_dispatches = state["total_dispatches"]
        # The historical tuple formats predate the typed-counter migration;
        # they remain the canonical snapshot encoding so old snapshots load.
        (
            self._c_dispatches.value,
            self._c_requests.value,
            self._c_violations.value,
        ) = state["lifetime"]
        (
            self._c_upstream_batches.value,
            self._c_upstream_attempts.value,
            self._c_retried_batches.value,
        ) = state.get("lifetime_upstream", (0, 0, 0))
        # pre-fault-tolerance snapshots carry no failure accounting
        self._c_failed_attempts.value = state.get("lifetime_failed_attempts", 0)
        (
            self._c_dispatched_slots.value,
            self._c_padded_slots.value,
        ) = state.get("lifetime_padding", (0, 0))
        # pre-obs snapshots carry no burn-meter state (restore() with an
        # empty dict resets the meter)
        self.burn.restore(state.get("burn", {}))
