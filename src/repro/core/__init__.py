"""MLProxy core — the paper's contribution as a composable library.

Public surface, organized as three layers:
  * **Queue layer** — :class:`~repro.core.batch_queue.BatchQueue` (the one
    shared queue/dispatch primitive) and the
    :class:`~repro.core.batch_queue.Policy` protocol every policy
    implements.
  * **Policy layer** — :class:`~repro.core.proxy.MLProxy` (the adaptive
    reverse proxy) and the baselines in :mod:`repro.core.policies`.
  * **Routing layer** — :class:`~repro.core.frontend.ProxyFrontend`, which
    multiplexes N named endpoints (each with its own policy + SLA) behind
    one merged timer.

Configuration lives in :class:`~repro.core.config.ProxyConfig` /
``SLAConfig`` / ``MonitorConfig`` / ``OptimizerConfig``;
:mod:`repro.core.jax_controller` holds the fleet-scale vectorized
controller.
"""
from repro.core.batch_queue import BatchQueue, Policy  # noqa: F401
from repro.core.config import (  # noqa: F401
    MonitorConfig,
    OptimizerConfig,
    ProxyConfig,
    SLAConfig,
    bucket_of,
    ms,
)
from repro.core.frontend import Endpoint, ProxyFrontend  # noqa: F401
from repro.core.monitor import LatencyWindow, P2Quantile, SmartMonitor  # noqa: F401
from repro.core.optimizer import AIMDBatchOptimizer  # noqa: F401
from repro.core.proxy import MLProxy  # noqa: F401
from repro.core.request import Batch, Request  # noqa: F401
from repro.core.scheduler import QueueScheduler  # noqa: F401
