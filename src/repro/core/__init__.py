"""MLProxy core — the paper's contribution as a composable library.

Public surface:
  * :class:`~repro.core.proxy.MLProxy` — the adaptive reverse proxy.
  * :class:`~repro.core.config.ProxyConfig` / ``SLAConfig`` /
    ``MonitorConfig`` / ``OptimizerConfig`` — configuration.
  * :mod:`repro.core.policies` — baseline policies for comparison.
  * :mod:`repro.core.jax_controller` — fleet-scale vectorized controller.
"""
from repro.core.config import (  # noqa: F401
    MonitorConfig,
    OptimizerConfig,
    ProxyConfig,
    SLAConfig,
    bucket_of,
    ms,
)
from repro.core.monitor import LatencyWindow, P2Quantile, SmartMonitor  # noqa: F401
from repro.core.optimizer import AIMDBatchOptimizer  # noqa: F401
from repro.core.proxy import MLProxy  # noqa: F401
from repro.core.request import Batch, Request  # noqa: F401
from repro.core.scheduler import QueueScheduler  # noqa: F401
