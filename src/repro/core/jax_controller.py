"""Vectorized, jittable MLProxy control plane for fleet-scale deployments.

Beyond the paper: a cloud provider shipping MLProxy "as part of their API
Gateway offering" (paper §6) hosts *thousands* of endpoints. Running one
Python object per endpoint is fine at paper scale (one endpoint); at fleet
scale the control decisions themselves become a throughput problem. This
module re-expresses the two MLProxy decision loops as pure JAX functions
over struct-of-arrays state, so a single jitted call advances *all*
endpoints at once:

* :func:`aimd_step` — Algorithm 2 for N endpoints (one fused vector op).
* :func:`timeout_step` — Algorithm 1's DTO/TO computation for N endpoints.
* latency statistics as fixed-size ring buffers per (endpoint, bucket) with
  a masked percentile — the sliding window of the Smart Monitor, kept in
  device memory.

All functions are `jax.jit`-compatible and pure; the host loop owns the
event plumbing and calls these at tick granularity. Property tests assert
equivalence with the scalar Python implementation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FleetState:
    """Struct-of-arrays control state for N endpoints × B batch-size buckets.

    Shapes:
      max_bs:        (N,) float — AIMD batch-size state (raw, ≥ 1).
      ring:          (N, B, W) float — latency samples per bucket (NaN = empty).
      ring_pos:      (N, B) int32 — next write slot per ring.
      e2e_ring:      (N, We) float — end-to-end latency samples (NaN = empty).
      e2e_pos:       (N,) int32.
      to_count:      (N,) int32 — timeout dispatches this interval.
      disp_count:    (N,) int32 — total dispatches this interval.
    """

    max_bs: jax.Array
    ring: jax.Array
    ring_pos: jax.Array
    e2e_ring: jax.Array
    e2e_pos: jax.Array
    to_count: jax.Array
    disp_count: jax.Array


def init_fleet(n_endpoints: int, n_buckets: int, window: int = 64,
               e2e_window: int = 256, initial_max_bs: float = 1.0) -> FleetState:
    return FleetState(
        max_bs=jnp.full((n_endpoints,), initial_max_bs, jnp.float32),
        ring=jnp.full((n_endpoints, n_buckets, window), jnp.nan, jnp.float32),
        ring_pos=jnp.zeros((n_endpoints, n_buckets), jnp.int32),
        e2e_ring=jnp.full((n_endpoints, e2e_window), jnp.nan, jnp.float32),
        e2e_pos=jnp.zeros((n_endpoints,), jnp.int32),
        to_count=jnp.zeros((n_endpoints,), jnp.int32),
        disp_count=jnp.zeros((n_endpoints,), jnp.int32),
    )


def _masked_percentile(x: jax.Array, q: float) -> jax.Array:
    """Percentile over the non-NaN suffix of the trailing axis.

    Empty windows yield NaN (callers treat NaN as "no estimate"). Uses a
    sort with NaNs pushed to the end and a per-row nearest-rank gather —
    O(W log W) on-device, no host sync.
    """
    sorted_x = jnp.sort(x, axis=-1)  # NaNs sort to the end
    count = jnp.sum(~jnp.isnan(x), axis=-1)
    rank = jnp.ceil(q / 100.0 * count).astype(jnp.int32) - 1
    rank = jnp.clip(rank, 0, x.shape[-1] - 1)
    picked = jnp.take_along_axis(sorted_x, rank[..., None], axis=-1)[..., 0]
    return jnp.where(count > 0, picked, jnp.nan)


@functools.partial(jax.jit, static_argnames=("percentile",))
def record_upstream(state: FleetState, endpoint: jax.Array, bucket: jax.Array,
                    latency: jax.Array, percentile: float = 95.0) -> FleetState:
    """Scatter a batch of (endpoint, bucket, latency) observations."""
    w = state.ring.shape[-1]
    pos = state.ring_pos[endpoint, bucket]
    ring = state.ring.at[endpoint, bucket, pos].set(latency)
    ring_pos = state.ring_pos.at[endpoint, bucket].set((pos + 1) % w)
    return dataclasses.replace(state, ring=ring, ring_pos=ring_pos)


@jax.jit
def record_e2e(state: FleetState, endpoint: jax.Array, latency: jax.Array) -> FleetState:
    w = state.e2e_ring.shape[-1]
    pos = state.e2e_pos[endpoint]
    ring = state.e2e_ring.at[endpoint, pos].set(latency)
    e2e_pos = state.e2e_pos.at[endpoint].set((pos + 1) % w)
    return dataclasses.replace(state, e2e_ring=ring, e2e_pos=e2e_pos)


@jax.jit
def record_dispatch(state: FleetState, endpoint: jax.Array,
                    was_timeout: jax.Array) -> FleetState:
    disp = state.disp_count.at[endpoint].add(1)
    to = state.to_count.at[endpoint].add(was_timeout.astype(jnp.int32))
    return dataclasses.replace(state, disp_count=disp, to_count=to)


@functools.partial(jax.jit, static_argnames=("percentile",))
def timeout_step(state: FleetState, queue_len: jax.Array, frt: jax.Array,
                 slo: jax.Array, percentile: float = 95.0,
                 ) -> Tuple[jax.Array, jax.Array]:
    """Algorithm 1's decision for all N endpoints at once.

    Args:
      queue_len: (N,) int32 current queue sizes (N_q).
      frt: (N,) seconds since each endpoint's oldest queued request.
      slo: (N,) SLO targets.
    Returns:
      (dispatch_now, timeout): (N,) bool — dispatch immediately;
      (N,) float — relative timeout for endpoints not dispatching now.
    """
    n, b, _ = state.ring.shape
    # RT95 for batch one larger than the queue; bucket index clips at B-1.
    probe = jnp.clip(queue_len, 0, b - 1)  # bucket of N_q+1 (precomputed map)
    est = _masked_percentile(state.ring[jnp.arange(n), probe, :], percentile)
    # Fallback for empty windows: max over *all* buckets' percentiles (a
    # conservative stand-in for the regression fallback; NaN → optimistic 0).
    per_bucket = _masked_percentile(state.ring, percentile)  # (N, B)
    fallback = jnp.nanmax(
        jnp.where(jnp.isnan(per_bucket), -jnp.inf, per_bucket), axis=-1
    )
    fallback = jnp.where(jnp.isfinite(fallback), fallback, 0.0)
    est = jnp.where(jnp.isnan(est), fallback, est)
    dto = slo - est
    to = dto - frt
    dispatch_now = (to <= 0.0) & (queue_len > 0)
    full = queue_len >= jnp.maximum(1.0, jnp.floor(state.max_bs))
    return dispatch_now | full, jnp.maximum(to, 0.0)


@functools.partial(jax.jit, static_argnames=("percentile",))
def aimd_step(state: FleetState, slo: jax.Array, *, to_thresh: float = 0.9,
              compliance_factor: float = 0.8, inc_step: float = 1.0,
              dec_mult: float = 0.8, max_cap: float = 256.0,
              percentile: float = 95.0) -> FleetState:
    """Algorithm 2 for all N endpoints (one fused update + interval reset)."""
    rt = _masked_percentile(state.e2e_ring, percentile)  # (N,)
    to_ratio = jnp.where(
        state.disp_count > 0, state.to_count / jnp.maximum(state.disp_count, 1), 0.0
    )
    rt_violation = jnp.where(jnp.isnan(rt), False, rt > compliance_factor * slo)
    violation = (to_ratio > to_thresh) | rt_violation
    new_bs = jnp.where(
        violation,
        jnp.maximum(1.0, state.max_bs * dec_mult),
        jnp.minimum(max_cap, state.max_bs + inc_step),
    )
    return dataclasses.replace(
        state,
        max_bs=new_bs,
        to_count=jnp.zeros_like(state.to_count),
        disp_count=jnp.zeros_like(state.disp_count),
    )


def effective_max_bs(state: FleetState) -> jax.Array:
    return jnp.maximum(1, jnp.floor(state.max_bs).astype(jnp.int32))
