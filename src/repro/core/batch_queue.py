"""Shared batch-queue primitive and the formal ``Policy`` protocol.

Every batching policy in this repo — :class:`~repro.core.proxy.MLProxy`'s
queue scheduler and all four baselines in :mod:`repro.core.policies` —
needs the same machinery underneath its decision logic: a FIFO of pending
requests, the first-arrival (FRT) reference point, a single pending dispatch
deadline, pow2 bucketing for fixed-shape backends, dispatch counters, and
snapshot/restore of all of the above. That machinery used to be duplicated
between ``QueueScheduler._dispatch`` and ``BatchingPolicy._dispatch``;
:class:`BatchQueue` is the one shared implementation.

A policy *decides* (target batch size, timeout); the queue *executes*
(accumulate, stamp, bucket, count, hand off). The split keeps every policy
down to its decision logic and makes the dispatch path change in exactly
one place.

:class:`Policy` is the event-driven surface the routing layer
(:mod:`repro.core.frontend`), the simulator, and the serving engine program
against. It is a :func:`typing.runtime_checkable` protocol so conformance
is testable without inheritance.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Callable, List, Optional, Protocol, \
    runtime_checkable

from repro.core.config import bucket_of
from repro.core.monitor import SmartMonitor
from repro.core.request import Batch, Request

if TYPE_CHECKING:  # imported lazily so the core stays obs-optional
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.trace import Tracer

DispatchFn = Callable[[Batch], None]
#: Called with (expired_requests, now) whenever the expiry sweep evicts
#: already-dead requests from the queue — the hook the live runtime uses
#: to resolve their tickets with a DeadlineExceeded result.
ExpireFn = Callable[[List[Request], float], None]

#: Epsilon for "deadline has passed" checks, mirroring the timer-fire
#: epsilon in the policies: a timer that wakes a float-ulp before the
#: deadline must still count the request as expired.
_EXPIRY_EPS = 1e-12


@runtime_checkable
class Policy(Protocol):
    """Event-driven batching-policy surface (clock-free: callers pass ``now``).

    Implementations: :class:`~repro.core.proxy.MLProxy` and every baseline in
    :mod:`repro.core.policies`. The simulator, the serving loop, and
    :class:`~repro.core.frontend.ProxyFrontend` only ever touch this surface,
    so policies are freely swappable per endpoint.
    """

    def on_request(self, request: Request, now: float) -> None:
        """Handle one arrival; may dispatch synchronously."""

    def on_response(self, batch: Batch, upstream_latency: float, now: float) -> None:
        """Record a completed upstream batch; completes member requests."""

    def on_timer(self, now: float) -> None:
        """Fire due timeouts / periodic updates."""

    def expire(self, now: float) -> List[Request]:
        """Evict queued requests whose deadline has passed; returns them.

        O(1) when nothing is expirable — safe to call on admission paths
        (e.g. before a queue-depth check counts dead requests)."""
        ...

    def shed(self, now: float, keep: int) -> List[Request]:
        """Evict queued requests beyond ``keep``, lowest deadline slack
        first (brownout load shedding); returns the evicted list."""
        ...

    def next_event_time(self, now: float) -> Optional[float]:
        """Earliest future time at which :meth:`on_timer` must run."""

    def flush(self, now: float) -> None:
        """Dispatch whatever is queued (shutdown / checkpoint barrier)."""

    def stats(self, now: float) -> dict:
        """Point-in-time metrics (max_bs, queue_len, violation_rate, ...)."""

    def snapshot(self) -> dict:
        """Serializable control-plane state (crash/restart resumes warm)."""

    def restore(self, state: dict) -> None:
        """Inverse of :meth:`snapshot`."""

    @property
    def max_bs(self) -> int:
        """Current target batch size."""
        ...

    @property
    def queue_len(self) -> int:
        """Pending requests queued (O(1); cheaper than ``stats()``)."""
        ...


class BatchQueue:
    """The shared queue/dispatch/bucketing/snapshot core under every policy.

    Holds pending requests plus the two pieces of timing state every policy
    needs — the oldest-arrival reference (``first_arrival``, the paper's FRT
    anchor) and the single pending dispatch deadline (``next_deadline``) —
    and owns the one ``_dispatch`` implementation: stamp dispatch times,
    apply bucketing, reset state, bump counters, notify the monitor, hand
    the batch to ``dispatch_fn``.
    """

    def __init__(
        self,
        dispatch_fn: DispatchFn,
        monitor: Optional[SmartMonitor] = None,
        bucketing: Optional[str] = None,
        expire_fn: Optional[ExpireFn] = None,
        tracer: Optional["Tracer"] = None,
    ) -> None:
        self.dispatch_fn = dispatch_fn
        self.monitor = monitor
        self.bucketing = bucketing
        self.expire_fn = expire_fn
        # Lifecycle span tracer (repro.obs.trace). None (the default)
        # means every emission site below is a single attribute check —
        # tracing off must cost nothing and perturb nothing.
        self.tracer = tracer
        self._queue: List[Request] = []
        self.first_arrival: Optional[float] = None
        self.next_deadline: Optional[float] = None
        self.dispatched_batches = 0
        self.dispatched_requests = 0
        self.expired_requests = 0
        self.shed_requests = 0
        # Deepest the queue has ever been (admission-time high-water mark).
        self.queue_depth_hwm = 0
        # Deadline bookkeeping for the hot path: how many queued requests
        # carry a deadline, and the earliest of them. Deadline-free
        # workloads (the default) pay one integer check per sweep; with
        # deadlines on, both the sweep and ``next_expiry`` are O(1)
        # unless something actually expires.
        self._deadline_count = 0
        self._min_deadline: Optional[float] = None

    # ------------------------------------------------------------------ api
    def __len__(self) -> int:
        return len(self._queue)

    @property
    def queue_len(self) -> int:
        return len(self._queue)

    def append(self, request: Request, now: float) -> None:
        """Enqueue one request; anchors ``first_arrival`` on an empty queue."""
        if not self._queue:
            self.first_arrival = now
        self._queue.append(request)
        if len(self._queue) > self.queue_depth_hwm:
            self.queue_depth_hwm = len(self._queue)
        # Deliberately no span event here: a per-arrival emission would
        # dominate the tracing-on overhead budget, and the queue-entry
        # instant is recoverable — the "batched"/"expired"/"shed" event
        # that resolves this request carries ``arrival_time`` in its
        # value field, so exporters reconstruct the queue-wait span
        # without a hot-path event.
        if request.deadline is not None:
            self._deadline_count += 1
            if (self._min_deadline is None
                    or request.deadline < self._min_deadline):
                self._min_deadline = request.deadline

    def frt(self, now: float) -> float:
        """Age of the oldest queued request (0 when empty)."""
        if self.first_arrival is None:
            return 0.0
        return now - self.first_arrival

    # --------------------------------------------------------------- expiry
    def expire(self, now: float) -> List[Request]:
        """Evict queued requests whose deadline has already passed.

        Expired requests are marked ``timed_out`` (terminal state), counted
        in ``expired_requests``, and handed to ``expire_fn`` so the owner
        (live server, simulator) can resolve them; they are never batched,
        dispatched, or billed. Returns the evicted list (often empty).
        """
        cutoff = now + _EXPIRY_EPS
        if self._min_deadline is None or self._min_deadline > cutoff:
            return []  # O(1): nothing queued can have expired yet
        expired = [r for r in self._queue
                   if r.deadline is not None and r.deadline <= cutoff]
        if not expired:
            return expired
        self._queue = [r for r in self._queue
                       if r.deadline is None or r.deadline > cutoff]
        self._deadline_count -= len(expired)
        self._min_deadline = min(
            (r.deadline for r in self._queue if r.deadline is not None),
            default=None,
        )
        self.expired_requests += len(expired)
        for r in expired:
            r.timed_out = True
        if self.tracer is not None:
            for r in expired:
                self.tracer.emit(now, "expired", r.endpoint or "",
                                 r.req_id, -1, 0, r.arrival_time)
        if self._queue:
            # FIFO order: the head of the surviving queue is the oldest;
            # re-anchor FRT on its arrival instant.
            self.first_arrival = self._queue[0].arrival_time
        else:
            self.first_arrival = None
            self.next_deadline = None
        if self.expire_fn is not None:
            self.expire_fn(expired, now)
        return expired

    def shed(self, now: float, keep: int) -> List[Request]:
        """Evict queued requests beyond ``keep``, lowest slack first.

        Brownout shedding: when an endpoint's circuit breaker opens, the
        requests least likely to survive the outage are dropped first —
        the ones with the smallest remaining deadline slack. Deadline-free
        requests have infinite slack, so they shed last (newest first,
        preserving the oldest requests' place at the head of the FIFO).

        Shed requests are counted in ``shed_requests`` and returned to the
        caller for ticket resolution; ``expire_fn`` is NOT invoked —
        shedding is an admission-control decision, not a deadline expiry
        (the two are distinct ledger classes).
        """
        # slack ordering reduces to deadline ordering (same `now`); `now`
        # is only used to timestamp shed span events
        excess = len(self._queue) - max(0, keep)
        if excess <= 0:
            return []
        order = sorted(
            range(len(self._queue)),
            key=lambda i: (
                (1, 0.0, -i) if self._queue[i].deadline is None
                else (0, self._queue[i].deadline, -i)
            ),
        )
        victims = set(order[:excess])
        evicted = [self._queue[i] for i in order[:excess]]
        self._queue = [r for i, r in enumerate(self._queue)
                       if i not in victims]
        self.shed_requests += len(evicted)
        if self.tracer is not None:
            for r in evicted:
                self.tracer.emit(now, "shed", r.endpoint or "",
                                 r.req_id, -1, 0, r.arrival_time)
        deadlines = [r.deadline for r in self._queue if r.deadline is not None]
        self._deadline_count = len(deadlines)
        self._min_deadline = min(deadlines, default=None)
        if self._queue:
            # FIFO order: the head of the surviving queue is the oldest
            self.first_arrival = self._queue[0].arrival_time
        else:
            self.first_arrival = None
            self.next_deadline = None
        return evicted

    def next_expiry(self) -> Optional[float]:
        """Earliest queued deadline (None when no queued request has one)."""
        return self._min_deadline

    def next_event_time(self) -> Optional[float]:
        """Merged wake-up: the earlier of the dispatch deadline and the
        earliest request expiry (what every policy's ``next_event_time``
        must report so the shared timer wakes for expiries too)."""
        deadline = self.next_deadline
        expiry = self._min_deadline
        if deadline is None:
            return expiry
        if expiry is None:
            return deadline
        return min(deadline, expiry)

    def _dispatch(self, now: float, cause: str,
                  limit: Optional[int] = None) -> Optional[Batch]:
        """Dispatch the queue (or its first ``limit`` requests) as one batch.
        The only implementation.

        Already-expired requests are evicted *before* batch formation; if
        that empties the queue there is nothing to dispatch and ``None``
        is returned (state already reset by the sweep). ``limit`` is how
        bucket-aware packing dispatches exactly at a bucket edge: the head
        of the FIFO goes out, the tail stays queued with its FRT anchor
        re-anchored on the new oldest request.
        """
        if self._deadline_count:
            self.expire(now)
            if not self._queue:
                return None
        if limit is not None and 0 < limit < len(self._queue):
            head, tail = self._queue[:limit], self._queue[limit:]
        else:
            head, tail = self._queue, []
        batch = Batch(requests=head, dispatch_time=now, cause=cause)
        if self.bucketing is not None:
            batch.bucket_size = bucket_of(batch.size, self.bucketing)
        for r in batch.requests:
            r.dispatch_time = now
        self._queue = tail
        self.next_deadline = None
        if tail:
            # FIFO order: the head of the surviving queue is the oldest
            self.first_arrival = tail[0].arrival_time
            deadlines = [r.deadline for r in tail if r.deadline is not None]
            self._deadline_count = len(deadlines)
            self._min_deadline = min(deadlines, default=None)
        else:
            self.first_arrival = None
            self._deadline_count = 0
            self._min_deadline = None
        self.dispatched_batches += 1
        self.dispatched_requests += batch.size
        if self.monitor is not None:
            self.monitor.record_dispatch(batch.size, cause,
                                         effective_size=batch.effective_size)
        tracer = self.tracer
        if tracer is not None:
            bid = batch.trace_id = tracer.next_batch_id()
            reqs = batch.requests
            ep = reqs[0].endpoint or "" if reqs else ""
            # inlined tracer.emit (see Tracer docstring): this is the
            # hottest emission site on the decision path. Membership is
            # packed columnar — ONE "batched" event per batch whose req
            # slot holds the member-id tuple and whose value slot holds
            # the matching arrival-time tuple — because per-member
            # events are what blow the ≤10% tracing-on overhead budget:
            # the retained ring allocations, not the emit calls, are the
            # measured cost. The ring evicts oldest-first on its own
            # (deque maxlen); drops are accounted up front, which is
            # exactly what per-event checks would have counted.
            buf = tracer.buf
            overflow = len(buf) + 2 - tracer.capacity
            if overflow > 0:
                tracer.dropped += overflow
            buf.append((now, "dispatched", ep, -1, bid, batch.size, 0.0,
                        cause))
            buf.append((now, "batched", ep,
                        tuple([r.req_id for r in reqs]), bid, batch.size,
                        tuple([r.arrival_time for r in reqs]), ""))
        self.dispatch_fn(batch)
        return batch

    @property
    def avg_batch_size(self) -> float:
        return (self.dispatched_requests / self.dispatched_batches
                if self.dispatched_batches else 0.0)

    def stats(self, monitor: SmartMonitor, now: float, *,
              max_bs: int, max_bs_raw: float) -> dict:
        """The one canonical per-policy stats dict.

        Every policy's ``stats()`` delegates here, so the key set cannot
        drift between MLProxy and the baselines (regression-tested in
        the stats-parity tests)."""
        burn = monitor.burn.rates(now)
        return {
            "max_bs": max_bs,
            "max_bs_raw": max_bs_raw,
            "queue_len": self.queue_len,
            "queue_depth_hwm": self.queue_depth_hwm,
            "dispatched_batches": self.dispatched_batches,
            "dispatched_requests": self.dispatched_requests,
            "avg_batch_size": self.avg_batch_size,
            "expired": self.expired_requests,
            "shed": self.shed_requests,
            "e2e_p": monitor.e2e_percentile(now),
            "violation_rate": monitor.violation_rate(),
            "timeout_ratio": monitor.timeout_ratio(),
            "upstream_batches": monitor.lifetime_upstream_batches,
            "upstream_attempts": monitor.lifetime_upstream_attempts,
            "retried_batches": monitor.lifetime_retried_batches,
            "retry_rate": monitor.retry_rate(),
            "failed_attempts": monitor.lifetime_failed_attempts,
            "failure_rate": monitor.failure_rate(),
            "dispatched_slots": monitor.lifetime_dispatched_slots,
            "padded_slots": monitor.lifetime_padded_slots,
            "padding_waste": monitor.padding_waste(),
            "burn_rate_fast": burn["burn_rate_fast"],
            "burn_rate_slow": burn["burn_rate_slow"],
        }

    # -------------------------------------------------------------- metrics
    def register_metrics(self, registry: "MetricsRegistry",
                         prefix: str = "queue") -> None:
        """Bind this queue's ledger counters into a MetricsRegistry."""
        registry.bind(f"{prefix}.dispatched_batches",
                      lambda: self.dispatched_batches)
        registry.bind(f"{prefix}.dispatched_requests",
                      lambda: self.dispatched_requests)
        registry.bind(f"{prefix}.expired_requests",
                      lambda: self.expired_requests)
        registry.bind(f"{prefix}.shed_requests", lambda: self.shed_requests)
        registry.bind(f"{prefix}.depth", lambda: len(self._queue))
        registry.bind(f"{prefix}.depth_hwm", lambda: self.queue_depth_hwm)

    # ------------------------------------------------------ fault tolerance
    def snapshot(self) -> dict:
        return {
            "queue": list(self._queue),
            "first_arrival": self.first_arrival,
            "next_deadline": self.next_deadline,
            "dispatched_batches": self.dispatched_batches,
            "dispatched_requests": self.dispatched_requests,
            "expired_requests": self.expired_requests,
            "shed_requests": self.shed_requests,
            "queue_depth_hwm": self.queue_depth_hwm,
        }

    def restore(self, state: dict) -> None:
        self._queue = list(state["queue"])
        self.first_arrival = state["first_arrival"]
        self.next_deadline = state["next_deadline"]
        self.dispatched_batches = state["dispatched_batches"]
        self.dispatched_requests = state["dispatched_requests"]
        # pre-deadline snapshots carry no expiry state; pre-brownout
        # snapshots carry no shed accounting; pre-obs snapshots carry no
        # high-water mark
        self.expired_requests = state.get("expired_requests", 0)
        self.shed_requests = state.get("shed_requests", 0)
        self.queue_depth_hwm = state.get("queue_depth_hwm", len(self._queue))
        deadlines = [r.deadline for r in self._queue if r.deadline is not None]
        self._deadline_count = len(deadlines)
        self._min_deadline = min(deadlines, default=None)
