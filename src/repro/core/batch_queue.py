"""Shared batch-queue primitive and the formal ``Policy`` protocol.

Every batching policy in this repo — :class:`~repro.core.proxy.MLProxy`'s
queue scheduler and all four baselines in :mod:`repro.core.policies` —
needs the same machinery underneath its decision logic: a FIFO of pending
requests, the first-arrival (FRT) reference point, a single pending dispatch
deadline, pow2 bucketing for fixed-shape backends, dispatch counters, and
snapshot/restore of all of the above. That machinery used to be duplicated
between ``QueueScheduler._dispatch`` and ``BatchingPolicy._dispatch``;
:class:`BatchQueue` is the one shared implementation.

A policy *decides* (target batch size, timeout); the queue *executes*
(accumulate, stamp, bucket, count, hand off). The split keeps every policy
down to its decision logic and makes the dispatch path change in exactly
one place.

:class:`Policy` is the event-driven surface the routing layer
(:mod:`repro.core.frontend`), the simulator, and the serving engine program
against. It is a :func:`typing.runtime_checkable` protocol so conformance
is testable without inheritance.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Protocol, runtime_checkable

from repro.core.config import bucket_of
from repro.core.monitor import SmartMonitor
from repro.core.request import Batch, Request

DispatchFn = Callable[[Batch], None]


@runtime_checkable
class Policy(Protocol):
    """Event-driven batching-policy surface (clock-free: callers pass ``now``).

    Implementations: :class:`~repro.core.proxy.MLProxy` and every baseline in
    :mod:`repro.core.policies`. The simulator, the serving loop, and
    :class:`~repro.core.frontend.ProxyFrontend` only ever touch this surface,
    so policies are freely swappable per endpoint.
    """

    def on_request(self, request: Request, now: float) -> None:
        """Handle one arrival; may dispatch synchronously."""

    def on_response(self, batch: Batch, upstream_latency: float, now: float) -> None:
        """Record a completed upstream batch; completes member requests."""

    def on_timer(self, now: float) -> None:
        """Fire due timeouts / periodic updates."""

    def next_event_time(self, now: float) -> Optional[float]:
        """Earliest future time at which :meth:`on_timer` must run."""

    def flush(self, now: float) -> None:
        """Dispatch whatever is queued (shutdown / checkpoint barrier)."""

    def stats(self, now: float) -> dict:
        """Point-in-time metrics (max_bs, queue_len, violation_rate, ...)."""

    def snapshot(self) -> dict:
        """Serializable control-plane state (crash/restart resumes warm)."""

    def restore(self, state: dict) -> None:
        """Inverse of :meth:`snapshot`."""

    @property
    def max_bs(self) -> int:
        """Current target batch size."""
        ...

    @property
    def queue_len(self) -> int:
        """Pending requests queued (O(1); cheaper than ``stats()``)."""
        ...


class BatchQueue:
    """The shared queue/dispatch/bucketing/snapshot core under every policy.

    Holds pending requests plus the two pieces of timing state every policy
    needs — the oldest-arrival reference (``first_arrival``, the paper's FRT
    anchor) and the single pending dispatch deadline (``next_deadline``) —
    and owns the one ``_dispatch`` implementation: stamp dispatch times,
    apply bucketing, reset state, bump counters, notify the monitor, hand
    the batch to ``dispatch_fn``.
    """

    def __init__(
        self,
        dispatch_fn: DispatchFn,
        monitor: Optional[SmartMonitor] = None,
        bucketing: Optional[str] = None,
    ) -> None:
        self.dispatch_fn = dispatch_fn
        self.monitor = monitor
        self.bucketing = bucketing
        self._queue: List[Request] = []
        self.first_arrival: Optional[float] = None
        self.next_deadline: Optional[float] = None
        self.dispatched_batches = 0
        self.dispatched_requests = 0

    # ------------------------------------------------------------------ api
    def __len__(self) -> int:
        return len(self._queue)

    @property
    def queue_len(self) -> int:
        return len(self._queue)

    def append(self, request: Request, now: float) -> None:
        """Enqueue one request; anchors ``first_arrival`` on an empty queue."""
        if not self._queue:
            self.first_arrival = now
        self._queue.append(request)

    def frt(self, now: float) -> float:
        """Age of the oldest queued request (0 when empty)."""
        if self.first_arrival is None:
            return 0.0
        return now - self.first_arrival

    def _dispatch(self, now: float, cause: str) -> Batch:
        """Dispatch the entire queue as one batch. The only implementation."""
        batch = Batch(requests=self._queue, dispatch_time=now, cause=cause)
        if self.bucketing is not None:
            batch.bucket_size = bucket_of(batch.size, self.bucketing)
        for r in batch.requests:
            r.dispatch_time = now
        self._queue = []
        self.first_arrival = None
        self.next_deadline = None
        self.dispatched_batches += 1
        self.dispatched_requests += batch.size
        if self.monitor is not None:
            self.monitor.record_dispatch(batch.size, cause)
        self.dispatch_fn(batch)
        return batch

    @property
    def avg_batch_size(self) -> float:
        return (self.dispatched_requests / self.dispatched_batches
                if self.dispatched_batches else 0.0)

    # ------------------------------------------------------ fault tolerance
    def snapshot(self) -> dict:
        return {
            "queue": list(self._queue),
            "first_arrival": self.first_arrival,
            "next_deadline": self.next_deadline,
            "dispatched_batches": self.dispatched_batches,
            "dispatched_requests": self.dispatched_requests,
        }

    def restore(self, state: dict) -> None:
        self._queue = list(state["queue"])
        self.first_arrival = state["first_arrival"]
        self.next_deadline = state["next_deadline"]
        self.dispatched_batches = state["dispatched_batches"]
        self.dispatched_requests = state["dispatched_requests"]
