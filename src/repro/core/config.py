"""Configuration objects for the MLProxy control plane.

All times are seconds (floats). The paper expresses SLOs in milliseconds;
callers may use :func:`ms` for readability.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union


def ms(x: float) -> float:
    """Milliseconds → seconds."""
    return x / 1000.0


@dataclasses.dataclass(frozen=True)
class SLAConfig:
    """Service-level objective for one endpoint.

    Attributes:
      slo_target: response-time target in seconds (the paper's ``RT_SLO``).
      percentile: which latency percentile the SLO constrains (paper: 95).
      compliance_factor: internal threshold as a fraction of ``slo_target``
        used by the AIMD optimizer to trigger multiplicative decrease
        *before* the SLO itself is violated (paper: 0.8).
      deadline_factor: per-request completion deadline as a multiple of
        ``slo_target``. When set, every admitted request without a
        client-supplied ``Request.deadline`` gets ``arrival +
        slo_target × deadline_factor``; requests still queued past their
        deadline are evicted (``timed_out``) instead of being batched,
        dispatched and billed. ``None`` (default) disables deadlines.
    """

    slo_target: float
    percentile: float = 95.0
    compliance_factor: float = 0.8
    deadline_factor: Optional[float] = None

    def __post_init__(self) -> None:
        if self.slo_target <= 0:
            raise ValueError(f"slo_target must be > 0, got {self.slo_target}")
        if not 0 < self.percentile <= 100:
            raise ValueError(f"percentile must be in (0, 100], got {self.percentile}")
        if not 0 < self.compliance_factor <= 1:
            raise ValueError(
                f"compliance_factor must be in (0, 1], got {self.compliance_factor}"
            )
        if self.deadline_factor is not None and self.deadline_factor <= 0:
            raise ValueError(
                f"deadline_factor must be > 0 or None, got {self.deadline_factor}"
            )

    @property
    def compliance_target(self) -> float:
        """The latency threshold the optimizer actually steers to."""
        return self.slo_target * self.compliance_factor

    @property
    def deadline_budget(self) -> Optional[float]:
        """Per-request deadline budget in seconds (None = no deadline)."""
        if self.deadline_factor is None:
            return None
        return self.slo_target * self.deadline_factor


@dataclasses.dataclass(frozen=True)
class MonitorConfig:
    """Smart Monitor configuration.

    Attributes:
      window_size: max samples retained per batch-size latency window.
      window_horizon: max age (seconds) of samples used in estimates; older
        samples are dropped lazily (the paper's "sliding window").
      estimator: upstream-latency estimator for unseen batch sizes:
        ``"window"``  — paper-faithful: windowed empirical percentile for the
                        exact batch size, falling back to ``"regression"``
                        when the window for that size is empty;
        ``"regression"`` — robust linear fit ``a + b·bs`` over the percentile
                        of every populated window (beyond paper);
        ``"p2"``      — P² streaming quantile per batch size (O(1) memory,
                        beyond paper).
      min_samples: minimum samples in a window before its percentile is
        trusted (below this the fallback estimator is used).
      optimistic_default: latency (seconds) assumed for batch size 1 before
        any observation exists. A small value makes the scheduler batch
        aggressively until real data arrives; the first completions correct
        it.
      outlier_mult: beyond paper — samples greater than ``outlier_mult ×
        window median`` are excluded from the percentile estimate. Cold
        starts and platform queueing storms otherwise poison RT95 for a
        full window horizon, driving DTO ≤ 0 and disabling batching right
        when batching would absorb the burst. 0 disables (paper-faithful
        raw percentile).
      burn_fast_window / burn_slow_window: window lengths (seconds) of the
        SLO burn-rate meter fed by every end-to-end completion (see
        :mod:`repro.obs.burnrate`). The fast window catches sharp
        regressions, the slow window confirms them; ``burn_rate_fast`` /
        ``burn_rate_slow`` surface through every stats path.
    """

    window_size: int = 256
    window_horizon: float = 120.0
    # End-to-end RT window horizon: short, so that a transient platform
    # storm stops dominating the compliance signal within ~2 optimizer
    # intervals ("we use a sliding window to only use the latest response
    # time values", paper §2.2).
    e2e_horizon: float = 60.0
    estimator: str = "window"
    min_samples: int = 3
    optimistic_default: float = 0.0
    outlier_mult: float = 5.0
    burn_fast_window: float = 60.0
    burn_slow_window: float = 600.0

    def __post_init__(self) -> None:
        if self.estimator not in ("window", "regression", "p2"):
            raise ValueError(f"unknown estimator {self.estimator!r}")
        if self.window_size < 8:
            raise ValueError("window_size must be >= 8")
        if not 0 < self.burn_fast_window <= self.burn_slow_window:
            raise ValueError(
                "need 0 < burn_fast_window <= burn_slow_window, got "
                f"{self.burn_fast_window}/{self.burn_slow_window}")


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    """Algorithm 2 (AIMD dynamic batch optimizer) configuration.

    Paper defaults: ``inc_step = 1``, ``dec_mult = 0.8``, evaluated every
    30 seconds; a violation is (timeout-dispatch ratio > ``to_thresh``) or
    (observed RT percentile > compliance threshold).
    """

    inc_step: float = 1.0
    dec_mult: float = 0.8
    update_interval: float = 30.0
    # Fraction of timeout-dispatched batches tolerated before Max_BS is
    # considered "too large for the current arrival rate" (paper §2.4; the
    # paper does not publish its value). At moderate rates timeout dispatch
    # is the NORMAL mode — Max_BS self-regulates through the RT-compliance
    # signal instead — so the threshold must be high; 0.5 pins Max_BS at 1
    # and forfeits all batching (validated in EXPERIMENTS.md §Table-3).
    to_thresh: float = 0.9
    initial_max_bs: float = 1.0
    max_bs_cap: int = 256
    min_bs: int = 1

    def __post_init__(self) -> None:
        if not 0 < self.dec_mult < 1:
            raise ValueError("dec_mult must be in (0, 1)")
        if self.inc_step <= 0:
            raise ValueError("inc_step must be > 0")
        if self.max_bs_cap < self.min_bs:
            raise ValueError("max_bs_cap must be >= min_bs")


@dataclasses.dataclass(frozen=True)
class ProxyConfig:
    """Top-level MLProxy configuration for one endpoint."""

    sla: SLAConfig
    monitor: MonitorConfig = dataclasses.field(default_factory=MonitorConfig)
    optimizer: OptimizerConfig = dataclasses.field(default_factory=OptimizerConfig)
    # Safety margin subtracted from every dispatch timeout to cover proxy
    # overhead (serialization, queue hop). The paper folds this into the
    # upstream latency estimate; we expose it explicitly.
    dispatch_overhead: float = 0.0
    # Batch-size bucketing for fixed-shape accelerators (beyond paper —
    # TPU adaptation). ``None`` disables; ``"pow2"`` rounds dispatch sizes
    # up to powers of two and keys monitor windows by bucket; an explicit
    # ascending tuple of bucket sizes (the engine's ``batch_buckets``)
    # rounds up within the tuple and clamps above its largest entry.
    bucketing: Union[None, str, Tuple[int, ...]] = None
    # Bucket-aware batch packing: when set to the engine's batch buckets,
    # the scheduler's full-trigger rounds Max_BS up to the next bucket
    # edge and dispatches exactly at it. Latency within a bucket is the
    # padded bucket's latency (the monitor keys by it), so topping a
    # forming batch up to the edge is free throughput — the extra
    # requests ride in slots that would otherwise be padding. ``None``
    # disables (dispatch at the raw Max_BS). Setting ``pack_buckets``
    # without ``bucketing`` implies ``bucketing = pack_buckets``.
    pack_buckets: Optional[Tuple[int, ...]] = None

    def __post_init__(self) -> None:
        if isinstance(self.bucketing, (tuple, list)):
            object.__setattr__(self, "bucketing",
                               validate_buckets(self.bucketing, "bucketing"))
        elif self.bucketing not in (None, "pow2"):
            raise ValueError(f"unknown bucketing {self.bucketing!r}")
        if self.pack_buckets is not None:
            object.__setattr__(
                self, "pack_buckets",
                validate_buckets(self.pack_buckets, "pack_buckets"))
            if self.bucketing is None:
                object.__setattr__(self, "bucketing", self.pack_buckets)


def validate_buckets(buckets, what: str = "buckets") -> Tuple[int, ...]:
    """Normalize an explicit bucket tuple: ints, positive, ascending."""
    out = tuple(int(b) for b in buckets)
    if not out:
        raise ValueError(f"{what} must be non-empty")
    if any(b <= 0 for b in out) or any(
            a >= b for a, b in zip(out, out[1:])):
        raise ValueError(f"{what} must be positive and ascending, got {out}")
    return out


def bucket_of(batch_size: int,
              scheme: Union[None, str, Tuple[int, ...]]) -> int:
    """Map a raw batch size to its compiled bucket under ``scheme``.

    ``scheme`` may be None (identity), ``"pow2"``, or an explicit
    ascending tuple of bucket sizes; with a tuple, sizes above the
    largest bucket clamp to it (the dispatch path chunks them).
    """
    if scheme is None or batch_size <= 1:
        return batch_size
    if isinstance(scheme, tuple):
        for b in scheme:
            if batch_size <= b:
                return b
        return scheme[-1]
    if scheme == "pow2":
        return 1 << (batch_size - 1).bit_length()
    raise ValueError(f"unknown bucketing {scheme!r}")
