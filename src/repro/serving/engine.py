"""JAX inference engine — the data plane MLProxy fronts on TPU.

Fixed-shape compiled programs make batch-size *bucketing* mandatory on
XLA backends: the engine compiles ``prefill``/``decode_step`` once per
(batch-bucket, prompt-bucket) and pads incoming batches up to the bucket.
This is the TPU-native adaptation of the paper (DESIGN.md §2): the proxy's
monitor keys its latency windows by the padded bucket size, which is the
size whose latency the next dispatch decision must predict.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model


def next_bucket(n: int, buckets: Sequence[int], clamp: bool = False) -> int:
    """Smallest bucket holding ``n`` requests.

    ``clamp=True`` returns the largest bucket for oversized ``n`` instead
    of raising — for *estimation* paths (monitor latency queries, mean
    lookups) that must stay total even when a policy's cap exceeds the
    engine's compiled buckets. Execution paths keep the strict default and
    chunk oversized batches instead (see ``serving/batcher.py``).
    """
    for b in buckets:
        if n <= b:
            return b
    if clamp:
        return buckets[-1]
    raise ValueError(f"batch {n} exceeds largest bucket {buckets[-1]}")


@dataclasses.dataclass
class EngineConfig:
    batch_buckets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)
    prompt_buckets: Tuple[int, ...] = (16, 32, 64, 128)
    max_len: int = 160  # prompt bucket + generation budget
    gen_len: int = 8
    greedy: bool = True


class InferenceEngine:
    """Single-replica engine: bucketed compile cache + prefill/decode loop."""

    def __init__(self, cfg: ModelConfig, engine_cfg: EngineConfig,
                 params: Optional[Any] = None, rng: Optional[jax.Array] = None):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.model = Model(cfg)
        if params is None:
            params = self.model.init(rng if rng is not None else jax.random.PRNGKey(0))
        self.params = params
        self._prefill_cache: Dict[Tuple[int, int], Any] = {}
        self._decode_cache: Dict[int, Any] = {}
        self.compile_count = 0
        self.stats: Dict[str, float] = {"batches": 0, "requests": 0, "tokens": 0}

    # ------------------------------------------------------------- compiled
    def _prefill_fn(self, bucket: int, plen: int):
        key = (bucket, plen)
        fn = self._prefill_cache.get(key)
        if fn is None:
            model = self.model

            def run(params, tokens, cache):
                return model.prefill(params, tokens, cache)

            fn = jax.jit(run)
            self._prefill_cache[key] = fn
            self.compile_count += 1
        return fn

    def _decode_fn(self, bucket: int):
        fn = self._decode_cache.get(bucket)
        if fn is None:
            model = self.model

            def run(params, tokens, cache):
                logits, cache = model.decode_step(params, tokens, cache)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt[:, None], cache

            fn = jax.jit(run, donate_argnames=("cache",))
            self._decode_cache[bucket] = fn
            self.compile_count += 1
        return fn

    def warmup(self, plen: int = 16) -> None:
        """Precompile every batch bucket (what a replica does at startup)."""
        for b in self.ecfg.batch_buckets:
            prompts = np.zeros((b, plen), np.int32)
            self.generate(prompts, gen_len=1)

    # ------------------------------------------------------------------ api
    def generate(self, prompts: np.ndarray, gen_len: Optional[int] = None,
                 ) -> Tuple[np.ndarray, Dict[str, float]]:
        """Greedy-decode ``gen_len`` tokens for a batch of prompts.

        prompts: (n, plen) int32, n ≤ largest bucket. Returns (tokens
        (n, gen_len), timing dict with wall seconds + bucket metadata).
        """
        gen_len = gen_len if gen_len is not None else self.ecfg.gen_len
        n, plen_raw = prompts.shape
        bucket = next_bucket(n, self.ecfg.batch_buckets)
        plen = next_bucket(plen_raw, self.ecfg.prompt_buckets)
        t0 = time.perf_counter()
        padded = np.zeros((bucket, plen), np.int32)
        padded[:n, plen - plen_raw:] = prompts  # left-pad into the bucket
        tokens = jnp.asarray(padded)

        cache = self.model.init_cache(bucket, self.ecfg.max_len)
        logits, cache = self._prefill_fn(bucket, plen)(self.params, tokens, cache)
        out = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]]
        decode = self._decode_fn(bucket)
        cur = out[0]
        for _ in range(gen_len - 1):
            cur, cache = decode(self.params, cur, cache)
            out.append(cur)
        result = jnp.concatenate(out, axis=1)
        result = jax.device_get(result)[:n]
        dt = time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["requests"] += n
        self.stats["tokens"] += n * gen_len
        return result, {
            "latency_s": dt, "bucket": bucket, "prompt_bucket": plen,
            "padding_waste": (bucket - n) / bucket,
        }


class ReplicaPool:
    """Elastic pool of engine replicas with failover (fault-tolerance shim).

    Replicas share weights (one copy in memory on this host) but have
    independent compile caches and health state, mirroring how a Knative
    deployment schedules independent model servers. ``fail(i)`` marks a
    replica down (its in-flight work is retried elsewhere); ``scale_to``
    adds/removes replicas.
    """

    def __init__(self, cfg: ModelConfig, engine_cfg: EngineConfig,
                 n_replicas: int = 1, rng: Optional[jax.Array] = None):
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self._template = InferenceEngine(cfg, engine_cfg, rng=rng)
        self.cfg = cfg
        self.engine_cfg = engine_cfg
        self.replicas: List[Optional[InferenceEngine]] = []
        self.healthy: List[bool] = []
        self._rr = 0
        self.retries = 0
        self.scale_to(n_replicas)

    def scale_to(self, n: int) -> None:
        """Grow or shrink the pool to exactly ``n`` replicas.

        Shrinking removes the tail replicas outright (freeing their compile
        caches) instead of merely marking them unhealthy — otherwise a later
        scale-up appends fresh replicas while the dead ones keep consuming
        round-robin slots and ``n_healthy`` drifts from the pool size.
        """
        if n < 0:
            raise ValueError(f"replica count must be >= 0, got {n}")
        if n < len(self.replicas):
            del self.replicas[n:]
            del self.healthy[n:]
            self._rr = self._rr % len(self.replicas) if self.replicas else 0
        while len(self.replicas) < n:
            eng = InferenceEngine(self.cfg, self.engine_cfg,
                                  params=self._template.params)
            self.replicas.append(eng)
            self.healthy.append(True)

    @property
    def n_healthy(self) -> int:
        return sum(self.healthy)

    def fail(self, index: int) -> None:
        self.healthy[index] = False

    def recover(self, index: int) -> None:
        self.healthy[index] = True

    def generate(self, prompts: np.ndarray, gen_len: Optional[int] = None):
        """Round-robin dispatch with failover (at-least-once)."""
        if not self.replicas:
            raise RuntimeError("no healthy replicas")
        attempts = 0
        while attempts <= len(self.replicas):
            self._rr = (self._rr + 1) % max(len(self.replicas), 1)
            idx = self._rr
            if not self.healthy[idx]:
                attempts += 1
                continue
            try:
                out, timing = self.replicas[idx].generate(prompts, gen_len)
                timing["replica"] = idx
                return out, timing
            except RuntimeError:
                self.fail(idx)
                self.retries += 1
                attempts += 1
        raise RuntimeError("no healthy replicas")
