"""JAX inference engine — the data plane MLProxy fronts on TPU.

Fixed-shape compiled programs make batch-size *bucketing* mandatory on
XLA backends: the engine compiles ``prefill``/decode once per
(batch-bucket, prompt-bucket) and pads incoming batches up to the bucket.
This is the TPU-native adaptation of the paper (DESIGN.md §2): the proxy's
monitor keys its latency windows by the padded bucket size, which is the
size whose latency the next dispatch decision must predict.

Hot-path layout (the fast data plane):

* **Fused decode** (``EngineConfig.fused_decode``, default on): the whole
  greedy decode loop is one compiled ``lax.scan`` program per
  (batch bucket, step count) — one device dispatch per batch instead of
  ``gen_len`` Python→XLA round-trips. Token outputs are bit-identical to
  the per-token path (greedy argmax over the same logits); set
  ``fused_decode=False`` to get the per-token reference loop.
* **Gen-length bucketing** (``EngineConfig.gen_buckets``): requested
  generation lengths round up to the next configured step bucket, so the
  fused program compiles once per bucket instead of once per distinct
  ``gen_len``. Extra steps are computed and sliced off; outputs for the
  requested length are unchanged (greedy decoding is prefix-stable).
* **Persistent KV-cache pool** (``EngineConfig.cache_pool``, default on):
  ``generate`` checks its cache out of a per-bucket pool and returns it
  afterwards instead of allocating + zero-filling per call. Reuse without
  zero-fill is sound because ``prefill`` overwrites rows ``[0:plen]`` for
  every row of the bucket and resets ``cache["len"]``, and decode
  attention masks positions ``>= cache_len`` — stale rows from a previous
  batch are never attended. Donated cache arguments let XLA recycle the
  buffers in place.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model


def next_bucket(n: int, buckets: Sequence[int], clamp: bool = False) -> int:
    """Smallest bucket holding ``n`` requests.

    ``clamp=True`` returns the largest bucket for oversized ``n`` instead
    of raising — for *estimation* paths (monitor latency queries, mean
    lookups) that must stay total even when a policy's cap exceeds the
    engine's compiled buckets. Execution paths keep the strict default and
    chunk oversized batches instead (see ``serving/batcher.py``).
    """
    for b in buckets:
        if n <= b:
            return b
    if clamp:
        return buckets[-1]
    raise ValueError(f"batch {n} exceeds largest bucket {buckets[-1]}")


#: The sanctioned wall clock for real-measurement code (this module and
#: its adapters). Everything that *models* time must take an injected
#: Clock instead — see ``runtime/clock.py`` and the reprolint
#: ``wallclock`` rule. Measurement modules importing this alias keep the
#: repo's wall-clock references in one greppable seam.
wall_clock = time.monotonic


@dataclasses.dataclass
class EngineConfig:
    batch_buckets: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)
    prompt_buckets: Tuple[int, ...] = (16, 32, 64, 128)
    max_len: int = 160  # prompt bucket + generation budget
    gen_len: int = 8
    greedy: bool = True
    #: Compile the decode loop as one lax.scan program per (batch bucket,
    #: step bucket) instead of dispatching per token. Off = the per-token
    #: reference loop (bit-identical outputs, ~gen_len× more dispatches).
    fused_decode: bool = True
    #: Step buckets for the fused loop: a requested gen_len rounds up to
    #: the next bucket (extra tokens are computed then sliced off), so the
    #: compile cache stays bounded under varying gen_len. None = compile
    #: per distinct requested length.
    gen_buckets: Optional[Tuple[int, ...]] = None
    #: Reuse KV caches across batches via a per-bucket pool instead of
    #: allocating + zero-filling per generate() call.
    cache_pool: bool = True


class InferenceEngine:
    """Single-replica engine: bucketed compile cache + prefill/decode loop."""

    def __init__(self, cfg: ModelConfig, engine_cfg: EngineConfig,
                 params: Optional[Any] = None, rng: Optional[jax.Array] = None):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.model = Model(cfg)
        if params is None:
            params = self.model.init(rng if rng is not None else jax.random.PRNGKey(0))
        self.params = params
        self._prefill_cache: Dict[Tuple[int, int], Any] = {}
        self._decode_cache: Dict[int, Any] = {}
        self._fused_cache: Dict[Tuple[int, int], Any] = {}
        self._kv_pool: Dict[int, Any] = {}
        self.compile_count = 0
        #: KV-cache allocations (pool misses); with the pool on, this
        #: saturates at one per bucket instead of growing per batch.
        self.cache_allocs = 0
        self.stats: Dict[str, float] = {"batches": 0, "requests": 0, "tokens": 0}
        self._in_warmup = False

    # ------------------------------------------------------------- compiled
    def _prefill_fn(self, bucket: int, plen: int):
        key = (bucket, plen)
        fn = self._prefill_cache.get(key)
        if fn is None:
            model = self.model

            def run(params, tokens, cache):
                return model.prefill(params, tokens, cache)

            # The input cache's contents are dead (prefill overwrites the
            # prompt rows and resets the length): donate so XLA writes the
            # new cache into the pooled buffers instead of copying.
            fn = jax.jit(run, donate_argnames=("cache",))
            self._prefill_cache[key] = fn
            self.compile_count += 1
        return fn

    def _decode_fn(self, bucket: int):
        fn = self._decode_cache.get(bucket)
        if fn is None:
            model = self.model

            def run(params, tokens, cache):
                logits, cache = model.decode_step(params, tokens, cache)
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                return nxt[:, None], cache

            fn = jax.jit(run, donate_argnames=("cache",))
            self._decode_cache[bucket] = fn
            self.compile_count += 1
        return fn

    def _fused_fn(self, bucket: int, steps: int):
        """One compiled program running ``steps - 1`` greedy decode steps."""
        key = (bucket, steps)
        fn = self._fused_cache.get(key)
        if fn is None:
            model = self.model

            def run(params, first, cache):
                def body(carry, _):
                    tok, c = carry
                    logits, c = model.decode_step(params, tok, c)
                    nxt = jnp.argmax(logits[:, -1], axis=-1)
                    nxt = nxt.astype(jnp.int32)[:, None]
                    return (nxt, c), nxt

                (_, cache), toks = jax.lax.scan(
                    body, (first, cache), None, length=steps - 1)
                # (steps-1, bucket, 1) → (bucket, steps-1)
                return jnp.swapaxes(toks[..., 0], 0, 1), cache

            fn = jax.jit(run, donate_argnames=("cache",))
            self._fused_cache[key] = fn
            self.compile_count += 1
        return fn

    # ------------------------------------------------------------ kv cache
    def _checkout_cache(self, bucket: int):
        cache = self._kv_pool.pop(bucket, None) if self.ecfg.cache_pool else None
        if cache is None:
            cache = self.model.init_cache(bucket, self.ecfg.max_len)
            self.cache_allocs += 1
        return cache

    def _return_cache(self, bucket: int, cache) -> None:
        if self.ecfg.cache_pool:
            self._kv_pool[bucket] = cache

    def _gen_steps(self, gen_len: int, plen: int) -> int:
        """Total generated tokens the compiled loop produces for ``gen_len``.

        Rounds up to ``gen_buckets`` (bounded compile cache), clamped so
        decode never writes past ``max_len``, and never below the
        requested length.
        """
        steps = gen_len
        if self.ecfg.gen_buckets:
            steps = next_bucket(gen_len, self.ecfg.gen_buckets, clamp=True)
        return max(gen_len, min(steps, self.ecfg.max_len - plen + 1))

    def warmup(self, plen: Optional[int] = None) -> Dict[Tuple[int, int], float]:
        """Precompile the configured buckets (what a replica does at startup).

        Warms every (batch bucket, prompt bucket) pair — or just the pairs
        for one prompt bucket when ``plen`` is given — at the default
        ``gen_len``, priming the prefill/decode compile caches and the KV
        pool. Returns post-compile wall seconds per ``(bucket, plen)``
        pair (each pair is run twice; the first run pays compilation and
        is discarded), the seed material for
        :class:`~repro.serving.batcher.EngineBackedLatency` estimates.

        Warmup traffic is synthetic: serving ``stats`` are not touched.
        """
        plens = ([next_bucket(plen, self.ecfg.prompt_buckets, clamp=True)]
                 if plen is not None else list(self.ecfg.prompt_buckets))
        timings: Dict[Tuple[int, int], float] = {}
        self._in_warmup = True
        try:
            for b in self.ecfg.batch_buckets:
                for p in plens:
                    prompts = np.zeros((b, p), np.int32)
                    self.generate(prompts)  # cold: compiles
                    _, timing = self.generate(prompts)
                    timings[(b, p)] = timing["latency_s"]
        finally:
            self._in_warmup = False
        return timings

    # ------------------------------------------------------------------ api
    def generate(self, prompts: np.ndarray, gen_len: Optional[int] = None,
                 ) -> Tuple[np.ndarray, Dict[str, float]]:
        """Greedy-decode ``gen_len`` tokens for a batch of prompts.

        prompts: (n, plen) int32, n ≤ largest bucket. Returns (tokens
        (n, gen_len), timing dict with wall seconds + bucket metadata).
        """
        gen_len = gen_len if gen_len is not None else self.ecfg.gen_len
        n, plen_raw = prompts.shape
        bucket = next_bucket(n, self.ecfg.batch_buckets)
        plen = next_bucket(plen_raw, self.ecfg.prompt_buckets)
        t0 = time.perf_counter()
        padded = np.zeros((bucket, plen), np.int32)
        padded[:n, plen - plen_raw:] = prompts  # left-pad into the bucket
        tokens = jnp.asarray(padded)

        cache = self._checkout_cache(bucket)
        logits, cache = self._prefill_fn(bucket, plen)(self.params, tokens, cache)
        first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        if self.ecfg.fused_decode and gen_len > 1:
            steps = self._gen_steps(gen_len, plen)
            rest, cache = self._fused_fn(bucket, steps)(self.params, first, cache)
            result = jnp.concatenate([first, rest[:, :gen_len - 1]], axis=1)
        else:
            out = [first]
            decode = self._decode_fn(bucket)
            cur = first
            for _ in range(gen_len - 1):
                cur, cache = decode(self.params, cur, cache)
                out.append(cur)
            result = jnp.concatenate(out, axis=1)
        result = jax.device_get(result)[:n]
        self._return_cache(bucket, cache)
        dt = time.perf_counter() - t0
        if not self._in_warmup:
            self.stats["batches"] += 1
            self.stats["requests"] += n
            self.stats["tokens"] += n * gen_len
        return result, {
            "latency_s": dt, "bucket": bucket, "prompt_bucket": plen,
            "padding_waste": (bucket - n) / bucket,
        }


class ReplicaPool:
    """Elastic pool of engine replicas with failover (fault-tolerance shim).

    Replicas share weights (one copy in memory on this host) but have
    independent compile caches and health state, mirroring how a Knative
    deployment schedules independent model servers. ``fail(i)`` marks a
    replica down (its in-flight work is retried elsewhere); ``scale_to``
    adds/removes replicas.

    Dispatch is **parallel across replicas**: each replica is guarded by
    its own lock (a replica's compile caches and KV pool are not
    thread-safe), and ``generate`` prefers an *idle* healthy replica over
    strict rotation, so concurrent callers overlap on different replicas
    instead of serializing behind one. Synchronization with the device
    happens only at the result boundary (``device_get`` inside the
    replica), so one caller's host-side padding of the next batch overlaps
    another replica's device compute.
    """

    def __init__(self, cfg: ModelConfig, engine_cfg: EngineConfig,
                 n_replicas: int = 1, rng: Optional[jax.Array] = None):
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self._template = InferenceEngine(cfg, engine_cfg, rng=rng)
        self.cfg = cfg
        self.engine_cfg = engine_cfg
        self.replicas: List[Optional[InferenceEngine]] = []
        self.healthy: List[bool] = []
        self._locks: List[threading.Lock] = []
        self._rr = 0
        self.retries = 0
        self.scale_to(n_replicas)

    def scale_to(self, n: int) -> None:
        """Grow or shrink the pool to exactly ``n`` replicas.

        Shrinking removes the tail replicas outright (freeing their compile
        caches) instead of merely marking them unhealthy — otherwise a later
        scale-up appends fresh replicas while the dead ones keep consuming
        round-robin slots and ``n_healthy`` drifts from the pool size.
        """
        if n < 0:
            raise ValueError(f"replica count must be >= 0, got {n}")
        if n < len(self.replicas):
            del self.replicas[n:]
            del self.healthy[n:]
            del self._locks[n:]
            self._rr = self._rr % len(self.replicas) if self.replicas else 0
        while len(self.replicas) < n:
            eng = InferenceEngine(self.cfg, self.engine_cfg,
                                  params=self._template.params)
            self.replicas.append(eng)
            self.healthy.append(True)
            self._locks.append(threading.Lock())

    @property
    def n_healthy(self) -> int:
        return sum(self.healthy)

    def fail(self, index: int) -> None:
        self.healthy[index] = False

    def recover(self, index: int) -> None:
        self.healthy[index] = True

    def warmup(self, plen: Optional[int] = None) -> Dict[Tuple[int, int], float]:
        """Warm every replica; returns the first replica's timings."""
        timings: Dict[Tuple[int, int], float] = {}
        for i, eng in enumerate(self.replicas):
            t = eng.warmup(plen)
            if i == 0:
                timings = t
        return timings

    def _acquire_replica(self) -> Tuple[Optional[int], Optional[threading.Lock]]:
        """Pick a healthy replica and acquire its lock.

        One non-blocking sweep in round-robin order first — an idle
        replica wins immediately, which is what lets concurrent
        dispatches overlap — then a blocking acquire on the
        round-robin-next healthy replica when all are busy. Returns
        (None, None) when no replica is healthy.
        """
        n = len(self.replicas)
        start = self._rr
        for off in range(1, n + 1):
            idx = (start + off) % n
            if not self.healthy[idx]:
                continue
            if self._locks[idx].acquire(blocking=False):
                self._rr = idx
                return idx, self._locks[idx]
        for off in range(1, n + 1):
            idx = (start + off) % n
            if self.healthy[idx]:
                self._rr = idx
                self._locks[idx].acquire()
                return idx, self._locks[idx]
        return None, None

    def generate(self, prompts: np.ndarray, gen_len: Optional[int] = None):
        """Idle-preferring round-robin dispatch with failover (at-least-once)."""
        if not self.replicas:
            raise RuntimeError("no healthy replicas")
        attempts = 0
        while attempts <= len(self.replicas):
            idx, lock = self._acquire_replica()
            if idx is None:
                attempts += 1
                continue
            try:
                out, timing = self.replicas[idx].generate(prompts, gen_len)
                timing["replica"] = idx
                return out, timing
            except RuntimeError:
                self.fail(idx)
                self.retries += 1
                attempts += 1
            finally:
                lock.release()
        raise RuntimeError("no healthy replicas")
