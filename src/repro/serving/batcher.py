"""Glue between MLProxy and the JAX engine.

``EngineBackedLatency`` turns the real engine into a
:class:`~repro.serverless.latency.LatencyModel`: ``sample(batch_size)``
executes a real bucketed prefill+decode on this host and returns measured
wall seconds. Plugging it into the Simulator gives the hybrid loop used by
``examples/serve_engine.py``: simulated arrivals + real MLProxy decisions +
real JAX execution (service times measured, not modeled).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.serverless.latency import LatencyModel
from repro.serving.engine import InferenceEngine, next_bucket


class EngineBackedLatency(LatencyModel):
    """LatencyModel whose samples are real engine executions."""

    name = "engine"
    noise_cv = 0.0  # real wall-clock variation is the noise

    def __init__(self, engine: InferenceEngine, prompt_len: int = 16,
                 gen_len: Optional[int] = None) -> None:
        self.engine = engine
        self.prompt_len = prompt_len
        self.gen_len = gen_len
        self._ema: Dict[int, float] = {}

    def mean(self, batch_size: int) -> float:
        bucket = next_bucket(batch_size, self.engine.ecfg.batch_buckets)
        if bucket in self._ema:
            return self._ema[bucket]
        # never measured: optimistic estimate from the closest known bucket
        known = sorted(self._ema)
        if known:
            return self._ema[known[-1]]
        return 0.0

    def sample(self, batch_size: int, rng: np.random.Generator) -> float:
        prompts = rng.integers(
            0, self.engine.cfg.vocab_size,
            size=(batch_size, self.prompt_len)).astype(np.int32)
        _, timing = self.engine.generate(prompts, gen_len=self.gen_len)
        bucket = timing["bucket"]
        dt = timing["latency_s"]
        prev = self._ema.get(bucket)
        self._ema[bucket] = dt if prev is None else 0.8 * prev + 0.2 * dt
        return dt
