"""Glue between the MLProxy control plane and the JAX engine.

``EngineBackedLatency`` turns the real engine into a
:class:`~repro.serverless.latency.LatencyModel`: ``sample(batch_size)``
executes a real bucketed prefill+decode on this host and returns measured
wall seconds. Plugging it into the Simulator gives the hybrid loop used by
``examples/serve_engine.py``: simulated arrivals + real MLProxy decisions +
real JAX execution (service times measured, not modeled).

``ReplicaPoolTarget`` is the real-serving dispatch target: it adapts a
:class:`~repro.serving.engine.ReplicaPool` to the ``dispatch_fn(batch)``
contract of the shared :class:`~repro.core.batch_queue.BatchQueue`, so a
:class:`~repro.core.frontend.ProxyFrontend` can give each endpoint its own
pool (one model per endpoint) while every policy dispatches through the
same code path.
"""
from __future__ import annotations

from typing import Callable, Dict, Mapping, Optional, Tuple

import numpy as np

from repro.core.request import Batch
from repro.serverless.latency import LatencyModel
from repro.serving.engine import (
    InferenceEngine,
    ReplicaPool,
    next_bucket,
    wall_clock,
)


class EngineBackedLatency(LatencyModel):
    """LatencyModel whose samples are real engine executions.

    Estimates start cold; seed them from warmup timings
    (``seed(engine.warmup())`` or ``warmup=True``) so the first policy
    RT95 probes see realistic per-bucket latency instead of 0.0 — a cold
    0.0 estimate makes the scheduler promise free batches until real
    samples correct it.
    """

    name = "engine"
    noise_cv = 0.0  # real wall-clock variation is the noise

    def __init__(self, engine: InferenceEngine, prompt_len: int = 16,
                 gen_len: Optional[int] = None, warmup: bool = False) -> None:
        self.engine = engine
        self.prompt_len = prompt_len
        self.gen_len = gen_len
        self._ema: Dict[int, float] = {}
        if warmup:
            self.seed(engine.warmup(plen=prompt_len))

    def seed(self, timings: Mapping[Tuple[int, int], float]) -> None:
        """Seed per-bucket EMAs from ``warmup()`` timings.

        ``timings`` maps (batch bucket, prompt bucket) → seconds; for each
        batch bucket the timing of the prompt bucket closest to this
        model's ``prompt_len`` is used. Measured samples keep updating the
        EMA afterwards — the seed only covers the cold window.
        """
        by_bucket: Dict[int, Tuple[int, float]] = {}
        for (bucket, plen), dt in timings.items():
            best = by_bucket.get(bucket)
            dist = abs(plen - self.prompt_len)
            if best is None or dist < best[0]:
                by_bucket[bucket] = (dist, dt)
        for bucket, (_, dt) in by_bucket.items():
            self._ema.setdefault(bucket, dt)

    def mean(self, batch_size: int) -> float:
        # clamp: estimation must stay total for any size the policy may
        # probe (RT95[N_q+1] can exceed the largest compiled bucket); an
        # oversized size executes as sequential largest-bucket chunks, so
        # the estimate carries the same chunk factor sample() pays
        largest = self.engine.ecfg.batch_buckets[-1]
        chunks = max(1, -(-batch_size // largest))
        bucket = next_bucket(batch_size, self.engine.ecfg.batch_buckets,
                             clamp=True)
        if bucket in self._ema:
            return chunks * self._ema[bucket]
        # Never measured: scale the nearest known bucket's EMA by the
        # bucket-size ratio. The old behaviour (largest known EMA,
        # unscaled) under-estimated bigger buckets and over-estimated
        # smaller ones; linear-in-bucket scaling is conservative for
        # sub-linear batching but keeps estimates ordered.
        known = sorted(self._ema)
        if known:
            nearest = min(known, key=lambda b: abs(b - bucket))
            return chunks * self._ema[nearest] * (bucket / nearest)
        return 0.0

    def sample(self, batch_size: int, rng: np.random.Generator) -> float:
        # Oversized sizes execute as sequential largest-bucket chunks —
        # exactly what the dispatch path does — so the sampled latency is
        # the real cost, not a mid-simulation ValueError.
        largest = self.engine.ecfg.batch_buckets[-1]
        total = 0.0
        remaining = batch_size
        while remaining > 0:
            n = min(remaining, largest)
            prompts = rng.integers(
                0, self.engine.cfg.vocab_size,
                size=(n, self.prompt_len)).astype(np.int32)
            _, timing = self.engine.generate(prompts, gen_len=self.gen_len)
            bucket = timing["bucket"]
            dt = timing["latency_s"]
            prev = self._ema.get(bucket)
            self._ema[bucket] = dt if prev is None else 0.8 * prev + 0.2 * dt
            total += dt
            remaining -= n
        return total


class ReplicaPoolTarget:
    """Per-endpoint dispatch target backed by a :class:`ReplicaPool`.

    Callable with the ``dispatch_fn(batch)`` signature the shared
    ``BatchQueue`` expects: builds the prompt array from each request's
    payload (token-id arrays; missing payloads become zero prompts), runs
    the pool with round-robin failover, and reports the measured wall-clock
    back through ``on_done(batch, latency_s, now)`` — typically the owning
    policy's ``on_response`` — closing the monitor feedback loop on real
    hardware.

    ``deadline`` (absolute, on this target's ``clock``) bounds the chunked
    path: once it has passed, remaining chunks are aborted — their
    requests are marked ``timed_out`` with no payload and counted in
    ``timing["deadline_aborted"]`` — instead of burning engine time on
    work nobody is waiting for. The chunk already running is never
    interrupted (a JAX dispatch is not interruptible mid-kernel).
    """

    def __init__(self, pool: ReplicaPool, prompt_len: int = 16,
                 gen_len: Optional[int] = None,
                 on_done: Optional[Callable[[Batch, float, float], None]] = None,
                 clock: Optional[Callable[[], float]] = None) -> None:
        self.pool = pool
        self.prompt_len = prompt_len
        self.gen_len = gen_len
        self.on_done = on_done
        # measurement clock; any deadline passed to __call__ must be
        # absolute on THIS clock (EngineTarget translates runtime-clock
        # deadlines before forwarding — the two epochs differ)
        self.clock = clock if clock is not None else wall_clock
        self.batches = 0
        self.requests = 0
        #: requests whose chunk was never executed because the batch
        #: deadline passed mid-way through the chunked path
        self.deadline_aborted = 0

    def _prompts(self, batch: Batch) -> np.ndarray:
        prompts = np.zeros((batch.size, self.prompt_len), np.int32)
        for i, req in enumerate(batch.requests):
            if req.payload is None:
                continue
            # keep the LAST prompt_len tokens: with left-padding the engine
            # continues from the trailing context, not the prompt's head
            toks = np.asarray(req.payload, np.int32).ravel()[-self.prompt_len:]
            prompts[i, self.prompt_len - len(toks):] = toks  # left-pad
        return prompts

    def __call__(self, batch: Batch, deadline: Optional[float] = None):
        t0 = self.clock()
        prompts = self._prompts(batch)
        largest = self.pool.engine_cfg.batch_buckets[-1]
        aborted_from: Optional[int] = None
        if batch.size <= largest:
            out, timing = self.pool.generate(prompts, gen_len=self.gen_len)
        else:
            # A batch larger than the largest compiled bucket executes as
            # sequential largest-bucket chunks — the dispatch path never
            # raises on a policy whose cap outruns the engine's buckets.
            outs = []
            timing = None
            chunks = 0
            for lo in range(0, batch.size, largest):
                if (deadline is not None and lo > 0
                        and self.clock() >= deadline):
                    aborted_from = lo
                    break
                o, timing = self.pool.generate(prompts[lo:lo + largest],
                                               gen_len=self.gen_len)
                outs.append(o)
                chunks += 1
            out = np.concatenate(outs, axis=0)
            if out.shape[0] < batch.size:  # aborted tail: zero rows
                pad = np.zeros((batch.size - out.shape[0],) + out.shape[1:],
                               out.dtype)
                out = np.concatenate([out, pad], axis=0)
            timing = dict(timing)
            timing["chunks"] = chunks
        latency = self.clock() - t0
        self.batches += 1
        self.requests += batch.size
        if aborted_from is not None:
            timing["deadline_aborted"] = batch.size - aborted_from
            self.deadline_aborted += batch.size - aborted_from
        for i, (req, tokens) in enumerate(zip(batch.requests, out)):
            if aborted_from is not None and i >= aborted_from:
                req.timed_out = True  # partial batch: tail reported dead
            else:
                req.payload = tokens
        if self.on_done is not None:
            self.on_done(batch, latency, t0 + latency)
        return out, timing
