"""Glue between the MLProxy control plane and the JAX engine.

``EngineBackedLatency`` turns the real engine into a
:class:`~repro.serverless.latency.LatencyModel`: ``sample(batch_size)``
executes a real bucketed prefill+decode on this host and returns measured
wall seconds. Plugging it into the Simulator gives the hybrid loop used by
``examples/serve_engine.py``: simulated arrivals + real MLProxy decisions +
real JAX execution (service times measured, not modeled).

``ReplicaPoolTarget`` is the real-serving dispatch target: it adapts a
:class:`~repro.serving.engine.ReplicaPool` to the ``dispatch_fn(batch)``
contract of the shared :class:`~repro.core.batch_queue.BatchQueue`, so a
:class:`~repro.core.frontend.ProxyFrontend` can give each endpoint its own
pool (one model per endpoint) while every policy dispatches through the
same code path.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.request import Batch
from repro.serverless.latency import LatencyModel
from repro.serving.engine import InferenceEngine, ReplicaPool, next_bucket


class EngineBackedLatency(LatencyModel):
    """LatencyModel whose samples are real engine executions."""

    name = "engine"
    noise_cv = 0.0  # real wall-clock variation is the noise

    def __init__(self, engine: InferenceEngine, prompt_len: int = 16,
                 gen_len: Optional[int] = None) -> None:
        self.engine = engine
        self.prompt_len = prompt_len
        self.gen_len = gen_len
        self._ema: Dict[int, float] = {}

    def mean(self, batch_size: int) -> float:
        # clamp: estimation must stay total for any size the policy may
        # probe (RT95[N_q+1] can exceed the largest compiled bucket); an
        # oversized size executes as sequential largest-bucket chunks, so
        # the estimate carries the same chunk factor as sample()
        largest = self.engine.ecfg.batch_buckets[-1]
        chunks = max(1, -(-batch_size // largest))
        bucket = next_bucket(batch_size, self.engine.ecfg.batch_buckets,
                             clamp=True)
        if bucket in self._ema:
            return chunks * self._ema[bucket]
        # never measured: optimistic estimate from the closest known bucket
        known = sorted(self._ema)
        if known:
            return chunks * self._ema[known[-1]]
        return 0.0

    def sample(self, batch_size: int, rng: np.random.Generator) -> float:
        # Oversized sizes execute as sequential largest-bucket chunks —
        # exactly what the dispatch path does — so the sampled latency is
        # the real cost, not a mid-simulation ValueError.
        largest = self.engine.ecfg.batch_buckets[-1]
        total = 0.0
        remaining = batch_size
        while remaining > 0:
            n = min(remaining, largest)
            prompts = rng.integers(
                0, self.engine.cfg.vocab_size,
                size=(n, self.prompt_len)).astype(np.int32)
            _, timing = self.engine.generate(prompts, gen_len=self.gen_len)
            bucket = timing["bucket"]
            dt = timing["latency_s"]
            prev = self._ema.get(bucket)
            self._ema[bucket] = dt if prev is None else 0.8 * prev + 0.2 * dt
            total += dt
            remaining -= n
        return total


class ReplicaPoolTarget:
    """Per-endpoint dispatch target backed by a :class:`ReplicaPool`.

    Callable with the ``dispatch_fn(batch)`` signature the shared
    ``BatchQueue`` expects: builds the prompt array from each request's
    payload (token-id arrays; missing payloads become zero prompts), runs
    the pool with round-robin failover, and reports the measured wall-clock
    back through ``on_done(batch, latency_s, now)`` — typically the owning
    policy's ``on_response`` — closing the monitor feedback loop on real
    hardware.
    """

    def __init__(self, pool: ReplicaPool, prompt_len: int = 16,
                 gen_len: Optional[int] = None,
                 on_done: Optional[Callable[[Batch, float, float], None]] = None,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.pool = pool
        self.prompt_len = prompt_len
        self.gen_len = gen_len
        self.on_done = on_done
        self.clock = clock
        self.batches = 0
        self.requests = 0

    def _prompts(self, batch: Batch) -> np.ndarray:
        prompts = np.zeros((batch.size, self.prompt_len), np.int32)
        for i, req in enumerate(batch.requests):
            if req.payload is None:
                continue
            # keep the LAST prompt_len tokens: with left-padding the engine
            # continues from the trailing context, not the prompt's head
            toks = np.asarray(req.payload, np.int32).ravel()[-self.prompt_len:]
            prompts[i, self.prompt_len - len(toks):] = toks  # left-pad
        return prompts

    def __call__(self, batch: Batch):
        t0 = self.clock()
        prompts = self._prompts(batch)
        largest = self.pool.engine_cfg.batch_buckets[-1]
        if batch.size <= largest:
            out, timing = self.pool.generate(prompts, gen_len=self.gen_len)
        else:
            # A batch larger than the largest compiled bucket executes as
            # sequential largest-bucket chunks — the dispatch path never
            # raises on a policy whose cap outruns the engine's buckets.
            outs = []
            timing = None
            for lo in range(0, batch.size, largest):
                o, timing = self.pool.generate(prompts[lo:lo + largest],
                                               gen_len=self.gen_len)
                outs.append(o)
            out = np.concatenate(outs, axis=0)
            timing = dict(timing)
            timing["chunks"] = -(-batch.size // largest)
        latency = self.clock() - t0
        self.batches += 1
        self.requests += batch.size
        for req, tokens in zip(batch.requests, out):
            req.payload = tokens
        if self.on_done is not None:
            self.on_done(batch, latency, t0 + latency)
        return out, timing
