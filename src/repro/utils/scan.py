"""``maybe_scan`` — ``jax.lax.scan`` or a Python unroll, same signature.

Scan keeps HLO size O(1) in depth (production path). The unrolled path
exists because XLA's cost analysis counts while-loop bodies ONCE regardless
of trip count: the dry-run calibrates true FLOPs/bytes/collective volumes
by compiling shallow *unrolled* variants at two depths and extrapolating
linearly (see repro.roofline.analysis).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


def maybe_scan(body: Callable, carry: Any, xs: Any, *, unroll: bool = False,
               ) -> Tuple[Any, Any]:
    """Like ``jax.lax.scan(body, carry, xs)``; Python-unrolled if ``unroll``."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if not ys or all(l is None for l in jax.tree.leaves(ys[0], is_leaf=lambda x: x is None)):
        return carry, None
    stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    return carry, stacked
