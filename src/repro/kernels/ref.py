"""Pure-jnp oracles for every Pallas kernel (same signatures as ops.py).

These re-export the model-library reference implementations — the kernels
are *behind* the model code, so the oracle and the production fallback are
the same audited code path.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import (
    chunked_attention as _chunked,
    decode_attention as _decode_ref,
    reference_attention as _naive,
)
from repro.models.ssm import ssd_chunked as _ssd_chunked, ssd_reference


def flash_attention(q, k, v, *, causal: bool = True, **_):
    """Oracle for ops.flash_attention (naive full-matrix GQA attention)."""
    return _naive(q, k, v, causal=causal)


def flash_attention_chunked(q, k, v, *, causal: bool = True, q_chunk: int = 512, **_):
    """Second, independently-derived oracle (streaming softmax)."""
    return _chunked(q, k, v, causal=causal, q_chunk=q_chunk)


def decode_attention(q, k_cache, v_cache, cache_len, **_):
    """Oracle for ops.decode_attention."""
    return _decode_ref(q, k_cache, v_cache, cache_len)


def mlstm_attention(q, k, v, log_i, log_f, **_):
    """Oracle for ops.mlstm_attention (pure-jnp parallel mLSTM)."""
    from repro.models.xlstm import _mlstm_parallel

    return _mlstm_parallel(q, k, v, log_i, log_f, chunk=10**9)


def ssd_scan(x, dt, a, b, c, *, chunk: int = 128, **_):
    """Oracle for ops.ssd_scan (chunked pure-jnp SSD)."""
    y, _ = _ssd_chunked(x, dt, a, b, c, chunk=chunk)
    return y


def ssd_scan_sequential(x, dt, a, b, c, **_):
    """Slow sequential oracle (exact recurrence)."""
    y, _ = ssd_reference(x, dt, a, b, c)
    return y
