"""Pallas TPU batched GQA decode-attention kernel.

One query token per sequence against a (padded) KV cache. Grid:
``(batch·kv_heads, num_kv_blocks)``; each step loads one kv block and the
G query heads that share it (the whole GQA group rides one MXU pass —
scores are a (G × block_k) matmul). Per-sequence valid lengths mask padded
cache slots. Running max/sum/acc in VMEM scratch, as in the prefill
kernel; the workload is memory-bound (cache streaming), so block_k is
large (512) to maximize the HBM burst size.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, block_k: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    k_start = ki * block_k
    # skip blocks entirely past the valid cache region
    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (G, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, bk)
        g = s.shape[0]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (g, block_k), 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(1) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_bhgd(q, k, v, lengths, *, block_k: int = 512,
                          interpret: bool = True):
    """Decode attention over pre-flattened kv-heads.

    q: (BHkv, G, D) — one token's query heads grouped by kv head;
    k, v: (BHkv, S, D) padded caches; lengths: (BHkv,) valid entries.
    Returns (BHkv, G, D).
    """
    bh, g, d = q.shape
    s = k.shape[1]
    block_k = min(block_k, max(s, 8))
    nk = math.ceil(s / block_k)
    pad = nk * block_k - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    kernel = functools.partial(_decode_kernel, scale=1.0 / math.sqrt(d),
                               block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(bh, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, ki: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, d), lambda b, ki: (b, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda b, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
