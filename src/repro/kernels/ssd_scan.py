"""Pallas TPU Mamba-2 / SSD chunked-scan kernel.

Grid: ``(batch·heads, num_chunks)`` with the chunk axis sequential; the
(P×N) recurrent state lives in VMEM scratch and is carried across chunks.
Each chunk step is four MXU matmuls (CBᵀ, diag-term, state injection,
state-to-output) over a (Q × {P,N}) working set — the chunk length Q is
the Pallas block size (default 128, MXU-aligned).

B and C are shared across heads (ngroups = 1), expressed in the index maps
(bh → batch is a static division), so no replication is materialized.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)    # (Q,)
    a = a_ref[0].astype(jnp.float32)      # scalar decay rate (negative)
    b = b_ref[0].astype(jnp.float32)      # (Q, N)
    c = c_ref[0].astype(jnp.float32)      # (Q, N)

    da = dt * a                            # (Q,) log-decay per step
    da_cum = jnp.cumsum(da)                # within-chunk cumulative
    da_total = da_cum[-1]

    # intra-chunk (quadratic) term: y[q] += Σ_k CBᵀ[q,k]·exp(Σ_{k<j≤q}da)·dt[k]·x[k]
    seg = da_cum[:, None] - da_cum[None, :]          # (Q, Q)
    qpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l_mat = jnp.where(kpos <= qpos, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    w = cb * l_mat * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, P)

    # inter-chunk: contribution of the carried state
    decay_from_start = jnp.exp(da_cum)  # (Q,)
    h_prev = h_ref[...]  # (P, N)
    y_off = jax.lax.dot_general(
        c * decay_from_start[:, None], h_prev,
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)  # (Q, P)
    y_ref[0, ...] = (y + y_off).astype(y_ref.dtype)

    # state update: h = h·exp(Σda) + Σ_k x[k] ⊗ (b[k]·decay_to_end[k]·dt[k])
    decay_to_end = jnp.exp(da_total - da_cum)  # (Q,)
    bw = b * (decay_to_end * dt)[:, None]  # (Q, N)
    inject = jax.lax.dot_general(x, bw, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (P, N)
    h_ref[...] = h_prev * jnp.exp(da_total) + inject


def ssd_scan_bhsd(x, dt, a, b, c, *, chunk: int = 128, interpret: bool = True):
    """SSD scan over pre-flattened heads.

    x: (BH, S, P); dt: (BH, S) (positive, already softplus'd);
    a: (BH,) negative decay rates; b, c: (B, S, N) shared across heads.
    Returns y: (BH, S, P). Sequences are padded to chunk multiples with
    dt = 0 (identity decay, zero injection) so padding is exact.
    """
    bh, s, p = x.shape
    bsz, _, n = b.shape
    if bh % bsz:
        raise ValueError(f"BH={bh} not a multiple of B={bsz}")
    h_per_b = bh // bsz
    chunk = min(chunk, max(s, 8))
    nc = math.ceil(s / chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh_, ci: (bh_, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh_, ci: (bh_, ci)),
            pl.BlockSpec((1,), lambda bh_, ci: (bh_,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, n), lambda bh_, ci: (bh_ // h_per_b, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh_, ci: (bh_ // h_per_b, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda bh_, ci: (bh_, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nc * chunk, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a.astype(jnp.float32), b, c)
    return out[:, :s]
