"""Pallas TPU kernel for the parallel mLSTM form (xLSTM's hot path).

Attention-shaped with an additive decay bias instead of softmax:

    b_ij = F_i − F_j + log i_j      (causal; F = cumsum log-sigmoid forget)
    m_i  = max_j b_ij
    num_i = Σ_j (q_i·k_j/√d) exp(b_ij − m_i) v_j
    den_i = Σ_j (q_i·k_j/√d) exp(b_ij − m_i)
    y_i  = num_i / max(|den_i|, exp(−m_i))

Same grid/scratch pattern as the flash kernel (the kv axis is sequential;
running (m, num, den) in VMEM): rescaling by exp(m_prev − m_new) is valid
because it multiplies both the signed numerator and denominator terms by
the same positive factor. Oracle: ``repro.models.xlstm._mlstm_parallel``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, fcum_ref, logi_ref, o_ref,
                  m_ref, num_ref, den_ref, *, scale: float, seq_len: int,
                  block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    @pl.when(k_start <= q_start + block_q - 1)  # causal block skip
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        fq = fcum_ref[0].astype(jnp.float32)      # (bq, 1) — F_i
        fk_li = logi_ref[0].astype(jnp.float32)   # (bk, 1) — log i_j − F_j

        bmat = fq + fk_li.T                       # (bq, bk): F_i − F_j + log i_j
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = (kpos <= qpos) & (kpos < seq_len)
        bmat = jnp.where(mask, bmat, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(bmat, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        w = scores * jnp.exp(bmat - m_new)
        w = jnp.where(mask, w, 0.0)
        num_ref[...] = num_ref[...] * alpha + jax.lax.dot_general(
            w, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        den_ref[...] = den_ref[...] * alpha + jnp.sum(w, axis=1, keepdims=True)
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        m = m_ref[...]
        den = jnp.maximum(jnp.abs(den_ref[...]), jnp.exp(-m))
        o_ref[0, ...] = (num_ref[...] / den).astype(o_ref.dtype)


def mlstm_attention_bhsd(q, k, v, log_i, log_f, *, block_q: int = 128,
                         block_k: int = 128, interpret: bool = True):
    """Parallel mLSTM over pre-flattened heads.

    q/k/v: (BH, S, D); log_i, log_f: (BH, S) (input-gate log and
    log-sigmoid forget). Returns y: (BH, S, D).
    """
    bh, s, d = q.shape
    block_q = min(block_q, max(s, 8))
    block_k = min(block_k, max(s, 8))
    nq = math.ceil(s / block_q)
    nk = math.ceil(s / block_k)
    q_pad = nq * block_q - s
    k_pad = nk * block_k - s
    fcum = jnp.cumsum(log_f.astype(jnp.float32), axis=1)  # (BH, S)
    fk_li = (log_i.astype(jnp.float32) - fcum)            # log i_j − F_j
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0)))
        fcum_q = jnp.pad(fcum, ((0, 0), (0, q_pad)))
    else:
        fcum_q = fcum
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0)))
        fk_li = jnp.pad(fk_li, ((0, 0), (0, k_pad)))

    kernel = functools.partial(
        _mlstm_kernel, scale=1.0 / math.sqrt(d), seq_len=s,
        block_q=block_q, block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_q, 1), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, 1), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, nq * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, fcum_q[..., None], fk_li[..., None])
    return out[:, :s]
