"""Pallas TPU flash-attention (prefill) kernel.

Grid: ``(batch·q_heads, num_q_blocks, num_kv_blocks)`` — the kv axis is the
innermost (sequential) dimension; running max / sum / accumulator live in
VMEM scratch and persist across kv steps (the standard TPU flash pattern).
GQA is handled in the k/v index maps (q-head → kv-head is a static integer
division), so no head replication is materialized.

Block sizes default to 128×128 (MXU-aligned); the f32 working set per step
is q(bq·d) + k,v(2·bk·d) + scores(bq·bk) + acc(bq·d) ≈ 260 KB for d=128 —
comfortably inside the ~16 MB VMEM budget, leaving room for double
buffering of the k/v streams.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, kv_len: int,
                  block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip kv blocks strictly above the diagonal band
    q_start = qi * block_q
    k_start = ki * block_k
    should_run = jnp.logical_or(
        jnp.logical_not(causal), k_start <= q_start + block_q - 1)

    @pl.when(should_run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len  # padded keys
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            mask = jnp.logical_and(mask, kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (bq, bk)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, block_q: int = 128,
                         block_k: int = 128, interpret: bool = True):
    """Flash attention over pre-flattened heads.

    q: (BHq, Sq, D); k, v: (BHkv, Sk, D) with BHq = BHkv · G.
    Sequences are padded to block multiples; padded keys are masked via
    ``kv_len`` baked into the kernel.
    """
    bhq, sq, d = q.shape
    bhkv, sk, _ = k.shape
    if bhq % bhkv:
        raise ValueError(f"q heads {bhq} not a multiple of kv heads {bhkv}")
    g = bhq // bhkv
    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(sk, 8))
    nq = math.ceil(sq / block_q)
    nk = math.ceil(sk / block_k)
    q_pad = nq * block_q - sq
    k_pad = nk * block_k - sk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(d), causal=causal, kv_len=sk,
        block_q=block_q, block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=(bhq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, nq * block_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
