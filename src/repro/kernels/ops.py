"""Jit'd public wrappers around the Pallas kernels.

These accept model-layout tensors — q (B, S, Hq, D), caches
(B, S, Hkv, D), SSD inputs (B, S, H, P) — handle GQA head-flattening,
padding, and dtype plumbing, and fall back to interpret mode off-TPU
(``interpret=None`` → auto: real Mosaic lowering on TPU, Python
interpretation on CPU so the same call sites work everywhere).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    """q: (B, S, Hq, D); k, v: (B, S, Hkv, D) → (B, S, Hq, D)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    out = _fa.flash_attention_bhsd(
        qf, kf, vf, causal=causal, block_q=block_q, block_k=block_k,
        interpret=_auto_interpret(interpret))
    return out.reshape(b, hq, s, d).transpose(0, 2, 1, 3)


def decode_attention(q, k_cache, v_cache, cache_len, *, block_k: int = 512,
                     interpret: Optional[bool] = None):
    """q: (B, 1, Hq, D); caches: (B, S, Hkv, D); cache_len: scalar or (B,).

    Returns (B, 1, Hq, D) — drop-in for the jnp decode path.
    """
    b, _, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, d).reshape(b * hkv, g, d)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    lengths = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1),
                               (b,))
    lengths = jnp.repeat(lengths, hkv) if lengths.shape[0] == b else lengths
    out = _dec.decode_attention_bhgd(
        qf, kf, vf, lengths, block_k=block_k,
        interpret=_auto_interpret(interpret))
    return out.reshape(b, hkv, g, d).reshape(b, 1, hq, d)


def mlstm_attention(q, k, v, log_i, log_f, *, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    """Parallel mLSTM in model layout.

    q/k/v: (B, S, H, D); log_i, log_f: (B, S, H) → y: (B, S, H, D).
    Drop-in for ``repro.models.xlstm._mlstm_parallel`` (its oracle).
    """
    from repro.kernels import mlstm_attention as _ml

    b, s, h, d = q.shape
    def flat(x4):
        return x4.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    li = log_i.transpose(0, 2, 1).reshape(b * h, s)
    lf = log_f.transpose(0, 2, 1).reshape(b * h, s)
    out = _ml.mlstm_attention_bhsd(
        flat(q), flat(k), flat(v), li, lf, block_q=block_q, block_k=block_k,
        interpret=_auto_interpret(interpret))
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def ssd_scan(x, dt, a, b, c, *, chunk: int = 128,
             interpret: Optional[bool] = None):
    """SSD scan in model layout.

    x: (B, S, H, P); dt: (B, S, H); a: (H,); b, c: (B, S, N) →
    y: (B, S, H, P). Drop-in for ``repro.models.ssm.ssd_chunked`` (which is
    its oracle) minus the final-state output.
    """
    bsz, s, h, p = x.shape
    xf = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * h, s)
    af = jnp.tile(a.reshape(1, h), (bsz, 1)).reshape(bsz * h)
    out = _ssd.ssd_scan_bhsd(xf, dtf, af, b, c, chunk=chunk,
                             interpret=_auto_interpret(interpret))
    return out.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
