"""Pallas API compatibility across jax versions.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer jax releases; the kernels import the name from here so they run on
both.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
