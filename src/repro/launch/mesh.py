"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches JAX device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import and only then calls it.

Mesh layout (TPU v5e pods of 256 chips):
  single-pod:  (16, 16)      axes ("data", "model")
  multi-pod:   (2, 16, 16)   axes ("pod", "data", "model")

"model" carries tensor/expert parallelism (high-bandwidth inner ICI ring),
"data" carries FSDP + batch parallelism, "pod" is pure data parallel
(one gradient reduction across the inter-pod links per step).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests use small ones, e.g. (2, 2))."""
    return jax.make_mesh(shape, axes)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes the batch dimension shards over (pod+data when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_devices(mesh: Mesh) -> int:
    return int(np.prod(mesh.devices.shape))
