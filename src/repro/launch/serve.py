"""Serving driver: MLProxy fronting the JAX engine (+ replica pool).

The hybrid loop: simulated arrivals drive the proxy in virtual time; every
dispatched batch executes a real bucketed prefill+decode on this host and
the measured wall time is the upstream latency the Smart Monitor learns
from. ``--snapshot`` persists the control-plane state so a restarted proxy
resumes with learned latency statistics (fault tolerance of the paper's
component itself).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --rate 40 --duration 60 [--snapshot /tmp/proxy_state.json]
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro.configs import ARCH_IDS, get_config
from repro.core import OptimizerConfig, SLAConfig
from repro.serverless.platform import PlatformConfig
from repro.serving.batcher import EngineBackedLatency
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.simulation.arrivals import PoissonProcess
from repro.simulation.simulator import Simulator


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="qwen2-0.5b", choices=list(ARCH_IDS))
    p.add_argument("--rate", type=float, default=40.0)
    p.add_argument("--duration", type=float, default=60.0)
    p.add_argument("--slo-ms", type=float, default=2000.0)
    p.add_argument("--gen-len", type=int, default=4)
    p.add_argument("--full-size", action="store_true",
                   help="full config (needs accelerators); default reduced")
    p.add_argument("--snapshot", default=None,
                   help="path to persist/restore proxy control-plane state")
    args = p.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    ecfg = EngineConfig(batch_buckets=(1, 2, 4, 8, 16, 32),
                        prompt_buckets=(16,), max_len=16 + args.gen_len + 8,
                        gen_len=args.gen_len)
    engine = InferenceEngine(cfg, ecfg, rng=jax.random.PRNGKey(0))
    print(f"[serve] compiling buckets for {cfg.name} ...")
    engine.warmup(plen=16)
    print(f"[serve] {engine.compile_count} programs cached")

    sla = SLAConfig(slo_target=args.slo_ms / 1000.0)
    sim = Simulator(
        policy="mlproxy", sla=sla,
        workload=EngineBackedLatency(engine, prompt_len=16,
                                     gen_len=args.gen_len),
        arrivals=PoissonProcess(rate=args.rate, duration=args.duration),
        platform_config=PlatformConfig(initial_scale=1, cold_start=0.5),
        duration=args.duration, seed=0,
        policy_kwargs={"bucketing": "pow2",
                       "optimizer": OptimizerConfig(update_interval=5.0,
                                                    initial_max_bs=2)},
    )
    if args.snapshot and os.path.exists(args.snapshot):
        with open(args.snapshot) as f:
            sim.policy.restore(json.load(f))
        print(f"[serve] restored proxy state (Max_BS={sim.policy.max_bs})")

    res = sim.run()
    s = res.summary
    print(f"[serve] {s['completed']:.0f} requests, "
          f"{engine.stats['batches']:.0f} JAX batches, "
          f"avg batch {s['avg_batch_size']:.2f}, P95 {s['p95']*1000:.0f} ms, "
          f"violations {s['violation_pct']:.2f}%")
    if args.snapshot:
        state = sim.policy.snapshot()
        with open(args.snapshot, "w") as f:
            json.dump(state, f, default=lambda o: getattr(o, "__dict__", str(o)))
        print(f"[serve] proxy state saved → {args.snapshot}")


if __name__ == "__main__":
    main()
