import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init). Everything below may import jax.

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from typing import Any, Dict, Optional, Tuple  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, get_config  # noqa: E402
from repro.configs.base import ModelConfig, ShapeCell  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch.mesh import make_production_mesh, make_mesh  # noqa: E402
from repro.models.model import Model, input_specs  # noqa: E402
from repro.optim import adamw  # noqa: E402

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape × mesh): build the step function
(train_step / prefill / decode), attach in/out shardings from
``repro.distributed.sharding``, ``.lower().compile()`` against
ShapeDtypeStruct inputs (no allocation), and record
``memory_analysis()`` + ``cost_analysis()`` + the collective-op byte
census parsed from the optimized HLO. Artifacts land in
``experiments/artifacts/dryrun/`` and feed §Roofline.
"""

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "artifacts", "dryrun")

_COLLECTIVE_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\])\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum output bytes of every collective op in optimized HLO text."""
    per_op: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_part, op = m.group(1), m.group(2)
        if m.group(3) == "-start" and f"{op}-done" in hlo_text:
            pass  # count the -start (has the shape); -done lines don't match
        nbytes = 0
        for dm in _SHAPE_RE.finditer(shape_part):
            dt, dims = dm.group(1), dm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        per_op[op] = per_op.get(op, 0) + nbytes
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_by_op": per_op, "counts": counts,
            "total_bytes": sum(per_op.values())}


def _cost_summary(cost: Dict[str, Any]) -> Dict[str, float]:
    out = {}
    for k in ("flops", "transcendentals", "bytes accessed"):
        if k in cost:
            out[k] = float(cost[k])
    return out


def calibration_depths(cfg: ModelConfig) -> Tuple[int, int]:
    """Two unrolled depths whose linear fit extrapolates to full depth.

    XLA cost analysis counts while-loop bodies once, so the scanned full
    compile under-reports FLOPs/bytes/collectives by ~the layer count. The
    dry-run therefore also compiles shallow *unrolled* variants at two
    depths; per-layer deltas are exact because layers are homogeneous
    within a family's repeat unit (super-block for xlstm, attn_every
    window for zamba2).
    """
    if cfg.family == "hybrid":
        u = cfg.attn_every
        return u, 2 * u
    if cfg.family == "ssm":
        u = cfg.mlstm_per_slstm + 1
        return u, 2 * u
    return 2, 4


def depth_variant(cfg: ModelConfig, depth: int) -> ModelConfig:
    kw = dict(num_layers=depth, scan_layers=False)
    if cfg.family == "encdec":
        kw["encoder_layers"] = depth
    return dataclasses.replace(cfg, **kw)


def build_step(cfg: ModelConfig, shape: ShapeCell, opt_state_dtype: str):
    """Returns (fn, abstract_args tuple, kind) for one cell."""
    model = Model(cfg)
    specs = input_specs(cfg, shape)
    abstract_params = model.init_abstract()
    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig(state_dtype=opt_state_dtype)
        abstract_opt = jax.eval_shape(
            lambda p: adamw.init_state(opt_cfg, p), abstract_params)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            params, opt_state, metrics = adamw.apply_updates(
                opt_cfg, params, grads, opt_state)
            metrics["loss"] = loss
            return params, opt_state, metrics

        return train_step, (abstract_params, abstract_opt, specs["batch"]), "train"
    if shape.kind == "prefill":
        def prefill_step(params, inputs, cache):
            return model.prefill(params, inputs, cache)

        return prefill_step, (abstract_params, specs["inputs"], specs["cache"]), "prefill"
    if shape.kind == "decode":
        def decode_step(params, tokens, cache):
            return model.decode_step(params, tokens, cache)

        return decode_step, (abstract_params, specs["tokens"], specs["cache"]), "decode"
    raise ValueError(shape.kind)


def _compile_cell(cfg: ModelConfig, shape: ShapeCell, mesh,
                  opt_state_dtype: str):
    """Shard + lower + compile one (config, shape) on ``mesh``."""
    fn, abstract_args, kind = build_step(cfg, shape, opt_state_dtype)
    params_sh = shd.shard_params(abstract_args[0], mesh, cfg)
    if kind == "train":
        opt_sh = shd.shard_opt_state(abstract_args[1], params_sh, mesh)
        batch_sh = shd.shard_inputs(abstract_args[2], mesh, cfg, shape)
        in_sh = (params_sh, opt_sh, batch_sh)
        metrics_sh = {"grad_norm": NamedSharding(mesh, P()),
                      "loss": NamedSharding(mesh, P())}
        out_sh = (params_sh, opt_sh, metrics_sh)
    else:
        rest = abstract_args[1:]
        # last serve argument is always the cache tree
        rest_sh = tuple(
            shd.shard_inputs(a, mesh, cfg, shape, is_cache=(i == len(rest) - 1))
            for i, a in enumerate(rest))
        in_sh = (params_sh,) + rest_sh
        cache_sh = rest_sh[-1]
        logits_sh = NamedSharding(mesh, shd.data_spec(
            (shape.global_batch, 1, cfg.vocab_size), mesh, cfg,
            shape.global_batch))
        out_sh = (logits_sh, cache_sh)
    t0 = time.perf_counter()
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(
            *abstract_args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
    return compiled, kind, t_lower, t_compile


def calibrate_cell(cfg: ModelConfig, shape: ShapeCell, mesh,
                   opt_state_dtype: str, verbose: bool = True) -> Dict[str, Any]:
    """Compile two shallow unrolled variants; record per-depth costs."""
    d1, d2 = calibration_depths(cfg)
    cal: Dict[str, Any] = {"depths": [d1, d2], "full_depth": cfg.num_layers,
                           "points": []}
    for d in (d1, d2):
        cfg_d = depth_variant(cfg, d)
        compiled, _, tl, tc = _compile_cell(cfg_d, shape, mesh, opt_state_dtype)
        cost = _cost_summary(compiled.cost_analysis())
        coll = collective_bytes(compiled.as_text())
        cal["points"].append({
            "depth": d, "cost": cost,
            "collective_total_bytes": coll["total_bytes"],
            "collective_bytes_by_op": coll["bytes_by_op"],
            "compile_s": round(tc, 2),
        })
        if verbose:
            print(f"  calib depth={d}: flops={cost.get('flops', 0):.3e} "
                  f"coll={coll['total_bytes']/1e9:.3f} GB ({tc:.1f}s)")
    return cal


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                mesh=None, opt_state_dtype: Optional[str] = None,
                calibrate: bool = True, save: bool = True,
                verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_tag = "pod2" if multi_pod else "pod1"
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    if not cfg.supports_shape(shape):
        record["status"] = "skipped"
        record["skip_reason"] = cfg.skip_reason(shape)
        if save:
            _save(record)
        return record

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(mesh.devices.size)
    record["mesh_shape"] = {k: int(v) for k, v in mesh.shape.items()}
    if opt_state_dtype is None:
        # bf16 moments for ≥100B-param models (memory; DESIGN.md §4)
        opt_state_dtype = "bfloat16" if cfg.param_count() > 1e11 else "float32"
    record["opt_state_dtype"] = opt_state_dtype

    compiled, kind, t_lower, t_compile = _compile_cell(
        cfg, shape, mesh, opt_state_dtype)
    record.update(status="ok", kind=kind, devices=n_dev,
                  lower_s=round(t_lower, 2), compile_s=round(t_compile, 2))

    try:
        mem = compiled.memory_analysis()
        record["memory_analysis"] = {
            k: int(getattr(mem, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        print_mem = record["memory_analysis"]
    except Exception as e:  # CPU backend may not implement it
        record["memory_analysis"] = {"error": str(e)}
        print_mem = str(e)

    try:
        record["cost_analysis"] = _cost_summary(compiled.cost_analysis())
    except Exception as e:
        record["cost_analysis"] = {"error": str(e)}

    hlo = compiled.as_text()
    record["collectives"] = collective_bytes(hlo)
    record["hlo_bytes"] = len(hlo)
    del hlo, compiled

    if calibrate:
        try:
            record["calibration"] = calibrate_cell(
                cfg, shape, mesh, opt_state_dtype, verbose=verbose)
        except Exception as e:
            record["calibration"] = {"error": repr(e)}
            print(f"[dryrun] calibration failed for {arch}×{shape_name}: {e}",
                  file=sys.stderr)

    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_tag}: OK "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(f"  memory_analysis: {print_mem}")
        print(f"  cost_analysis: {record['cost_analysis']}")
        print(f"  collectives: {record['collectives']['counts']} "
              f"total {record['collectives']['total_bytes']/1e9:.3f} GB")
    if save:
        _save(record)
    return record


def _save(record: Dict[str, Any]) -> None:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
    with open(os.path.join(ARTIFACT_DIR, name), "w") as f:
        json.dump(record, f, indent=1)


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="all", help="arch id or 'all'")
    p.add_argument("--shape", default="all", help="shape name or 'all'")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--mesh-shape", default=None,
                   help="debug override, e.g. '4,4' (axes data,model)")
    p.add_argument("--no-save", action="store_true")
    p.add_argument("--no-calibrate", action="store_true",
                   help="skip the unrolled two-depth cost calibration")
    args = p.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES_BY_NAME) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    mesh = None
    if args.mesh_shape:
        dims = tuple(int(x) for x in args.mesh_shape.split(","))
        axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        mesh = make_mesh(dims, axes)

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    # calibration (roofline terms) only on the single-pod
                    # mesh — the multi-pod pass proves the pod axis shards
                    dryrun_cell(arch, shape, multi_pod=mp, mesh=mesh,
                                save=not args.no_save,
                                calibrate=(not mp) and not args.no_calibrate)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[dryrun] {arch} × {shape} × "
                          f"{'pod2' if mp else 'pod1'}: FAILED — {e}",
                          file=sys.stderr)
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)
    print("\n[dryrun] all requested cells compiled.")


if __name__ == "__main__":
    main()
