"""Training driver: data pipeline → jitted train step → checkpoint/restart.

Runnable at reduced scale on CPU (``examples/train_100m.py`` drives a ~100M
config for a few hundred steps); the same step function is what the
dry-run lowers at full scale on the production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, TokenDataset
from repro.distributed import checkpoint as ckpt
from repro.models.model import Model
from repro.optim import adamw


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    seed: int = 0
    warmup_steps: int = 20
    optimizer: adamw.AdamWConfig = dataclasses.field(default_factory=adamw.AdamWConfig)


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    total_steps: int, warmup: int):
    """Build the jittable (params, opt_state, batch) → ... step function."""
    model = Model(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        lr_scale = adamw.cosine_schedule(
            opt_state.step, warmup=warmup, total=total_steps)
        params, opt_state, metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state, lr_scale=lr_scale)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def train(cfg: ModelConfig, tcfg: TrainConfig,
          data_cfg: Optional[DataConfig] = None,
          ) -> Dict[str, Any]:
    """Run a (reduced-scale) training job; returns final metrics."""
    data_cfg = data_cfg or DataConfig(
        seq_len=min(cfg.max_seq_len, 128), global_batch=8,
        vocab_size=cfg.vocab_size, seed=tcfg.seed)
    dataset = TokenDataset(data_cfg)
    model = Model(cfg)
    rng = jax.random.PRNGKey(tcfg.seed)
    params = model.init(rng)
    opt_state = adamw.init_state(tcfg.optimizer, params)

    start_step = 0
    if tcfg.checkpoint_dir:
        restored = ckpt.restore_latest(tcfg.checkpoint_dir,
                                       {"params": params, "opt": opt_state})
        if restored is not None:
            start_step, tree, meta = restored
            params, opt_state = tree["params"], tree["opt"]
            dataset.restore(meta["data"])
            print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(
        cfg, tcfg.optimizer, tcfg.steps, tcfg.warmup_steps))
    losses = []
    t0 = time.perf_counter()
    for step in range(start_step, tcfg.steps):
        batch = jax.tree.map(jnp.asarray, next(dataset))
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (step + 1) % tcfg.log_every == 0 or step == tcfg.steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            print(f"[train] step {step+1}/{tcfg.steps} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({dt/(step-start_step+1)*1000:.0f} ms/step)")
        if tcfg.checkpoint_dir and (step + 1) % tcfg.checkpoint_every == 0:
            ckpt.save_checkpoint(
                tcfg.checkpoint_dir, step + 1,
                {"params": params, "opt": opt_state},
                metadata={"data": dataset.state(), "arch": cfg.name})
            ckpt.prune_checkpoints(tcfg.checkpoint_dir, tcfg.keep_checkpoints)
    return {
        "final_loss": losses[-1] if losses else float("nan"),
        "first_loss": losses[0] if losses else float("nan"),
        "params": params,
        "steps": tcfg.steps,
    }


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", required=True)
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--checkpoint-dir", default=None)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--full-size", action="store_true",
                   help="use the full config (needs accelerators)")
    args = p.parse_args()
    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    tcfg = TrainConfig(steps=args.steps, checkpoint_dir=args.checkpoint_dir)
    data_cfg = DataConfig(seq_len=args.seq_len, global_batch=args.batch,
                          vocab_size=cfg.vocab_size)
    out = train(cfg, tcfg, data_cfg)
    print(f"[train] done: loss {out['first_loss']:.4f} → {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
