"""Roofline analysis from dry-run artifacts (deliverable g).

Three terms per (arch × shape) cell on the single-pod mesh, all derived
from per-partition quantities of the compiled step (cost_analysis and the
collective census are per-device after SPMD partitioning, so each term
divides by a single chip's peak):

    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / ICI_link_bw

FLOPs/bytes/collective volumes come from the *depth-calibrated* linear fit
(two shallow unrolled compiles; see ``repro.launch.dryrun.calibrate_cell``)
because XLA cost analysis counts while-loop (scan) bodies once. The raw
scanned-compile numbers are retained for comparison.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Any, Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

ARTIFACT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "artifacts",
    "dryrun")

# Shape-cell step counts for MODEL_FLOPS (tokens processed by one step)
_TRAIN_MULT = 6.0  # fwd 2ND + bwd 4ND
_INFER_MULT = 2.0  # fwd only


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    devices: int = 0
    flops: float = 0.0  # per device, calibrated
    bytes_hbm: float = 0.0
    bytes_coll: float = 0.0
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0  # analytic 6·N·D (per device)
    useful_ratio: float = 0.0  # model_flops / hlo_flops
    raw_flops: float = 0.0  # uncalibrated (scan counted once)
    skip_reason: Optional[str] = None
    memory: Optional[dict] = None
    compile_s: float = 0.0

    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)


def _extrapolate(points: List[dict], full_depth: int, key) -> float:
    """Linear fit through two depth points, evaluated at full depth."""
    (d1, v1), (d2, v2) = [(pt["depth"], key(pt)) for pt in points]
    if d2 == d1:
        return v2
    slope = (v2 - v1) / (d2 - d1)
    return v1 + slope * (full_depth - d1)


def tokens_of_shape(shape_name: str) -> float:
    from repro.configs.base import SHAPES_BY_NAME

    s = SHAPES_BY_NAME[shape_name]
    if s.kind == "decode":
        return float(s.global_batch)  # one token per sequence
    return float(s.global_batch * s.seq_len)


def analyze_record(rec: Dict[str, Any]) -> RooflineRow:
    from repro.configs import get_config

    row = RooflineRow(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                      status=rec.get("status", "?"),
                      skip_reason=rec.get("skip_reason"))
    if row.status != "ok":
        return row
    row.devices = rec.get("devices", 0)
    row.memory = rec.get("memory_analysis")
    row.compile_s = rec.get("compile_s", 0.0)
    row.raw_flops = rec.get("cost_analysis", {}).get("flops", 0.0)

    cal = rec.get("calibration")
    if cal and "points" in cal and len(cal["points"]) == 2:
        full = cal["full_depth"]
        row.flops = _extrapolate(cal["points"], full,
                                 lambda p: p["cost"].get("flops", 0.0))
        row.bytes_hbm = _extrapolate(cal["points"], full,
                                     lambda p: p["cost"].get("bytes accessed", 0.0))
        row.bytes_coll = _extrapolate(cal["points"], full,
                                      lambda p: p["collective_total_bytes"])
    else:
        row.flops = row.raw_flops
        row.bytes_hbm = rec.get("cost_analysis", {}).get("bytes accessed", 0.0)
        row.bytes_coll = rec.get("collectives", {}).get("total_bytes", 0.0)

    row.t_compute = row.flops / PEAK_FLOPS
    row.t_memory = row.bytes_hbm / HBM_BW
    row.t_collective = row.bytes_coll / ICI_BW
    row.bottleneck = row.dominant()

    # analytic MODEL_FLOPS per device
    cfg = get_config(rec["arch"])
    n = cfg.active_param_count()
    mult = _TRAIN_MULT if rec.get("kind") == "train" else _INFER_MULT
    tokens = tokens_of_shape(rec["shape"])
    row.model_flops = mult * n * tokens / max(row.devices, 1)
    row.useful_ratio = row.model_flops / row.flops if row.flops else 0.0
    return row


def load_records(mesh: str = "pod1", artifact_dir: Optional[str] = None,
                 ) -> List[Dict[str, Any]]:
    d = artifact_dir or ARTIFACT_DIR
    recs = []
    for path in sorted(glob.glob(os.path.join(d, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_table(mesh: str = "pod1", artifact_dir: Optional[str] = None,
                   ) -> List[RooflineRow]:
    return [analyze_record(r) for r in load_records(mesh, artifact_dir)]


def format_table(rows: List[RooflineRow]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'status':8s} "
           f"{'compute(ms)':>12s} {'memory(ms)':>11s} {'collective(ms)':>14s} "
           f"{'bottleneck':>11s} {'useful':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.status != "ok":
            lines.append(f"{r.arch:24s} {r.shape:12s} {'SKIP':8s} "
                         f"{'—':>12s} {'—':>11s} {'—':>14s} "
                         f"{(r.skip_reason or ''):>11s}")
            continue
        lines.append(
            f"{r.arch:24s} {r.shape:12s} {r.status:8s} "
            f"{r.t_compute*1e3:12.2f} {r.t_memory*1e3:11.2f} "
            f"{r.t_collective*1e3:14.2f} {r.bottleneck:>11s} "
            f"{r.useful_ratio:7.2f}")
    return "\n".join(lines)


def main() -> None:
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--mesh", default="pod1")
    p.add_argument("--json", action="store_true")
    args = p.parse_args()
    rows = roofline_table(args.mesh)
    if args.json:
        print(json.dumps([dataclasses.asdict(r) for r in rows], indent=1))
    else:
        print(format_table(rows))


if __name__ == "__main__":
    main()
