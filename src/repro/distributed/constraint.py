"""Best-effort activation sharding constraints.

``shard_activation(x, spec...)`` applies ``with_sharding_constraint`` using
whatever axes the ambient mesh actually has, skipping axes that don't
divide the dimension — so model code can state its *intent* (batch over
("pod","data"), vocab over "model") and still trace fine with no mesh (CPU
tests) or partial meshes (debug runs).
"""
from __future__ import annotations

import warnings
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

AxisSpec = Union[None, str, Tuple[str, ...]]


def ambient_mesh():
    """Mesh of the enclosing ``with mesh:`` / ``set_mesh`` scope, or None."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:
            return am
    except Exception:
        pass
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        try:
            mesh = jax.interpreters.pxla.thread_resources.env.physical_mesh
        except Exception:
            return None
    return None if mesh.empty else mesh


def shard_activation(x: jax.Array, *spec: AxisSpec) -> jax.Array:
    """Constrain ``x`` to ``spec`` where the ambient mesh allows it."""
    mesh = ambient_mesh()
    if mesh is None:
        return x
    if len(spec) != x.ndim:
        raise ValueError(f"spec rank {len(spec)} != array rank {x.ndim}")
    names = set(mesh.axis_names)
    fixed = []
    for dim, axes in zip(x.shape, spec):
        if axes is None:
            fixed.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        present = tuple(a for a in axes if a in names)
        size = 1
        for a in present:
            size *= mesh.shape[a]
        if present and size > 1 and dim % size == 0:
            fixed.append(present if len(present) > 1 else present[0])
        else:
            fixed.append(None)
    if all(f is None for f in fixed):
        return x
    return jax.lax.with_sharding_constraint(x, P(*fixed))
