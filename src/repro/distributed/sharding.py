"""Sharding rules: params (FSDP×TP×EP), optimizer state, inputs, caches.

Strategy (single-pod mesh ("data","model"); multi-pod adds a pure-DP
"pod" axis in front):

  * weight matrices — contracting/output features over "model" (tensor
    parallel), the other large dim over "data" (FSDP; XLA all-gathers on
    use, reduce-scatters gradients);
  * expert weights — experts over "model" (expert parallel), d_model over
    "data";
  * embeddings / lm_head — vocab over "model", d_model over "data";
  * batch inputs — batch over ("pod","data"); when batch == 1 (long-context
    decode) the sequence dim shards over "data" instead (sequence
    parallelism);
  * KV caches / SSM states — batch over ("pod","data"); then the largest
    remaining dim divisible by "model" (kv-heads when they divide evenly,
    otherwise the cache sequence dim);
  * 1-D/small leaves — replicated.

Pattern overrides keep the out-projections ("wo", "out_proj", "down_proj")
sharded on their *contracting* dim so TP activations flow without an extra
all-gather (Megatron convention).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _batch_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))


def _leaf_path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


# ----------------------------------------------------------------- parameters
def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               cfg: ModelConfig) -> P:
    model_n = _axis_size(mesh, "model")
    data_n = _axis_size(mesh, "data")
    spec: list = [None] * len(shape)
    if len(shape) <= 1:
        return P(*spec)

    dims = list(range(len(shape)))

    def assign(axis_name: str, dim: int) -> None:
        spec[dim] = axis_name
        dims.remove(dim)

    def divisible(dim: int, n: int) -> bool:
        return n > 1 and shape[dim] % n == 0 and shape[dim] >= 2 * n

    # -- pattern overrides ---------------------------------------------------
    low = path.lower()
    is_embed = re.search(r"(^|/)embed", low) and shape[-1] == cfg.d_model
    is_head = "lm_head" in low
    is_expert = re.search(r"moe/w[io]$", low) or (
        len(shape) >= 3 and cfg.num_experts and shape[-3] == cfg.num_experts
        and "conv" not in low)
    is_out_proj = re.search(r"(wo|out_proj|down_proj)$", low)

    if is_embed:
        # (V, D) or stacked (.., V, D): vocab → model, d_model → data
        if divisible(len(shape) - 2, model_n):
            assign("model", len(shape) - 2)
        if divisible(len(shape) - 1, data_n):
            assign("data", len(shape) - 1)
        return P(*spec)
    if is_head:
        # (D, V): vocab → model, d_model → data
        if divisible(len(shape) - 1, model_n):
            assign("model", len(shape) - 1)
        if divisible(len(shape) - 2, data_n):
            assign("data", len(shape) - 2)
        return P(*spec)
    if is_expert and cfg.num_experts:
        e_dim = next((d for d in dims if shape[d] == cfg.num_experts), None)
        if e_dim is not None and shape[e_dim] % model_n == 0:
            assign("model", e_dim)
        # FSDP over the expert FFN width — matches the shard_map MoE
        # in_specs (wi: (…, E, D, F) F→data; wo: (…, E, F, D) F→data), so
        # the stored layout is exactly what the kernel consumes.
        f_dim = len(shape) - 1 if low.endswith("wi") else len(shape) - 2
        if f_dim in dims and divisible(f_dim, data_n):
            assign("data", f_dim)
        else:
            cands = [d for d in dims if divisible(d, data_n)]
            if cands:
                assign("data", max(cands, key=lambda d: shape[d]))
        return P(*spec)

    # -- generic matrices ----------------------------------------------------
    if is_out_proj:
        model_dim = len(shape) - 2  # contracting dim
        other = len(shape) - 1
    else:
        model_dim = len(shape) - 1  # output features
        other = len(shape) - 2
    if divisible(model_dim, model_n):
        assign("model", model_dim)
    if other in dims and divisible(other, data_n):
        assign("data", other)
    else:
        cands = [d for d in dims if divisible(d, data_n) and shape[d] >= 512]
        if cands:
            assign("data", max(cands, key=lambda d: shape[d]))
    return P(*spec)


def shard_params(abstract_params: Any, mesh: Mesh, cfg: ModelConfig) -> Any:
    """NamedSharding tree matching an abstract (or concrete) param tree."""

    def one(path, leaf):
        return NamedSharding(
            mesh, param_spec(_leaf_path_str(path), tuple(leaf.shape), mesh, cfg))

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def shard_opt_state(abstract_opt: Any, param_shardings: Any, mesh: Mesh) -> Any:
    """Optimizer state mirrors param sharding (mu/nu); scalars replicated."""
    replicated = NamedSharding(mesh, P())
    return type(abstract_opt)(
        step=replicated,
        mu=param_shardings,
        nu=param_shardings,
    )


# ---------------------------------------------------------------- data/caches
def data_spec(shape: Tuple[int, ...], mesh: Mesh, cfg: ModelConfig,
              global_batch: int) -> P:
    """Batch inputs: batch over ("pod","data"); seq over "data" if batch=1."""
    b_axes = batch_axes(mesh)
    bsz = _batch_size(mesh)
    spec: list = [None] * len(shape)
    if not shape:
        return P()
    if shape[0] == global_batch and global_batch % max(bsz, 1) == 0 and bsz > 1:
        spec[0] = b_axes if len(b_axes) > 1 else b_axes[0]
    elif len(shape) >= 2 and "data" in mesh.axis_names:
        # batch not shardable (e.g. 1): sequence parallelism over "data"
        if shape[1] % _axis_size(mesh, "data") == 0 and shape[1] >= 2 * _axis_size(mesh, "data"):
            spec[1] = "data"
    return P(*spec)


def cache_spec(path: str, shape: Tuple[int, ...], mesh: Mesh, cfg: ModelConfig,
               global_batch: int) -> P:
    model_n = _axis_size(mesh, "model")
    b_axes = batch_axes(mesh)
    bsz = _batch_size(mesh)
    spec: list = [None] * len(shape)
    if len(shape) == 0:
        return P()
    dims = list(range(len(shape)))
    # batch dim: first dim whose size == global_batch and shards evenly
    for d in dims:
        if shape[d] == global_batch and global_batch % max(bsz, 1) == 0 and bsz > 1:
            spec[d] = b_axes if len(b_axes) > 1 else b_axes[0]
            dims.remove(d)
            break
    # model dim: kv-heads if they divide; else largest divisible dim
    head_like = [d for d in dims
                 if shape[d] in (cfg.num_kv_heads, cfg.num_heads)
                 and shape[d] % model_n == 0 and model_n > 1]
    if head_like:
        spec[head_like[0]] = "model"
    else:
        cands = [d for d in dims
                 if model_n > 1 and shape[d] % model_n == 0 and shape[d] >= 2 * model_n]
        if cands:
            spec[max(cands, key=lambda d: shape[d])] = "model"
    return P(*spec)


def shard_inputs(abstract_inputs: Any, mesh: Mesh, cfg: ModelConfig,
                 shape_cell: ShapeCell, *, is_cache: bool = False) -> Any:
    """Sharding tree for the step-function inputs of one dry-run cell.

    ``is_cache=True`` forces :func:`cache_spec` for every leaf (the cache
    argument is passed as a bare tree, so its leaf paths carry no "cache"
    marker).
    """

    def one(path, leaf):
        pstr = _leaf_path_str(path)
        if is_cache or "cache" in pstr:
            sp = cache_spec(pstr, tuple(leaf.shape), mesh, cfg,
                            shape_cell.global_batch)
        else:
            sp = data_spec(tuple(leaf.shape), mesh, cfg, shape_cell.global_batch)
        return NamedSharding(mesh, sp)

    return jax.tree_util.tree_map_with_path(one, abstract_inputs)
