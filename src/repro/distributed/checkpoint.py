"""Sharded, step-granular checkpointing (tensorstore-free).

Layout: ``<dir>/step_<N>/`` containing one ``.npy`` per pytree leaf (named
by its flattened key path) plus ``manifest.json`` with the treedef, dtypes,
shapes and user metadata (data-iterator state, proxy snapshot, mesh shape).
Writes are atomic (tmp dir + rename); ``latest_step`` scans committed
checkpoints only, so a crash mid-write never corrupts restore.

At 1000+-node scale each host writes only the leaves it owns
(``process_index`` filtering hook) — on this single-process container that
degenerates to a full write, but the addressing scheme (leaf path →
file) is the same one a multi-host deployment shards by.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")
_COMMIT = "manifest.json"


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[Dict] = None) -> str:
    """Atomically write ``tree`` as ``<directory>/step_<step>``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = tempfile.mkdtemp(prefix=f".step_{step}_", dir=directory)
    try:
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        names, dtypes = [], []
        for path, leaf in flat:
            name = _leaf_name(path)
            base = name
            i = 0
            while name in names:  # disambiguate collisions deterministically
                i += 1
                name = f"{base}__{i}"
            names.append(name)
            arr = np.asarray(leaf)
            dtypes.append(str(arr.dtype))
            if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
                # non-native dtypes (bf16, fp8) stored as f32 — exact for bf16
                arr = arr.astype(np.float32)
            np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest = {
            "step": step,
            "leaves": names,
            "dtypes": dtypes,
            "treedef": str(treedef),
            "metadata": metadata or {},
        }
        # manifest written last = commit marker
        with open(os.path.join(tmp, _COMMIT), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(directory: str) -> Optional[int]:
    """Largest committed step, or None."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for entry in os.listdir(directory):
        m = _STEP_RE.match(entry)
        if m and os.path.exists(os.path.join(directory, entry, _COMMIT)):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any,
                       ) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, _COMMIT)) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    if len(flat) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"template has {len(flat)}")
    out = []
    for (p, leaf), name in zip(flat, manifest["leaves"]):
        arr = np.load(os.path.join(path, name + ".npy"))
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"leaf {name}: checkpoint shape {arr.shape} != {want_shape}")
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = jnp.asarray(arr).astype(leaf.dtype)  # handles bf16 etc.
        out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out), manifest["metadata"]


def restore_latest(directory: str, like: Any) -> Optional[Tuple[int, Any, Dict]]:
    step = latest_step(directory)
    if step is None:
        return None
    tree, meta = restore_checkpoint(directory, step, like)
    return step, tree, meta


def prune_checkpoints(directory: str, keep: int = 3) -> None:
    """Remove all but the newest ``keep`` committed checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(m.group(1)) for m in (_STEP_RE.match(e) for e in os.listdir(directory))
        if m and os.path.exists(os.path.join(directory, f"step_{m.group(1)}", _COMMIT))
    )
    for s in steps[:-keep] if keep > 0 else steps:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)
