"""Gradient compression for the inter-pod (DP) reduction.

At 2+ pods the gradient all-reduce crosses the slow inter-pod links once
per step; compressing that traffic is the standard lever (DESIGN.md §4).
Two composable schemes, both pure-JAX and usable as hooks around
``adamw.apply_updates``:

* :func:`int8_compress` / :func:`int8_decompress` — per-tensor symmetric
  int8 quantization (4× traffic reduction vs f32, 2× vs bf16) with an f32
  scale per leaf.
* :class:`TopKCompressor` — top-k magnitude sparsification with **error
  feedback** (the residual is carried and added to the next step's
  gradient, preserving convergence; Stich et al., 2018).

These compress the *representation*; the actual collective runs on the
compressed payload (values + indices) under any reduction the caller
wires (psum of dense int32-decoded tensors, or gather-based sparse
aggregation). The hooks are exercised by unit tests and available to the
train driver via ``TrainConfig.optimizer`` wrapping.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------- int8
def int8_compress(tree: Any) -> Any:
    """Per-leaf symmetric int8 quantization: leaf → (q int8, scale f32)."""

    def one(x):
        if not jnp.issubdtype(x.dtype, jnp.floating):
            return (x, None)
        xf = x.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        return (q, scale)

    return jax.tree.map(one, tree)


def int8_decompress(ctree: Any, like: Any) -> Any:
    """Inverse of :func:`int8_compress` (dtype restored from ``like``)."""
    flat_c, _ = jax.tree.flatten(ctree, is_leaf=lambda t: isinstance(t, tuple))
    flat_l, treedef = jax.tree.flatten(like)
    out = []
    for (q, scale), ref in zip(flat_c, flat_l):
        if scale is None:
            out.append(q)
        else:
            out.append((q.astype(jnp.float32) * scale).astype(ref.dtype))
    return jax.tree.unflatten(treedef, out)


# ------------------------------------------------------------------- top-k
@dataclasses.dataclass
class TopKState:
    residual: Any  # error-feedback memory, same structure as grads


class TopKCompressor:
    """Top-k magnitude sparsification with error feedback.

    ``compress`` returns (values, indices) per leaf covering ``fraction``
    of the entries; the untransmitted remainder accumulates in the
    residual and is re-injected next step.
    """

    def __init__(self, fraction: float = 0.01):
        if not 0 < fraction <= 1:
            raise ValueError("fraction must be in (0, 1]")
        self.fraction = fraction

    def init(self, grads: Any) -> TopKState:
        return TopKState(residual=jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads))

    def compress(self, grads: Any, state: TopKState
                 ) -> Tuple[Any, TopKState]:
        frac = self.fraction

        def one(g, r):
            gf = g.astype(jnp.float32) + r
            flat = gf.reshape(-1)
            k = max(1, int(flat.shape[0] * frac))
            vals, idx = jax.lax.top_k(jnp.abs(flat), k)
            sel = flat[idx]
            kept = jnp.zeros_like(flat).at[idx].set(sel)
            new_r = flat - kept  # error feedback
            return (sel, idx, g.shape), new_r.reshape(g.shape)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = jax.tree.leaves(state.residual)
        outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        payload = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_state = TopKState(residual=jax.tree.unflatten(
            treedef, [o[1] for o in outs]))
        return payload, new_state

    @staticmethod
    def decompress(payload: Any, like: Any) -> Any:
        flat_p, _ = jax.tree.flatten(
            payload, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
        flat_l, treedef = jax.tree.flatten(like)
        out = []
        for (vals, idx, shape), ref in zip(flat_p, flat_l):
            dense = jnp.zeros(int(jnp.prod(jnp.asarray(shape))),
                              jnp.float32).at[idx].set(vals)
            out.append(dense.reshape(shape).astype(ref.dtype))
        return jax.tree.unflatten(treedef, out)

    def compressed_bytes(self, grads: Any) -> int:
        total = 0
        for g in jax.tree.leaves(grads):
            k = max(1, int(g.size * self.fraction))
            total += k * (4 + 4)  # f32 value + int32 index
        return total
