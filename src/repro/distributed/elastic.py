"""Elastic scaling: resume work on a different mesh than it was saved from.

Checkpoints (``repro.distributed.checkpoint``) store full (unsharded)
arrays addressed by leaf path, so elasticity is a *placement* decision at
restore time: ``reshard(tree, mesh, cfg)`` computes fresh parameter
shardings for the new mesh and ``device_put``s accordingly. A job saved on
a 2-pod mesh restores onto 1 pod (or a differently-shaped debug mesh)
without any format conversion; only divisibility constraints re-derive.

For the serving path, elasticity is live: ``ReplicaPool.scale_to`` adds or
retires replicas, and the proxy control-plane snapshot (monitor windows,
AIMD state) carries over verbatim — a resized deployment resumes with
learned latency statistics instead of cold-starting the controller.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.distributed import checkpoint as ckpt
from repro.distributed.sharding import shard_params


def reshard(tree: Any, mesh, cfg: ModelConfig) -> Any:
    """Place a (host) pytree onto ``mesh`` with freshly derived shardings."""
    shardings = shard_params(tree, mesh, cfg)
    return jax.device_put(tree, shardings)


def restore_elastic(directory: str, like: Any, mesh, cfg: ModelConfig,
                    step: Optional[int] = None) -> Tuple[int, Any, dict]:
    """Restore the latest (or given) checkpoint onto an arbitrary mesh."""
    if step is None:
        step = ckpt.latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    tree, meta = ckpt.restore_checkpoint(directory, step, like)
    return step, reshard(tree, mesh, cfg), meta
