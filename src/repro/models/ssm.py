"""Mamba-2 (SSD) blocks: chunked parallel scan for train/prefill, O(1)
recurrent step for decode.

The SSD form (Dao & Gu, 2024) computes, per head with state size N and
head dim P:

    h_t = exp(A·dt_t) · h_{t-1} + dt_t · B_t ⊗ x_t
    y_t = C_t · h_t + D · x_t

The chunked algorithm splits the sequence into chunks of length Q: an
intra-chunk quadratic term (masked by the cumulative decay), a per-chunk
final state, an inter-chunk state recurrence (scan over chunks) and a
state-to-output term. All matmuls are MXU-shaped; the chunk length is the
natural Pallas block size (see ``repro.kernels.ssd_scan``). ``ngroups=1``
(B and C shared across heads), matching the released Mamba-2 configs.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_mamba2(key, d_model: int, d_state: int, dtype,
                expand: int = 2, head_dim: int = 64, conv_width: int = 4) -> dict:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 5)
    conv_dim = d_inner + 2 * d_state  # x, B, C all pass the causal conv
    return {
        # fused input projection: [z, x, B, C, dt]
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner + 2 * d_state + n_heads, dtype),
        "conv": (jax.random.normal(ks[1], (conv_width, conv_dim)) * 0.1).astype(dtype),
        "conv_bias": jnp.zeros((conv_dim,), dtype=dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), dtype=jnp.float32),
        "d_skip": jnp.ones((n_heads,), dtype=jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype=dtype),
        "out_proj": dense_init(ks[2], d_inner, d_model, dtype,
                               scale=1.0 / math.sqrt(d_inner)),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """Stable 'segment sum': out[..., i, j] = sum_{k=j+1..i} x[..., k], -inf for j>i."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, chunk: int = 128,
                h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (pure-jnp oracle for the Pallas kernel).

    Shapes: x (B,S,H,P); dt (B,S,H) (already softplus'd, >0); a (H,)
    (negative); b, c (B,S,N) shared across heads; h0 optional (B,H,P,N).
    Returns (y (B,S,H,P), h_final (B,H,P,N)). f32 internally.
    """
    bs, s, h, p = x.shape
    n = b.shape[-1]
    nc = math.ceil(s / chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    # Tensor operands stay in the model dtype (bf16 on TPU): the big HBM
    # reads (x, B, C and the (Q×Q) score/decay products) halve vs wholesale
    # f32 upcasting, while einsum accumulation stays f32 via
    # preferred_element_type (§Perf hillclimb: zamba2 train memory term).
    xf = x.reshape(bs, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bs, nc, chunk, h)
    bf = b.reshape(bs, nc, chunk, n)
    cf = c.reshape(bs, nc, chunk, n)
    f32 = jnp.float32

    da = dtf * a[None, None, None, :]  # (B,C,Q,H) log-decay per step
    da_cum = jnp.cumsum(da, axis=2)  # within-chunk cumulative
    da_total = da_cum[:, :, -1, :]  # (B,C,H)

    # 1) intra-chunk (quadratic) term
    l = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))  # (B,C,H,Q,Q) f32
    cb = jnp.einsum("bzqn,bzkn->bzqk", cf, bf,
                    preferred_element_type=f32)  # (B,C,Q,Q)
    w = (cb[:, :, None] * l * dtf.transpose(0, 1, 3, 2)[:, :, :, None, :]
         ).astype(x.dtype)  # (B,C,H,Q,Q) — one f32 product, read back at bf16
    y_diag = jnp.einsum("bzhqk,bzkhp->bzqhp", w, xf,
                        preferred_element_type=f32)

    # 2) per-chunk final states: decay from position to chunk end
    decay_to_end = jnp.exp(da_total[:, :, None, :] - da_cum)  # (B,C,Q,H)
    bw = (bf[:, :, :, None, :] * (decay_to_end * dtf)[..., None]
          ).astype(x.dtype)  # (B,C,Q,H,N)
    states = jnp.einsum("bzqhn,bzqhp->bzhpn", bw, xf,
                        preferred_element_type=f32)  # (B,C,H,P,N)

    # 3) inter-chunk recurrence (scan over chunk axis)
    def body(h_prev, inp):
        st, dtot = inp  # (B,H,P,N), (B,H)
        h_new = h_prev * jnp.exp(dtot)[:, :, None, None] + st
        return h_new, h_prev

    init = h0.astype(jnp.float32) if h0 is not None else jnp.zeros((bs, h, p, n), jnp.float32)
    h_last, h_prevs = jax.lax.scan(
        body,
        init,
        (states.transpose(1, 0, 2, 3, 4), da_total.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # (B,C,H,P,N) state entering chunk

    # 4) state-to-output: decay from chunk start to position
    decay_from_start = jnp.exp(da_cum)  # (B,C,Q,H)
    y_off = jnp.einsum("bzqn,bzqh,bzhpn->bzqhp",
                       cf.astype(f32), decay_from_start, h_prevs)

    y = (y_diag + y_off).reshape(bs, nc * chunk, h, p)
    if pad:
        y = y[:, :s]
    return y.astype(x.dtype), h_last


def ssd_reference(x, dt, a, b, c, h0=None):
    """Sequential per-step oracle (slow; tests only)."""
    bs, s, h, p = x.shape
    n = b.shape[-1]
    hstate = (h0.astype(jnp.float32) if h0 is not None
              else jnp.zeros((bs, h, p, n), jnp.float32))
    ys = []
    for t in range(s):
        dtt = dt[:, t].astype(jnp.float32)  # (B,H)
        decay = jnp.exp(dtt * a[None, :])  # (B,H)
        inject = jnp.einsum("bh,bhp,bn->bhpn", dtt, x[:, t].astype(jnp.float32),
                            b[:, t].astype(jnp.float32))
        hstate = hstate * decay[:, :, None, None] + inject
        ys.append(jnp.einsum("bhpn,bn->bhp", hstate, c[:, t].astype(jnp.float32)))
    return jnp.stack(ys, axis=1).astype(x.dtype), hstate


def ssd_step(hstate, x_t, dt_t, a, b_t, c_t):
    """One decode step. hstate (B,H,P,N); x_t (B,H,P); dt_t (B,H);
    b_t, c_t (B,N). Returns (y_t (B,H,P), new state)."""
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf * a[None, :])
    inject = jnp.einsum("bh,bhp,bn->bhpn", dtf, x_t.astype(jnp.float32),
                        b_t.astype(jnp.float32))
    h_new = hstate * decay[:, :, None, None] + inject
    y = jnp.einsum("bhpn,bn->bhp", h_new, c_t.astype(jnp.float32))
    return y, h_new


# --------------------------------------------------------------- full block
def _causal_conv(seq: jax.Array, w: jax.Array, bias: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d. seq (B,S,C); w (W,C). Returns (out, new_state)
    where state carries the last W-1 inputs for streaming decode."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((seq.shape[0], width - 1, seq.shape[-1]), seq.dtype)
    else:
        pad = state.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)
    out = sum(full[:, i:i + seq.shape[1]] * w[i][None, None, :] for i in range(width))
    new_state = full[:, -(width - 1):] if width > 1 else None
    return out + bias[None, None, :], new_state


def mamba2_forward(p: dict, x: jax.Array, *, d_state: int, head_dim: int = 64,
                   chunk: int = 128, state: Optional[dict] = None,
                   ) -> Tuple[jax.Array, dict]:
    """Full Mamba-2 mixer. x: (B, S, D) → (B, S, D).

    ``state`` (for streaming decode) carries {"h": (B,H,P,N), "conv": (B,W-1,C)}.
    Pass state=None for train/prefill-from-scratch (returns final state).
    """
    bsz, s, d_model = x.shape
    d_inner = p["out_proj"].shape[0]
    n_heads = p["a_log"].shape[0]

    zxbcdt = x @ p["in_proj"]
    z, xin, b, c, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + d_state,
                 2 * d_inner + 2 * d_state], axis=-1)
    conv_in = jnp.concatenate([xin, b, c], axis=-1)
    conv_out, conv_state = _causal_conv(
        conv_in, p["conv"], p["conv_bias"],
        state["conv"] if state is not None else None)
    conv_out = jax.nn.silu(conv_out)
    xin, b, c = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = -jnp.exp(p["a_log"])  # (H,) negative decay rates
    xh = xin.reshape(bsz, s, n_heads, head_dim)

    h0 = state["h"] if state is not None else None
    if s == 1 and state is not None:
        y, h_last = ssd_step(h0, xh[:, 0], dt[:, 0], a, b[:, 0], c[:, 0])
        y = y[:, None]
    else:
        y, h_last = ssd_chunked(xh, dt, a, b, c, chunk=chunk, h0=h0)
    y = y + xh.astype(y.dtype) * p["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, s, d_inner)

    # gated RMS norm (mamba2's norm-before-out-proj, gated by z)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    out = (yf.astype(x.dtype)) @ p["out_proj"]
    new_state = {"h": h_last, "conv": conv_state}
    return out, new_state


def init_mamba2_state(bsz: int, d_model: int, d_state: int, dtype,
                      expand: int = 2, head_dim: int = 64,
                      conv_width: int = 4) -> dict:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    return {
        "h": jnp.zeros((bsz, n_heads, head_dim, d_state), jnp.float32),
        "conv": jnp.zeros((bsz, conv_width - 1, conv_dim), dtype),
    }
