"""Zamba2-style hybrid: a stack of Mamba-2 blocks with a single *shared*
transformer block (attention + SwiGLU FFN, one set of weights) applied
before every ``attn_every``-th Mamba block. Each application of the shared
block has its own KV cache ("apps" axis).

Layer scan carries the hidden state; the shared block lives outside the
scanned params and is applied under ``lax.cond`` keyed on a per-layer flag,
so the 38-layer stack still lowers to a single compact scan.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    apply_rope,
    dense_init,
    embed_init,
    init_mlp,
    make_norm,
    mlp,
    rope_frequencies,
    softmax_cross_entropy,
)
from repro.utils.scan import maybe_scan
from repro.distributed.constraint import shard_activation

Params = Dict[str, Any]


def n_attn_apps(cfg: ModelConfig) -> int:
    return sum(1 for i in range(cfg.num_layers) if i % cfg.attn_every == 0)


def _attn_flags(cfg: ModelConfig) -> jnp.ndarray:
    flags = jnp.asarray(
        [i % cfg.attn_every == 0 for i in range(cfg.num_layers)], jnp.bool_)
    app_idx = jnp.cumsum(flags.astype(jnp.int32)) - 1  # index into the apps axis
    return flags, app_idx


def init_params(cfg: ModelConfig, key) -> Params:
    init_norm, _ = make_norm(cfg.norm)
    k_emb, k_layers, k_attn, k_mlp, k_head = jax.random.split(key, 5)

    def init_layer(k):
        return {
            "norm": init_norm(cfg.d_model, cfg.dtype),
            "mamba": ssm_lib.init_mamba2(
                k, cfg.d_model, cfg.ssm_state, cfg.dtype,
                head_dim=cfg.ssm_head_dim),
        }

    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    return {
        "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "layers": jax.vmap(init_layer)(layer_keys),
        "shared": {
            "attn_norm": init_norm(cfg.d_model, cfg.dtype),
            "attn": attn_lib.init_attention(
                k_attn, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.hd, cfg.dtype),
            "mlp_norm": init_norm(cfg.d_model, cfg.dtype),
            "mlp": init_mlp(k_mlp, cfg.d_model, cfg.d_ff, cfg.activation, cfg.dtype),
        },
        "final_norm": init_norm(cfg.d_model, cfg.dtype),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab_size, cfg.dtype,
                              scale=1.0 / math.sqrt(cfg.d_model)),
    }


def _shared_block(cfg: ModelConfig, shared: Params, x, cos, sin, positions,
                  mode: str, kv=None, cache_len=None):
    """One application of the shared attention+FFN block."""
    _, norm = make_norm(cfg.norm)
    h = norm(shared["attn_norm"], x)
    q, k, v = attn_lib.qkv_proj(shared["attn"], h, cfg.num_heads,
                                cfg.num_kv_heads, cfg.hd)
    q = apply_rope(q, cos, sin, positions)
    k = apply_rope(k, cos, sin, positions)
    if mode == "decode":
        k_cache, v_cache = kv
        k_cache, v_cache = attn_lib.cache_update_layer(
            k_cache, v_cache, k, v, cache_len)
        out = attn_lib.decode_attention(q, k_cache, v_cache, cache_len + 1)
        kv_out = (k_cache, v_cache)
    else:
        # NOTE(§Perf): head-sharding q/k/v here (kv=32 divides the mesh) was
        # measured and REFUTED — it fights the sharding the surrounding
        # Mamba layers propagate and triples collective volume (36.6 →
        # 92 GB per 6 layers, 205 collective-permutes). Sequence-parallel
        # K/V is the right layout inside a hybrid stack.
        k = shard_activation(k, ("pod", "data"), "model", None, None)
        v = shard_activation(v, ("pod", "data"), "model", None, None)
        out = attn_lib.chunked_attention(q, k, v, causal=True,
                                         q_chunk=cfg.attn_q_chunk)
        kv_out = (k, v)
    b, s = x.shape[:2]
    out = out.reshape(b, s, cfg.num_heads * cfg.hd) @ shared["attn"]["wo"]
    x = x + out
    x = x + mlp(shared["mlp"], norm(shared["mlp_norm"], x), cfg.activation)
    return x, kv_out


def forward(cfg: ModelConfig, params: Params, tokens) -> Tuple[jax.Array, jax.Array]:
    _, norm = make_norm(cfg.norm)
    x = shard_activation((params["embed"][tokens]).astype(cfg.cdtype),
                         ("pod", "data"), None, None)
    b, s = x.shape[:2]
    cos, sin = rope_frequencies(cfg.hd, s, cfg.rope_theta)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    flags, _ = _attn_flags(cfg)
    shared = params["shared"]

    def body(carry, inp):
        x, = carry
        layer, is_attn = inp
        x = jax.lax.cond(
            is_attn,
            lambda x: _shared_block(cfg, shared, x, cos, sin, positions, "train")[0],
            lambda x: x,
            x,
        )
        h, _ = ssm_lib.mamba2_forward(
            layer["mamba"], norm(layer["norm"], x),
            d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk)
        return (x + h,), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x,), _ = maybe_scan(body, (x,), (params["layers"], flags),
                         unroll=not cfg.scan_layers)
    x = norm(params["final_norm"], x)
    w = shard_activation(params["lm_head"], None, "model")
    logits = shard_activation(x @ w.astype(x.dtype),
                              ("pod", "data"), None, "model")
    return logits.astype(jnp.float32), jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    logits, _ = forward(cfg, params, batch.get("inputs", batch.get("tokens")))
    return softmax_cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


# ------------------------------------------------------------------ serving
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    apps = n_attn_apps(cfg)
    d_inner = 2 * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return {
        "attn_k": jnp.zeros((apps, batch, max_len, cfg.num_kv_heads, cfg.hd), cfg.cdtype),
        "attn_v": jnp.zeros((apps, batch, max_len, cfg.num_kv_heads, cfg.hd), cfg.cdtype),
        "ssm_h": jnp.zeros((cfg.num_layers, batch, n_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32),
        "ssm_conv": jnp.zeros((cfg.num_layers, batch, 3, conv_dim), cfg.cdtype),
        "len": jnp.zeros((), jnp.int32),
    }


def _run_with_cache(cfg: ModelConfig, params: Params, tokens, cache, mode: str):
    _, norm = make_norm(cfg.norm)
    x = shard_activation((params["embed"][tokens]).astype(cfg.cdtype),
                         ("pod", "data"), None, None)
    b, s = x.shape[:2]
    cos, sin = rope_frequencies(cfg.hd, cfg.max_seq_len, cfg.rope_theta)
    cache_len = cache["len"]
    if mode == "decode":
        positions = jnp.broadcast_to(cache_len[None, None], (b, 1)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    flags, app_idx = _attn_flags(cfg)
    shared = params["shared"]
    attn_k, attn_v = cache["attn_k"], cache["attn_v"]

    def body(carry, inp):
        x, attn_k, attn_v = carry
        layer, is_attn, app, h0, conv0 = inp

        def with_attn(x, ak, av):
            if mode == "decode":
                kv = (ak[app], av[app])
                x, (k_new, v_new) = _shared_block(
                    cfg, shared, x, cos, sin, positions, "decode",
                    kv=kv, cache_len=cache_len)
                ak = ak.at[app].set(k_new)
                av = av.at[app].set(v_new)
            else:
                x, (k, v) = _shared_block(cfg, shared, x, cos, sin, positions, mode)
                ak = jax.lax.dynamic_update_slice(
                    ak, k.astype(ak.dtype)[None], (app, 0, 0, 0, 0))
                av = jax.lax.dynamic_update_slice(
                    av, v.astype(av.dtype)[None], (app, 0, 0, 0, 0))
            return x, ak, av

        x, attn_k, attn_v = jax.lax.cond(
            is_attn, with_attn, lambda x, ak, av: (x, ak, av), x, attn_k, attn_v)
        state = {"h": h0, "conv": conv0}
        h, new_state = ssm_lib.mamba2_forward(
            layer["mamba"], norm(layer["norm"], x),
            d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
            chunk=cfg.ssm_chunk, state=state)
        return (x + h, attn_k, attn_v), (new_state["h"], new_state["conv"])

    (x, attn_k, attn_v), (hs, convs) = maybe_scan(
        body, (x, attn_k, attn_v),
        (params["layers"], flags, app_idx, cache["ssm_h"], cache["ssm_conv"]),
        unroll=not cfg.scan_layers)
    new_cache = {
        "attn_k": attn_k, "attn_v": attn_v,
        "ssm_h": hs, "ssm_conv": convs.astype(cache["ssm_conv"].dtype),
        "len": cache_len + (1 if mode == "decode" else s),
    }
    x = norm(params["final_norm"], x[:, -1:])
    w = shard_activation(params["lm_head"], None, "model")
    logits = shard_activation(x @ w.astype(x.dtype),
                              ("pod", "data"), None, "model")
    return logits.astype(jnp.float32), new_cache


def prefill(cfg: ModelConfig, params: Params, tokens, cache):
    return _run_with_cache(cfg, params, tokens, cache, "prefill")


def decode_step(cfg: ModelConfig, params: Params, tokens, cache):
    return _run_with_cache(cfg, params, tokens, cache, "decode")
