"""Model facade: one uniform API over all architecture families.

    model = Model(cfg)
    params = model.init(rng)
    loss   = model.loss(params, batch)
    logits, cache = model.prefill(params, inputs, cache)
    logits, cache = model.decode_step(params, tokens, cache)

plus ``input_specs(cfg, shape)`` building ShapeDtypeStruct stand-ins for the
dry-run (weak-type-correct, shardable, no device allocation) and
``make_inputs`` building real (random) inputs for smoke tests.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import encdec, hybrid, transformer, xlstm_model

Params = Dict[str, Any]

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "hybrid": hybrid,
    "ssm": xlstm_model,
    "encdec": encdec,
}


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._mod = _FAMILY_MODULES[cfg.family]

    # ------------------------------------------------------------------ api
    def init(self, rng) -> Params:
        return self._mod.init_params(self.cfg, rng)

    def init_abstract(self) -> Params:
        """Parameter pytree as ShapeDtypeStructs (no allocation)."""
        return jax.eval_shape(lambda: self._mod.init_params(
            self.cfg, jax.random.PRNGKey(0)))

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        return self._mod.loss_fn(self.cfg, params, batch)

    def forward(self, params: Params, inputs) -> jax.Array:
        logits, _ = self._mod.forward(self.cfg, params, inputs)
        return logits

    def init_cache(self, batch: int, max_len: int):
        return self._mod.init_cache(self.cfg, batch, max_len)

    def prefill(self, params: Params, inputs, cache):
        return self._mod.prefill(self.cfg, params, inputs, cache)

    def decode_step(self, params: Params, tokens, cache):
        return self._mod.decode_step(self.cfg, params, tokens, cache)


# ---------------------------------------------------------------- input specs
def _token_spec(b: int, s: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the shape's step fn.

    * ``train``:   the loss/`train_step` inputs (tokens or embeds + labels).
    * ``prefill``: prompt of ``seq_len`` tokens + an (abstract) empty cache.
    * ``decode``:  one new token + an (abstract) cache of ``seq_len``.
    """
    b, s = shape.global_batch, shape.seq_len
    model = Model(cfg)
    if shape.kind == "train":
        if cfg.family == "encdec":
            frames = jax.ShapeDtypeStruct((b, cfg.frontend_seq or s, cfg.d_model),
                                          cfg.cdtype)
            return {"batch": {"frames": frames, "tokens": _token_spec(b, s),
                              "labels": _token_spec(b, s)}}
        if cfg.embed_inputs:
            inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.cdtype)
            return {"batch": {"inputs": inputs, "labels": _token_spec(b, s)}}
        return {"batch": {"tokens": _token_spec(b, s), "labels": _token_spec(b, s)}}
    if shape.kind == "prefill":
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
        if cfg.family == "encdec":
            frames = jax.ShapeDtypeStruct((b, cfg.frontend_seq or s, cfg.d_model),
                                          cfg.cdtype)
            return {"inputs": {"frames": frames, "tokens": _token_spec(b, s)},
                    "cache": cache}
        return {"inputs": _token_spec(b, s), "cache": cache}
    if shape.kind == "decode":
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
        return {"tokens": _token_spec(b, 1), "cache": cache}
    raise ValueError(shape.kind)


def make_inputs(cfg: ModelConfig, shape: ShapeCell, rng) -> Dict[str, Any]:
    """Concrete random inputs matching :func:`input_specs` (smoke tests).

    Caches are built with the real ``init_cache`` (valid zeros + lengths),
    not random tensors.
    """
    b, s = shape.global_batch, shape.seq_len
    model = Model(cfg)
    k1, k2 = jax.random.split(rng)
    toks = lambda key, bb, ss: jax.random.randint(
        key, (bb, ss), 0, cfg.vocab_size, dtype=jnp.int32)
    if shape.kind == "train":
        labels = toks(k2, b, s)
        if cfg.family == "encdec":
            frames = jax.random.normal(
                k1, (b, cfg.frontend_seq or s, cfg.d_model)).astype(cfg.cdtype)
            return {"batch": {"frames": frames, "tokens": toks(k1, b, s),
                              "labels": labels}}
        if cfg.embed_inputs:
            inputs = jax.random.normal(k1, (b, s, cfg.d_model)).astype(cfg.cdtype)
            return {"batch": {"inputs": inputs, "labels": labels}}
        return {"batch": {"tokens": toks(k1, b, s), "labels": labels}}
    if shape.kind == "prefill":
        cache = model.init_cache(b, s)
        if cfg.family == "encdec":
            frames = jax.random.normal(
                k1, (b, cfg.frontend_seq or s, cfg.d_model)).astype(cfg.cdtype)
            return {"inputs": {"frames": frames, "tokens": toks(k2, b, s)},
                    "cache": cache}
        return {"inputs": toks(k1, b, s), "cache": cache}
    if shape.kind == "decode":
        cache = model.init_cache(b, s)
        cache = dict(cache)
        cache["len"] = jnp.asarray(s - 1, jnp.int32)
        return {"tokens": toks(k1, b, 1), "cache": cache}
    raise ValueError(shape.kind)
