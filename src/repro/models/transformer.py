"""Decoder-only transformer LM covering the dense, MoE and VLM-backbone
families. Layers are stacked and executed with ``jax.lax.scan`` (O(1) HLO in
depth — a 96-layer nemotron lowers in seconds) with optional remat.

Three entry points per model:
  * ``forward``       — logits over a full (B, S) sequence (training).
  * ``prefill``       — run the prompt, return last-position logits + cache.
  * ``decode_step``   — one token against the KV cache.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.utils.scan import maybe_scan
from repro.models.layers import (
    dense_init,
    embed_init,
    make_norm,
    mlp,
    init_mlp,
    rope_frequencies,
    apply_rope,
    softmax_cross_entropy,
)

Params = Dict[str, Any]


# ------------------------------------------------------------------- params
def init_layer(cfg: ModelConfig, key) -> Params:
    init_norm, _ = make_norm(cfg.norm)
    ka, km, kmoe = jax.random.split(key, 3)
    p: Params = {
        "attn_norm": init_norm(cfg.d_model, cfg.dtype),
        "attn": attn_lib.init_attention(
            ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd,
            cfg.dtype, qkv_bias=cfg.qkv_bias),
        "mlp_norm": init_norm(cfg.d_model, cfg.dtype),
    }
    if cfg.family == "moe":
        p["moe"] = moe_lib.init_moe(
            kmoe, cfg.d_model, cfg.num_experts, cfg.expert_d_ff,
            cfg.activation, cfg.dtype)
        if cfg.moe_shared_ffn:
            p["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, cfg.activation,
                                cfg.dtype, bias=cfg.mlp_bias)
    else:
        p["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, cfg.activation,
                            cfg.dtype, bias=cfg.mlp_bias)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    init_norm, _ = make_norm(cfg.norm)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = jax.vmap(lambda k: init_layer(cfg, k))(layer_keys)
    params: Params = {
        "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "layers": layers,
        "final_norm": init_norm(cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            k_head, cfg.d_model, cfg.vocab_size, cfg.dtype,
            scale=1.0 / math.sqrt(cfg.d_model))
    return params


# ------------------------------------------------------------------ forward
def _attention_block(cfg: ModelConfig, p: Params, x, cos, sin, positions,
                     mode: str, kv_slice=None, cache_len=None):
    """Returns (attn_out, (k, v)) — k/v for cache writes."""
    from repro.distributed.constraint import ambient_mesh, shard_activation

    q, k, v = attn_lib.qkv_proj(p["attn"], x, cfg.num_heads, cfg.num_kv_heads, cfg.hd)
    q = apply_rope(q, cos, sin, positions)
    k = apply_rope(k, cos, sin, positions)
    if mode == "train" or mode == "prefill":
        mesh = ambient_mesh()
        model_n = mesh.shape["model"] if (
            mesh is not None and "model" in mesh.axis_names) else 1
        if (model_n > 1 and cfg.num_heads % model_n == 0
                and cfg.num_kv_heads % model_n == 0):
            # Tensor-parallel heads — only when BOTH q and kv heads divide
            # the model axis, so attention is fully local per head shard.
            # Measured (§Perf): q-only head sharding with replicated kv is
            # WORSE than sequence-parallel (GSPMD re-gathers at the GQA
            # einsum); with both sharded, nemotron on a (64,4) mesh drops
            # from 68.3 to 29.8 GB collective per layer.
            q = shard_activation(q, ("pod", "data"), None, "model", None)
            k = shard_activation(k, ("pod", "data"), None, "model", None)
            v = shard_activation(v, ("pod", "data"), None, "model", None)
        else:
            # Context-parallel K/V: shard the key sequence over "model" so
            # the (q_chunk × S) score tensors shard too (softmax reductions
            # become psums). Fallback when heads don't divide the mesh —
            # unsharded scores dominate activation memory at 32k prefill.
            k = shard_activation(k, ("pod", "data"), "model", None, None)
            v = shard_activation(v, ("pod", "data"), "model", None, None)
        if cfg.use_pallas:
            from repro.kernels import ops as kernel_ops

            out = kernel_ops.flash_attention(q, k, v, causal=True)
        else:
            out = attn_lib.chunked_attention(
                q, k, v, causal=True, q_chunk=cfg.attn_q_chunk)
    elif mode == "decode":
        k_cache, v_cache = kv_slice
        k_cache, v_cache = attn_lib.cache_update_layer(
            k_cache, v_cache, k, v, cache_len)
        if cfg.use_pallas:
            from repro.kernels import ops as kernel_ops

            out = kernel_ops.decode_attention(q, k_cache, v_cache, cache_len + 1)
        else:
            out = attn_lib.decode_attention(q, k_cache, v_cache, cache_len + 1)
        k, v = k_cache, v_cache  # updated full caches are passed back
    else:
        raise ValueError(mode)
    b, s = x.shape[:2]
    out = out.reshape(b, s, cfg.num_heads * cfg.hd)
    return out @ p["attn"]["wo"], (k, v)


def _ffn_block(cfg: ModelConfig, p: Params, x):
    """Dense or MoE FFN; returns (out, aux_loss)."""
    if cfg.family == "moe":
        routed, aux = moe_lib.moe_ffn(
            p["moe"], x, top_k=cfg.num_experts_per_tok,
            capacity_factor=cfg.capacity_factor, activation=cfg.activation)
        if cfg.moe_shared_ffn:
            routed = routed + mlp(p["mlp"], x, cfg.activation)
        return routed, aux
    return mlp(p["mlp"], x, cfg.activation), jnp.zeros((), jnp.float32)


def _make_layer_fn(cfg: ModelConfig, cos, sin, mode: str):
    _, norm = make_norm(cfg.norm)

    def layer_fn(carry, layer_params, kv_slice=None):
        if mode == "decode":
            x, positions, cache_len = carry
        else:
            x, positions = carry
            cache_len = None
        h, kv = _attention_block(
            cfg, layer_params, norm(layer_params["attn_norm"], x),
            cos, sin, positions, mode,
            kv_slice=kv_slice, cache_len=cache_len)
        x = x + h
        h, aux = _ffn_block(cfg, layer_params, norm(layer_params["mlp_norm"], x))
        x = x + h
        return x, kv, aux

    return layer_fn


def _embed_tokens(cfg: ModelConfig, params: Params, tokens_or_embeds):
    from repro.distributed.constraint import shard_activation

    if tokens_or_embeds.dtype in (jnp.int32, jnp.int64):
        x = params["embed"][tokens_or_embeds]
    else:
        x = tokens_or_embeds.astype(cfg.dtype)  # modality-frontend embeddings
    # Pin the residual stream to batch-sharded right at the top: the gather
    # from a (vocab→model, d→data)-sharded table otherwise leaves the
    # output's batch dim replicated and everything downstream inherits it.
    x = shard_activation(x, ("pod", "data"), None, None)
    return x.astype(cfg.cdtype)


def _unembed(cfg: ModelConfig, params: Params, x) -> jax.Array:
    from repro.distributed.constraint import shard_activation

    w = params.get("lm_head")
    if w is None:
        w = params["embed"].T
    # Resolve the FSDP shard of the weight *before* the matmul: gathering
    # the (D, V/model) weight is MBs; letting GSPMD align the contraction
    # by resharding activations costs an all-gather of the whole batch.
    w = shard_activation(w, None, "model")
    logits = x @ w.astype(x.dtype)
    # (B, S, V): batch over DP axes, vocab over TP — without this the
    # partitioner can materialize a replicated (tokens × vocab) tensor.
    logits = shard_activation(logits, ("pod", "data"), None, "model")
    return logits.astype(jnp.float32)


def forward(cfg: ModelConfig, params: Params, tokens_or_embeds,
            ) -> Tuple[jax.Array, jax.Array]:
    """Training/eval forward pass → (logits (B,S,V) f32, moe aux loss)."""
    _, norm = make_norm(cfg.norm)
    x = _embed_tokens(cfg, params, tokens_or_embeds)
    b, s = x.shape[:2]
    cos, sin = rope_frequencies(cfg.hd, s, cfg.rope_theta)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    layer_fn = _make_layer_fn(cfg, cos, sin, "train")

    def scan_body(carry, layer_params):
        x, positions = carry
        x, _, aux = layer_fn((x, positions), layer_params)
        return (x, positions), aux

    if cfg.remat:
        scan_body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, _), auxs = maybe_scan(scan_body, (x, positions), params["layers"],
                              unroll=not cfg.scan_layers)
    aux = jnp.sum(auxs)
    x = norm(params["final_norm"], x)
    return _unembed(cfg, params, x), aux


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            aux_weight: float = 0.01) -> jax.Array:
    inputs = batch.get("inputs", batch.get("tokens"))
    logits, aux = forward(cfg, params, inputs)
    loss = softmax_cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
    return loss + aux_weight * aux


# ------------------------------------------------------------------ serving
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, jax.Array]:
    return attn_lib.init_kv_cache(
        cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.hd, cfg.cdtype)


def prefill(cfg: ModelConfig, params: Params, tokens, cache: Dict[str, jax.Array],
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Run the prompt; write K/V for all layers; return last-pos logits."""
    _, norm = make_norm(cfg.norm)
    x = _embed_tokens(cfg, params, tokens)
    b, s = x.shape[:2]
    cos, sin = rope_frequencies(cfg.hd, cfg.max_seq_len, cfg.rope_theta)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    layer_fn = _make_layer_fn(cfg, cos, sin, "prefill")

    def scan_body(carry, layer_params):
        x, positions = carry
        x, kv, _ = layer_fn((x, positions), layer_params)
        return (x, positions), kv

    if cfg.remat:
        scan_body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, _), (ks, vs) = maybe_scan(scan_body, (x, positions), params["layers"],
                                  unroll=not cfg.scan_layers)
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["len"] = jnp.asarray(s, jnp.int32)
    x_last = norm(params["final_norm"], x[:, -1:])
    return _unembed(cfg, params, x_last), cache


def decode_step(cfg: ModelConfig, params: Params, tokens,
                cache: Dict[str, jax.Array],
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step. tokens: (B, 1) int32 → (logits (B,1,V), cache)."""
    _, norm = make_norm(cfg.norm)
    x = _embed_tokens(cfg, params, tokens)
    b = x.shape[0]
    cache_len = cache["len"]
    cos, sin = rope_frequencies(cfg.hd, cfg.max_seq_len, cfg.rope_theta)
    positions = jnp.broadcast_to(cache_len[None, None], (b, 1)).astype(jnp.int32)
    layer_fn = _make_layer_fn(cfg, cos, sin, "decode")

    def scan_body(carry, inp):
        layer_params, k_slice, v_slice = inp
        x, positions, clen = carry
        x, (k_new, v_new), _ = layer_fn(
            (x, positions, clen), layer_params, kv_slice=(k_slice, v_slice))
        return (x, positions, clen), (k_new, v_new)

    (x, _, _), (ks, vs) = maybe_scan(
        scan_body, (x, positions, cache_len),
        (params["layers"], cache["k"], cache["v"]),
        unroll=not cfg.scan_layers)
    cache = dict(cache)
    cache["k"], cache["v"] = ks, vs
    cache["len"] = cache_len + 1
    x = norm(params["final_norm"], x)
    return _unembed(cfg, params, x), cache
