"""Attention: GQA with RoPE, memory-efficient chunked prefill, cached decode.

The chunked (flash-style) prefill path scans over query blocks carrying a
running (max, sum, accumulator) triple, so the full S×S score matrix is
never materialized — this is what makes 32k-token prefill lowerable at
full size. The same function doubles as the pure-jnp oracle for the Pallas
flash kernel in ``repro.kernels``; on TPU the kernel slots in behind the
``use_pallas`` flag of the model config.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


# ----------------------------------------------------------------- parameters
def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype, qkv_bias: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d_model, num_heads * head_dim, dtype),
        "wk": dense_init(kk, d_model, num_kv_heads * head_dim, dtype),
        "wv": dense_init(kv, d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ko, num_heads * head_dim, d_model, dtype,
                         scale=1.0 / math.sqrt(num_heads * head_dim)),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype=dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype=dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype=dtype)
    return p


def qkv_proj(p: dict, x: jax.Array, num_heads: int, num_kv_heads: int,
             head_dim: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (
        q.reshape(b, s, num_heads, head_dim),
        k.reshape(b, s, num_kv_heads, head_dim),
        v.reshape(b, s, num_kv_heads, head_dim),
    )


# ------------------------------------------------------------- full attention
def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Sq, Hq, D), k: (B, Sk, Hkv, D) → (B, Hkv, G, Sq, Sk)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                      k.astype(jnp.float32))


def reference_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        q_offset: int = 0) -> jax.Array:
    """Naive full-matrix GQA attention (oracle; used for small shapes)."""
    b, sq, hq, d = q.shape
    sk = k.shape[1]
    scores = _gqa_scores(q, k) / math.sqrt(d)  # (B, Hkv, G, Sq, Sk)
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        mask = kpos <= qpos  # (Sq, Sk)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


# ---------------------------------------------------------- chunked (flash)
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, q_chunk: int = 512,
                      q_offset: int = 0) -> jax.Array:
    """Flash-style attention: scan over query chunks with streaming softmax.

    Memory: O(Sq·Sk / n_chunks) scores instead of O(Sq·Sk). Equivalent to
    :func:`reference_attention` up to float error.
    """
    b, sq, hq, d = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    if sq <= q_chunk:
        return reference_attention(q, k, v, causal=causal, q_offset=q_offset)
    n_chunks = math.ceil(sq / q_chunk)
    pad = n_chunks * q_chunk - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qs = q.reshape(b, n_chunks, q_chunk, hq, d).transpose(1, 0, 2, 3, 4)

    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / math.sqrt(d)
    kpos = jnp.arange(sk)[None, :]

    def body(carry, inp):
        qc, idx = inp  # (B, C, Hq, D), scalar chunk index
        qg = qc.reshape(b, q_chunk, hkv, g, d).astype(jnp.float32)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf) * scale
        if causal:
            qpos = idx * q_chunk + jnp.arange(q_chunk)[:, None] + q_offset
            mask = kpos <= qpos  # (C, Sk)
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m = jnp.max(scores, axis=-1)
        w = jnp.exp(scores - m[..., None])
        l = jnp.sum(w, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", w, vf)
        o = o / jnp.maximum(l, 1e-30)[..., None]
        out = o.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, hq, d)
        return carry, out

    # Remat the chunk body: without this the scan stacks every chunk's
    # (B,H,C,Sk) score/softmax residuals for backward — the full O(S²)
    # matrix flash attention exists to avoid. Recompute per chunk instead.
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(body, (), (qs, jnp.arange(n_chunks)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * q_chunk, hq, d)
    if pad:
        out = out[:, :sq]
    return out.astype(q.dtype)


# --------------------------------------------------------------------- decode
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array) -> jax.Array:
    """Single-step attention over a (possibly padded) KV cache.

    q: (B, 1, Hq, D); k_cache/v_cache: (B, S_max, Hkv, D);
    cache_len: scalar or (B,) — number of valid cache entries (includes the
    token being decoded, already written into the cache).
    """
    b, _, hq, d = q.shape
    s_max, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    scores = scores / math.sqrt(d)
    pos = jnp.arange(s_max)[None, :]
    valid = pos < jnp.reshape(cache_len, (-1, 1))  # (B or 1, S_max)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)


# ------------------------------------------------------------------ KV cache
def init_kv_cache(num_layers: int, batch: int, max_len: int, num_kv_heads: int,
                  head_dim: int, dtype) -> dict:
    shape = (num_layers, batch, max_len, num_kv_heads, head_dim)
    return {
        "k": jnp.zeros(shape, dtype=dtype),
        "v": jnp.zeros(shape, dtype=dtype),
        "len": jnp.zeros((), dtype=jnp.int32),
    }


def cache_update_layer(k_cache: jax.Array, v_cache: jax.Array, k: jax.Array,
                       v: jax.Array, start: jax.Array):
    """Write (B, S, Hkv, D) at position ``start`` of one layer's cache."""
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, start, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, start, 0, 0))
    return k_cache, v_cache
