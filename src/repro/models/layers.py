"""Shared building blocks: norms, activations, RoPE, projections, embeddings.

Everything is functional: ``init_*`` builds a params pytree, the matching
apply function consumes it. Parameter dtype and compute dtype are decoupled
(bf16 params / bf16 MXU compute / f32 norm + softmax accumulation on TPU;
f32 everywhere for CPU smoke tests).
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


# ----------------------------------------------------------------- init utils
def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ----------------------------------------------------------------------- norms
def init_rmsnorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype), "bias": jnp.zeros((dim,), dtype=dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dtype)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return init_rmsnorm, rmsnorm
    if kind == "layernorm":
        return init_layernorm, layernorm
    raise ValueError(f"unknown norm {kind!r}")


# ----------------------------------------------------------------- activations
def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu":
        return jax.nn.relu
    if name == "relu2":  # squared ReLU (Primer / Nemotron-4)
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


GATED_ACTIVATIONS = ("silu",)  # gated (GLU) families use fused wi = [gate|up]


# ------------------------------------------------------------------------ FFN
def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype,
             bias: bool = False) -> Params:
    k1, k2 = jax.random.split(key)
    gated = activation in GATED_ACTIVATIONS
    wi_out = 2 * d_ff if gated else d_ff
    p = {
        "wi": dense_init(k1, d_model, wi_out, dtype),
        "wo": dense_init(k2, d_ff, d_model, dtype, scale=1.0 / math.sqrt(d_ff)),
    }
    if bias:
        p["bi"] = jnp.zeros((wi_out,), dtype=dtype)
        p["bo"] = jnp.zeros((d_model,), dtype=dtype)
    return p


def mlp(p: Params, x: jax.Array, activation: str) -> jax.Array:
    act = activation_fn(activation)
    h = x @ p["wi"]
    if "bi" in p:
        h = h + p["bi"]
    if activation in GATED_ACTIVATIONS:
        gate, up = jnp.split(h, 2, axis=-1)
        h = act(gate) * up
    else:
        h = act(h)
    out = h @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    return out


# ----------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, max_len: int, theta: float,
                     dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """Precompute (cos, sin) tables of shape (max_len, head_dim // 2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_len, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)
    return jnp.cos(freqs).astype(dtype), jnp.sin(freqs).astype(dtype)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array,
               positions: jax.Array) -> jax.Array:
    """Rotate pairs. x: (B, S, H, D); positions: (B, S) absolute indices."""
    c = cos[positions][:, :, None, :]  # (B, S, 1, D/2)
    s = sin[positions][:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- embeddings
def init_embedding(key, vocab: int, d_model: int, dtype) -> Params:
    return {"table": embed_init(key, vocab, d_model, dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return p["table"][tokens]


def unembed(p: Params, x: jax.Array, head: Optional[jax.Array]) -> jax.Array:
    """Project to vocab logits; ``head`` is None for tied embeddings."""
    w = head if head is not None else p["table"].T
    return (x @ w.astype(x.dtype)).astype(jnp.float32)


# --------------------------------------------------------------------- losses
def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token loss. logits: (..., V) f32; labels: (...) int32.

    Gather-free: the gold logit is extracted with a one-hot contraction
    instead of ``take_along_axis`` so a vocab-sharded logits tensor reduces
    with a psum rather than an all-gather (GSPMD lowers gathers over a
    sharded operand dim by gathering the operand).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
