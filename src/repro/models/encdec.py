"""Encoder-decoder transformer (SeamlessM4T v2 text/speech backbone).

The speech frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings (B, S_enc, D) to the encoder. The decoder is a
standard causal transformer with cross-attention into the encoder memory.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models.layers import (
    apply_rope,
    dense_init,
    embed_init,
    init_mlp,
    make_norm,
    mlp,
    rope_frequencies,
    softmax_cross_entropy,
)
from repro.utils.scan import maybe_scan
from repro.distributed.constraint import shard_activation

Params = Dict[str, Any]


def init_params(cfg: ModelConfig, key) -> Params:
    init_norm, _ = make_norm(cfg.norm)
    k_emb, k_enc, k_dec, k_head = jax.random.split(key, 4)

    def init_enc_layer(k):
        ka, km = jax.random.split(k)
        return {
            "attn_norm": init_norm(cfg.d_model, cfg.dtype),
            "attn": attn_lib.init_attention(
                ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd, cfg.dtype),
            "mlp_norm": init_norm(cfg.d_model, cfg.dtype),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, cfg.activation, cfg.dtype),
        }

    def init_dec_layer(k):
        ka, kc, km = jax.random.split(k, 3)
        p = init_enc_layer(jax.random.fold_in(k, 7))
        p["cross_norm"] = init_norm(cfg.d_model, cfg.dtype)
        p["cross"] = attn_lib.init_attention(
            kc, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd, cfg.dtype)
        return p

    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    return {
        "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "enc_layers": jax.vmap(init_enc_layer)(enc_keys),
        "enc_norm": init_norm(cfg.d_model, cfg.dtype),
        "dec_layers": jax.vmap(init_dec_layer)(dec_keys),
        "final_norm": init_norm(cfg.d_model, cfg.dtype),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab_size, cfg.dtype,
                              scale=1.0 / math.sqrt(cfg.d_model)),
    }


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, D) stub embeddings → encoder memory (B, S_enc, D)."""
    _, norm = make_norm(cfg.norm)
    x = shard_activation(frames.astype(cfg.cdtype), ("pod", "data"), None, None)
    b, s = x.shape[:2]
    cos, sin = rope_frequencies(cfg.hd, s, cfg.rope_theta)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(carry, layer):
        (x,) = carry
        h = norm(layer["attn_norm"], x)
        q, k, v = attn_lib.qkv_proj(layer["attn"], h, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.hd)
        q = apply_rope(q, cos, sin, positions)
        k = apply_rope(k, cos, sin, positions)
        k = shard_activation(k, ("pod", "data"), "model", None, None)
        v = shard_activation(v, ("pod", "data"), "model", None, None)
        out = attn_lib.chunked_attention(q, k, v, causal=False,
                                         q_chunk=cfg.attn_q_chunk)
        out = out.reshape(b, s, cfg.num_heads * cfg.hd) @ layer["attn"]["wo"]
        x = x + out
        x = x + mlp(layer["mlp"], norm(layer["mlp_norm"], x), cfg.activation)
        return (x,), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (x,), _ = maybe_scan(body, (x,), params["enc_layers"],
                         unroll=not cfg.scan_layers)
    return norm(params["enc_norm"], x)


def _decoder(cfg: ModelConfig, params: Params, tokens, memory, mode: str,
             cache=None):
    _, norm = make_norm(cfg.norm)
    x = shard_activation((params["embed"][tokens]).astype(cfg.cdtype),
                         ("pod", "data"), None, None)
    b, s = x.shape[:2]
    cos, sin = rope_frequencies(cfg.hd, cfg.max_seq_len, cfg.rope_theta)
    if mode == "decode":
        cache_len = cache["len"]
        positions = jnp.broadcast_to(cache_len[None, None], (b, 1)).astype(jnp.int32)
    else:
        cache_len = None
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    sm = memory.shape[1]

    def body(carry, inp):
        if mode == "decode":
            layer, k_sl, v_sl = inp
        else:
            layer = inp
        (x,) = carry
        h = norm(layer["attn_norm"], x)
        q, k, v = attn_lib.qkv_proj(layer["attn"], h, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.hd)
        q = apply_rope(q, cos, sin, positions)
        k = apply_rope(k, cos, sin, positions)
        if mode == "decode":
            k_sl, v_sl = attn_lib.cache_update_layer(k_sl, v_sl, k, v, cache_len)
            out = attn_lib.decode_attention(q, k_sl, v_sl, cache_len + 1)
            kv_out = (k_sl, v_sl)
        else:
            k = shard_activation(k, ("pod", "data"), "model", None, None)
            v = shard_activation(v, ("pod", "data"), "model", None, None)
            out = attn_lib.chunked_attention(q, k, v, causal=True,
                                             q_chunk=cfg.attn_q_chunk)
            kv_out = (k, v)
        x = x + out.reshape(b, s, cfg.num_heads * cfg.hd) @ layer["attn"]["wo"]
        # cross-attention (no positional rotation on memory keys)
        h = norm(layer["cross_norm"], x)
        qc = (h @ layer["cross"]["wq"]).reshape(b, s, cfg.num_heads, cfg.hd)
        kc = (memory @ layer["cross"]["wk"]).reshape(b, sm, cfg.num_kv_heads, cfg.hd)
        vc = (memory @ layer["cross"]["wv"]).reshape(b, sm, cfg.num_kv_heads, cfg.hd)
        kc = shard_activation(kc, ("pod", "data"), "model", None, None)
        vc = shard_activation(vc, ("pod", "data"), "model", None, None)
        out = attn_lib.chunked_attention(qc, kc, vc, causal=False,
                                         q_chunk=cfg.attn_q_chunk)
        x = x + out.reshape(b, s, cfg.num_heads * cfg.hd) @ layer["cross"]["wo"]
        x = x + mlp(layer["mlp"], norm(layer["mlp_norm"], x), cfg.activation)
        return (x,), kv_out

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    if mode == "decode":
        (x,), (ks, vs) = maybe_scan(
            body, (x,), (params["dec_layers"], cache["k"], cache["v"]),
            unroll=not cfg.scan_layers)
    else:
        (x,), (ks, vs) = maybe_scan(body, (x,), params["dec_layers"],
                                    unroll=not cfg.scan_layers)
    x = norm(params["final_norm"], x)
    w = shard_activation(params["lm_head"], None, "model")
    logits = shard_activation(x @ w.astype(x.dtype),
                              ("pod", "data"), None, "model")
    return logits.astype(jnp.float32), (ks, vs)


def forward(cfg: ModelConfig, params: Params, batch_inputs):
    """batch_inputs: {"frames": (B,S_enc,D), "tokens": (B,S_dec)}."""
    memory = encode(cfg, params, batch_inputs["frames"])
    logits, _ = _decoder(cfg, params, batch_inputs["tokens"], memory, "train")
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    logits, _ = forward(cfg, params, batch)
    return softmax_cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    c = attn_lib.init_kv_cache(cfg.num_layers, batch, max_len,
                               cfg.num_kv_heads, cfg.hd, cfg.cdtype)
    c["memory"] = jnp.zeros((batch, cfg.frontend_seq or 1, cfg.d_model), cfg.cdtype)
    return c


def prefill(cfg: ModelConfig, params: Params, inputs, cache):
    """inputs: {"frames", "tokens"} — encode then decoder-prefill."""
    memory = encode(cfg, params, inputs["frames"])
    tokens = inputs["tokens"]
    logits, (ks, vs) = _decoder(cfg, params, tokens, memory, "prefill")
    s = tokens.shape[1]
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["len"] = jnp.asarray(s, jnp.int32)
    cache["memory"] = memory
    return logits[:, -1:], cache


def decode_step(cfg: ModelConfig, params: Params, tokens, cache):
    logits, (ks, vs) = _decoder(cfg, params, tokens, cache["memory"], "decode",
                                cache=cache)
    cache = dict(cache)
    cache["k"], cache["v"] = ks, vs
    cache["len"] = cache["len"] + 1
    return logits, cache
