"""Mixture-of-Experts FFN with sort-based token dispatch.

Designed for very large expert counts (kimi-k2: 384 experts, top-8) where
the classic GShard one-hot dispatch einsum — O(T·E·C) memory — is
infeasible at 1M tokens. Instead, (token, choice) pairs are sorted by
expert id, positions within each expert are computed from the sorted
order, and tokens are scattered into a capacity-bounded (E, C, D) buffer
(dropping overflow, standard capacity-factor semantics). Cost:
O(T·K log(T·K)) sort + O(T·K·D) gather/scatter + O(E·C·D) buffer; the
buffer is sharded E→'model' (expert parallelism) and C→'data' so the
scatter lowers to the expected all-to-all on a 2-D mesh.

The router is in f32 (softmax over experts is precision-sensitive), with
an optional auxiliary load-balancing loss (Switch-style).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import GATED_ACTIVATIONS, activation_fn, dense_init

# jax.shard_map landed after 0.4.x (older releases ship it under
# jax.experimental.shard_map), and the check_rep→check_vma kwarg rename
# happened in a separate release — so detect the kwarg on whichever
# function exists rather than keying one off the other.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - exercised only on older jax
    from jax.experimental.shard_map import shard_map as _shard_map
import inspect as _inspect

_SHARD_MAP_KW = (
    {"check_vma": False}
    if "check_vma" in _inspect.signature(_shard_map).parameters
    else {"check_rep": False}
)


def init_moe(key, d_model: int, num_experts: int, expert_d_ff: int,
             activation: str, dtype, router_dtype=jnp.float32) -> dict:
    kr, k1, k2 = jax.random.split(key, 3)
    gated = activation in GATED_ACTIVATIONS
    scale_in = 1.0 / math.sqrt(d_model)
    scale_out = 1.0 / math.sqrt(expert_d_ff)
    if gated:
        # (E, D, 2, F): gate/up stacked on a separate axis so an F-shard
        # (the FSDP axis) always holds ALIGNED gate/up pairs — required by
        # the token-routed decode path, which computes with F-sharded
        # expert weights in place.
        wi = jax.random.normal(k1, (num_experts, d_model, 2, expert_d_ff))
    else:
        wi = jax.random.normal(k1, (num_experts, d_model, expert_d_ff))
    return {
        "router": dense_init(kr, d_model, num_experts, router_dtype),
        "wi": (wi * scale_in).astype(dtype),
        "wo": (jax.random.normal(k2, (num_experts, expert_d_ff, d_model)) * scale_out).astype(dtype),
    }


def expert_capacity(num_tokens: int, num_experts: int, top_k: int,
                    capacity_factor: float, multiple_of: int = 8) -> int:
    cap = math.ceil(num_tokens * top_k * capacity_factor / num_experts)
    return max(multiple_of, multiple_of * math.ceil(cap / multiple_of))


def moe_ffn(
    p: dict,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    activation: str = "silu",
    shard_experts: Optional[str] = "model",
    shard_capacity: Optional[str] = "data",
    return_aux: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Routed expert FFN. x: (B, S, D) → (B, S, D), aux-loss scalar.

    Two execution paths:
      * **shard_map** (used whenever an ambient mesh with a "model" axis is
        present and divides the expert count): tokens are replicated across
        the model axis within each data column, so each model rank
        dispatches *locally* to the experts it owns — zero dispatch
        collectives — computes them with FSDP-gathered weights, and a
        single psum over "model" combines. This is the production path;
        letting GSPMD partition the global formulation instead replicates
        the (T·K, D) dispatch tensors on every device (240 GB for kimi-k2).
      * **global** (no mesh — CPU tests, single device): sort-based
        dispatch into a capacity-bounded (E, C, D) buffer.
    """
    from repro.distributed.constraint import ambient_mesh

    mesh = ambient_mesh()
    e = p["router"].shape[-1]
    if mesh is not None and "model" in mesh.axis_names:
        model_n = mesh.shape["model"]
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp_n = 1
        for a in dp_axes:
            dp_n *= mesh.shape[a]
        t = x.shape[0] * x.shape[1]
        if e % model_n == 0 and t % max(dp_n, 1) == 0:
            return _moe_shard_map(
                p, x, mesh=mesh, dp_axes=dp_axes, top_k=top_k,
                capacity_factor=capacity_factor, activation=activation,
                return_aux=return_aux)
    return _moe_global(
        p, x, top_k=top_k, capacity_factor=capacity_factor,
        activation=activation, shard_experts=shard_experts,
        shard_capacity=shard_capacity, return_aux=return_aux)


def _local_dispatch_compute(xf, router, wi, wo, *, e_loc, e_lo, top_k, cap,
                            activation, return_aux, n_model):
    """Per-device MoE: local sort-based dispatch over the owned experts.

    xf: (T_loc, D) tokens of this data column (replicated over model);
    wi: (E_loc, D, Wio) / wo: (E_loc, F, D) — this model rank's experts.
    Returns (partial y (T_loc, D) — caller psums over "model", aux).
    """
    t_loc, d = xf.shape
    act = activation_fn(activation)

    logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)  # (T_loc, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # keep only choices routed to experts this model rank owns; the rest
    # go to an overflow bucket (e_loc) that is dropped.
    flat_e = expert_idx.reshape(t_loc * top_k).astype(jnp.int32)
    mine = (flat_e >= e_lo) & (flat_e < e_lo + e_loc)
    local_e = jnp.where(mine, flat_e - e_lo, e_loc)

    sort_order = jnp.argsort(local_e)
    sorted_e = local_e[sort_order]
    expert_start = jnp.searchsorted(sorted_e, jnp.arange(e_loc + 1, dtype=jnp.int32))
    pos_in_expert = jnp.arange(t_loc * top_k, dtype=jnp.int32) - expert_start[
        jnp.clip(sorted_e, 0, e_loc)]
    keep = (pos_in_expert < cap) & (sorted_e < e_loc)
    slot = jnp.where(keep, pos_in_expert, 0)
    token_of = sort_order // top_k

    gathered = xf[token_of] * keep[:, None].astype(xf.dtype)
    expert_in = jnp.zeros((e_loc + 1, cap, d), dtype=xf.dtype)
    expert_in = expert_in.at[jnp.clip(sorted_e, 0, e_loc), slot].add(
        gathered, mode="drop")[:e_loc]

    if wi.ndim == 4:  # gated: (E, D, 2, F) — works with full or sharded F
        h = jnp.einsum("ecd,edgf->ecgf", expert_in, wi)
        h = act(h[:, :, 0]) * h[:, :, 1]
    else:
        h = act(jnp.einsum("ecd,edf->ecf", expert_in, wi))
    expert_out = jnp.einsum("ecf,efd->ecd", h, wo)

    contrib = expert_out[jnp.clip(sorted_e, 0, e_loc - 1), slot]
    w = gate_vals.reshape(t_loc * top_k)[sort_order].astype(contrib.dtype)
    contrib = contrib * (w * keep.astype(contrib.dtype))[:, None]
    y = jnp.zeros((t_loc, d), dtype=contrib.dtype)
    y = y.at[token_of].add(contrib)

    if return_aux:
        e = router.shape[-1]
        me = jnp.mean(probs, axis=0)
        pe = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32),
                      axis=0)
        aux = e * jnp.sum(me * pe)
    else:
        aux = jnp.zeros((), jnp.float32)
    return y, aux


def _moe_shard_map(p, x, *, mesh, dp_axes, top_k, capacity_factor,
                   activation, return_aux):
    """Dispatch between the two shard_map execution plans by napkin math.

    * **weight-gather plan** (train/prefill, T large): each model rank
      FSDP-gathers its experts' weights over the DP axes, dispatches its
      own data column's tokens locally (tokens are model-replicated), one
      psum over "model" combines. Collective bytes ≈ expert_params/model_n
      per device per layer.
    * **token-route plan** (decode, T small): weights stay fully sharded
      (E→model, F→data); the (tiny) token batch is all-gathered over DP,
      every device computes its (expert, F-shard) contribution, one psum
      over (model ∪ dp) combines, each DP rank keeps its token slice.
      Collective bytes ≈ a few × T·D per device per layer — for kimi-k2
      decode_32k this replaces a 4.5 GB/layer weight gather with ~5 MB of
      token traffic (§Perf hillclimb).
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    t = b * s
    e = p["router"].shape[-1]
    model_n = mesh.shape["model"]
    dp_n = 1
    for a in dp_axes:
        dp_n *= mesh.shape[a]
    e_loc = e // model_n
    t_loc = t // max(dp_n, 1)
    xf = x.reshape(t, d)
    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    dsize = jnp.dtype(x.dtype).itemsize

    weight_gather_bytes = (p["wi"].size + p["wo"].size) * dsize // max(model_n, 1)
    token_route_bytes = 4 * t * d * dsize
    use_token_route = (dp_n > 1 and token_route_bytes < weight_gather_bytes)

    data_ax = "data" if "data" in mesh.axis_names else None
    wi_spec = (P("model", None, None, data_ax) if p["wi"].ndim == 4
               else P("model", None, data_ax))
    wo_spec = P("model", data_ax, None)

    if use_token_route:
        cap = expert_capacity(t, e, top_k, capacity_factor)

        def local_fn(xf_loc, router, wi_loc, wo_loc):
            x_all = jax.lax.all_gather(xf_loc, dp_axes, axis=0, tiled=True)
            m_idx = jax.lax.axis_index("model") if model_n > 1 else 0
            y_partial, aux = _local_dispatch_compute(
                x_all, router, wi_loc, wo_loc, e_loc=e_loc,
                e_lo=m_idx * e_loc, top_k=top_k, cap=cap,
                activation=activation, return_aux=return_aux,
                n_model=model_n)
            axes = (("model",) if model_n > 1 else ()) + dp_axes
            y_all = jax.lax.psum(y_partial, axes)  # combine experts + F shards
            r = jnp.zeros((), jnp.int32)
            for a in dp_axes:
                r = r * mesh.shape[a] + jax.lax.axis_index(a)
            y = jax.lax.dynamic_slice_in_dim(y_all, r * t_loc, t_loc, axis=0)
            return y, aux
    else:
        cap = expert_capacity(t_loc, e, top_k, capacity_factor)

        def local_fn(xf_loc, router, wi_loc, wo_loc):
            # FSDP: resolve this layer's expert weights (gather over DP)
            if dp_axes:
                wi_full = jax.lax.all_gather(wi_loc, dp_axes,
                                             axis=wi_loc.ndim - 1, tiled=True)
                wo_full = jax.lax.all_gather(wo_loc, dp_axes, axis=1, tiled=True)
            else:
                wi_full, wo_full = wi_loc, wo_loc
            m_idx = jax.lax.axis_index("model") if model_n > 1 else 0
            y_partial, aux = _local_dispatch_compute(
                xf_loc, router, wi_full, wo_full, e_loc=e_loc,
                e_lo=m_idx * e_loc, top_k=top_k, cap=cap,
                activation=activation, return_aux=return_aux,
                n_model=model_n)
            y = jax.lax.psum(y_partial, "model") if model_n > 1 else y_partial
            if return_aux and dp_axes:
                aux = jax.lax.pmean(aux, dp_axes)  # replicated along DP too
            return y, aux

    in_specs = (P(dp, None), P(None, None), wi_spec, wo_spec)
    out_specs = (P(dp, None), P())
    y, aux = _shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **_SHARD_MAP_KW)(xf, p["router"], p["wi"], p["wo"])
    return y.reshape(b, s, d).astype(x.dtype), aux


def _moe_global(
    p: dict,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    activation: str = "silu",
    shard_experts: Optional[str] = "model",
    shard_capacity: Optional[str] = "data",
    return_aux: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Global (no-mesh) path: sort-based dispatch with capacity."""
    b, s, d = x.shape
    t = b * s
    e = p["router"].shape[-1]
    cap = expert_capacity(t, e, top_k, capacity_factor)
    xf = x.reshape(t, d)

    from repro.distributed.constraint import shard_activation

    # ---- router (f32) ----
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    logits = shard_activation(logits, ("pod", "data"), None)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- sort (token, choice) pairs by expert ----
    flat_e = expert_idx.reshape(t * top_k).astype(jnp.int32)
    sort_order = jnp.argsort(flat_e)  # stable
    sorted_e = flat_e[sort_order]
    # first slot index of each expert in the sorted order
    expert_start = jnp.searchsorted(sorted_e, jnp.arange(e, dtype=jnp.int32))
    pos_in_expert = jnp.arange(t * top_k, dtype=jnp.int32) - expert_start[sorted_e]
    keep = pos_in_expert < cap
    slot = jnp.where(keep, pos_in_expert, 0)
    token_of = sort_order // top_k  # original token index per sorted pair

    # ---- dispatch: scatter tokens into the (E, C, D) buffer ----
    gathered = xf[token_of] * keep[:, None].astype(xf.dtype)
    # (T·K, D) rows in expert-sorted order: keep them sharded over the DP
    # axes — unconstrained, GSPMD replicates this tensor (T·K·D bytes on
    # every device; 240 GB for kimi-k2 at 1M tokens).
    gathered = shard_activation(gathered, ("pod", "data"), None)
    expert_in = jnp.zeros((e, cap, d), dtype=x.dtype)
    expert_in = expert_in.at[sorted_e, slot].add(gathered, mode="drop")
    expert_in = _shard(expert_in, (shard_experts, shard_capacity, None))

    # ---- expert computation ----
    act = activation_fn(activation)
    wi = p["wi"]
    if wi.ndim == 4:  # gated storage (E, D, 2, F) → fused (E, D, 2F)
        wi = wi.reshape(wi.shape[0], wi.shape[1], -1)
    h = jnp.einsum("ecd,edf->ecf", expert_in, wi)
    if activation in GATED_ACTIVATIONS:
        g, u = jnp.split(h, 2, axis=-1)
        h = act(g) * u
    else:
        h = act(h)
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    expert_out = _shard(expert_out, (shard_experts, shard_capacity, None))

    # ---- combine: gather back and weight by (renormalized) gates ----
    contrib = expert_out[sorted_e, slot]  # (T·K, D)
    contrib = shard_activation(contrib, ("pod", "data"), None)
    w = gate_vals.reshape(t * top_k)[sort_order].astype(contrib.dtype)
    contrib = contrib * (w * keep.astype(contrib.dtype))[:, None]
    y = jnp.zeros((t, d), dtype=contrib.dtype)
    y = y.at[token_of].add(contrib)
    y = shard_activation(y, ("pod", "data"), None)

    # ---- Switch-style load-balance auxiliary loss ----
    if return_aux:
        me = jnp.mean(probs, axis=0)  # mean router prob per expert
        pe = jnp.mean(
            jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0
        )  # fraction of tokens whose top-1 is e
        aux = e * jnp.sum(me * pe)
    else:
        aux = jnp.zeros((), jnp.float32)
    return y.reshape(b, s, d).astype(x.dtype), aux


def _ambient_mesh():
    """The mesh of the enclosing ``with mesh:`` / ``set_mesh`` scope, or None."""
    import warnings

    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:
            return am
    except Exception:
        pass
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        try:
            mesh = jax.interpreters.pxla.thread_resources.env.physical_mesh
        except Exception:
            return None
    return None if mesh.empty else mesh


def _shard(x: jax.Array, spec_axes: tuple) -> jax.Array:
    """Best-effort sharding constraint: apply only axes the ambient mesh has."""
    from jax.sharding import PartitionSpec as P

    mesh = _ambient_mesh()
    if mesh is None:
        return x
    axes = tuple(a if (a in mesh.axis_names) else None for a in spec_axes)
    if all(a is None for a in axes):
        return x
    # avoid over-sharding tiny dims
    fixed = []
    for dim, a in zip(x.shape, axes):
        if a is not None and dim % mesh.shape[a] == 0:
            fixed.append(a)
        else:
            fixed.append(None)
    return jax.lax.with_sharding_constraint(x, P(*fixed))
