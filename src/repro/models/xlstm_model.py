"""xLSTM LM (xLSTM[7:1]): super-blocks of ``mlstm_per_slstm`` mLSTM blocks
followed by one sLSTM block, scanned at both levels (outer scan over
super-blocks, inner scan over the mLSTM stack) so depth adds no HLO."""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import xlstm as xl
from repro.models.layers import (
    dense_init,
    embed_init,
    make_norm,
    softmax_cross_entropy,
)
from repro.utils.scan import maybe_scan
from repro.distributed.constraint import shard_activation

Params = Dict[str, Any]


def block_counts(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_super, n_mlstm_per_super). num_layers must divide evenly."""
    per = cfg.mlstm_per_slstm + 1
    if cfg.num_layers % per:
        raise ValueError(
            f"{cfg.name}: num_layers={cfg.num_layers} not divisible by "
            f"mlstm_per_slstm+1={per}")
    return cfg.num_layers // per, cfg.mlstm_per_slstm


def init_params(cfg: ModelConfig, key) -> Params:
    init_norm, _ = make_norm(cfg.norm)
    n_super, n_m = block_counts(cfg)
    k_emb, k_m, k_s, k_head = jax.random.split(key, 4)

    def init_m(k):
        return {
            "norm": init_norm(cfg.d_model, cfg.dtype),
            "mlstm": xl.init_mlstm(k, cfg.d_model, cfg.num_heads, cfg.dtype),
        }

    def init_s(k):
        return {
            "norm": init_norm(cfg.d_model, cfg.dtype),
            "slstm": xl.init_slstm(k, cfg.d_model, cfg.num_heads, cfg.dtype),
        }

    m_keys = jax.random.split(k_m, n_super * n_m).reshape(n_super, n_m, 2)
    s_keys = jax.random.split(k_s, n_super)
    return {
        "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "m_blocks": jax.vmap(jax.vmap(init_m))(m_keys),  # (n_super, n_m, ...)
        "s_blocks": jax.vmap(init_s)(s_keys),  # (n_super, ...)
        "final_norm": init_norm(cfg.d_model, cfg.dtype),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab_size, cfg.dtype,
                              scale=1.0 / math.sqrt(cfg.d_model)),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0) -> Dict[str, Any]:
    """Recurrent state; ``max_len`` is ignored (O(1)-in-seq states)."""
    n_super, n_m = block_counts(cfg)
    m_state = jax.vmap(jax.vmap(
        lambda _: xl.init_mlstm_state(batch, cfg.d_model, cfg.num_heads, cfg.cdtype)
    ))(jnp.zeros((n_super, n_m)))
    s_state = jax.vmap(
        lambda _: xl.init_slstm_state(batch, cfg.d_model, cfg.num_heads)
    )(jnp.zeros((n_super,)))
    return {"m": m_state, "s": s_state, "len": jnp.zeros((), jnp.int32)}


def _run(cfg: ModelConfig, params: Params, tokens, cache, with_state: bool):
    _, norm = make_norm(cfg.norm)
    x = shard_activation((params["embed"][tokens]).astype(cfg.cdtype),
                         ("pod", "data"), None, None)

    def super_body(carry, inp):
        (x,) = carry
        m_params, s_params, m_state, s_state = inp

        def m_body(xc, minp):
            mp, mst = minp
            h, new_st = xl.mlstm_block(
                mp["mlstm"], norm(mp["norm"], xc), cfg.num_heads,
                state=mst if with_state else None, chunk=cfg.attn_q_chunk)
            return xc + h, new_st

        x, new_m = maybe_scan(m_body, x, (m_params, m_state),
                              unroll=not cfg.scan_layers)
        h, new_s = xl.slstm_block(
            s_params["slstm"], norm(s_params["norm"], x), cfg.num_heads,
            state=s_state if with_state else None)
        x = x + h
        return (x,), (new_m, new_s)

    if cfg.remat:
        super_body = jax.checkpoint(
            super_body, policy=jax.checkpoint_policies.nothing_saveable)
    (x,), (new_m, new_s) = maybe_scan(
        super_body, (x,),
        (params["m_blocks"], params["s_blocks"], cache["m"], cache["s"]),
        unroll=not cfg.scan_layers)
    x = norm(params["final_norm"], x)
    w = shard_activation(params["lm_head"], None, "model")
    logits = shard_activation(x @ w.astype(x.dtype),
                              ("pod", "data"), None, "model")
    return logits.astype(jnp.float32), new_m, new_s


def forward(cfg: ModelConfig, params: Params, tokens) -> Tuple[jax.Array, jax.Array]:
    cache = init_cache(cfg, tokens.shape[0])
    logits, _, _ = _run(cfg, params, tokens, cache, with_state=False)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: Params, batch) -> jax.Array:
    logits, _ = forward(cfg, params, batch.get("inputs", batch.get("tokens")))
    return softmax_cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


def prefill(cfg: ModelConfig, params: Params, tokens, cache):
    logits, new_m, new_s = _run(cfg, params, tokens, cache, with_state=True)
    new_cache = {"m": new_m, "s": new_s, "len": cache["len"] + tokens.shape[1]}
    return logits[:, -1:], new_cache


def decode_step(cfg: ModelConfig, params: Params, tokens, cache):
    logits, new_m, new_s = _run(cfg, params, tokens, cache, with_state=True)
    new_cache = {"m": new_m, "s": new_s, "len": cache["len"] + 1}
    return logits, new_cache
