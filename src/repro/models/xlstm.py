"""xLSTM blocks (Beck et al., 2024): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential scan), composed 7:1 as in xLSTM[7:1].

mLSTM parallel form (per head, queries i, keys j ≤ i):

    b_ij = F_i − F_j + log i_j          (F = cumsum of log-sigmoid forget)
    m_i  = max_j b_ij                   (stabilizer)
    ŷ_i  = Σ_j exp(b_ij − m_i) (q_i·k_j/√d) v_j
    n_i  = max(|Σ_j exp(b_ij − m_i)(q_i·k_j/√d)|, exp(−m_i))
    y_i  = ŷ_i / n_i

This is attention-shaped (quadratic in S with a decay bias instead of
softmax), so train/prefill use a chunked form; decode uses the O(1)
recurrent cell with state (C: d×d matrix, n: d vector, m: scalar) per head.

sLSTM uses exponential gating with a stabilizer and a per-head recurrent
matrix; it is inherently sequential → ``lax.scan`` over time.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

NEG_INF = -1e30


# ------------------------------------------------------------------- mLSTM
def init_mlstm(key, d_model: int, num_heads: int, dtype,
               proj_factor: float = 2.0, conv_width: int = 4) -> dict:
    d_inner = int(proj_factor * d_model)
    hd = d_inner // num_heads
    ks = jax.random.split(key, 8)

    def headwise(k):  # block-diagonal per-head projection (H, hd, hd)
        return (jax.random.normal(k, (num_heads, hd, hd))
                / math.sqrt(hd)).astype(dtype)

    return {
        "up_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype),  # [x | gate z]
        "conv": (jax.random.normal(ks[1], (conv_width, d_inner)) * 0.1).astype(dtype),
        "conv_bias": jnp.zeros((d_inner,), dtype=dtype),
        "wq": headwise(ks[2]),
        "wk": headwise(ks[3]),
        "wv": headwise(ks[4]),
        "w_if": dense_init(ks[5], d_inner, 2 * num_heads, jnp.float32),
        "b_if": jnp.concatenate(
            [jnp.zeros((num_heads,)), 3.0 * jnp.ones((num_heads,))]
        ).astype(jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype=dtype),
        "down_proj": dense_init(ks[6], d_inner, d_model, dtype,
                                scale=1.0 / math.sqrt(d_inner)),
    }


def _mlstm_parallel(q, k, v, log_i, log_f, chunk: int = 512):
    """q/k/v: (B,S,H,D); log_i/log_f: (B,S,H) → y (B,S,H,D). f32 internal."""
    b, s, h, d = q.shape
    qf = q.astype(jnp.float32) / math.sqrt(d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    fcum = jnp.cumsum(log_f, axis=1)  # (B,S,H) = F_i

    def attend(q_blk, fcum_q, idx0):
        """q_blk (B,C,H,D); fcum_q (B,C,H); returns y for one query chunk."""
        c = q_blk.shape[1]
        bmat = (fcum_q[:, :, None, :] - fcum[:, None, :, :]
                + log_i[:, None, :, :])  # (B,C,S,H)
        qpos = idx0 + jnp.arange(c)[:, None]
        kpos = jnp.arange(s)[None, :]
        mask = (kpos <= qpos)[None, :, :, None]
        bmat = jnp.where(mask, bmat, NEG_INF)
        m = jnp.max(bmat, axis=2)  # (B,C,H)
        dmat = jnp.exp(bmat - m[:, :, None, :])
        scores = jnp.einsum("bchd,bshd->bcsh", q_blk.astype(jnp.float32)
                            / math.sqrt(d), kf)
        cmat = scores * dmat
        num = jnp.einsum("bcsh,bshd->bchd", cmat, vf)
        den = jnp.abs(jnp.sum(cmat, axis=2))  # (B,C,H)
        den = jnp.maximum(den, jnp.exp(-m))
        return num / den[..., None]

    if s <= chunk:
        y = attend(q, fcum, 0)
    else:
        nc = math.ceil(s / chunk)
        pad = nc * chunk - s
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        fp = jnp.pad(fcum, ((0, 0), (0, pad), (0, 0)))
        qs = qp.reshape(b, nc, chunk, h, d).transpose(1, 0, 2, 3, 4)
        fs = fp.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)

        def body(_, inp):
            qc, fc, i = inp
            return (), attend(qc, fc, i * chunk)

        _, ys = jax.lax.scan(body, (), (qs, fs, jnp.arange(nc)))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, d)[:, :s]
    return y.astype(q.dtype)


def mlstm_step(state: dict, q, k, v, log_i, log_f):
    """Recurrent mLSTM cell. state: {"C": (B,H,D,D), "n": (B,H,D),
    "m": (B,H)}; q/k/v: (B,H,D); log_i/log_f: (B,H)."""
    d = q.shape[-1]
    qf = q.astype(jnp.float32) / math.sqrt(d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    m_new = jnp.maximum(log_f + state["m"], log_i)
    f_sc = jnp.exp(log_f + state["m"] - m_new)
    i_sc = jnp.exp(log_i - m_new)
    c_new = (state["C"] * f_sc[..., None, None]
             + i_sc[..., None, None] * kf[..., :, None] * vf[..., None, :])
    n_new = state["n"] * f_sc[..., None] + i_sc[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new)),
                      jnp.exp(-m_new))
    y = num / den[..., None]
    return y, {"C": c_new, "n": n_new, "m": m_new}


def mlstm_block(p: dict, x: jax.Array, num_heads: int,
                state: Optional[dict] = None, chunk: int = 512,
                ) -> Tuple[jax.Array, Optional[dict]]:
    """Full mLSTM block. x: (B,S,D). state enables streaming decode."""
    b, s, d_model = x.shape
    hd = p["wq"].shape[-1]
    d_inner = num_heads * hd

    up = x @ p["up_proj"]
    xi, z = jnp.split(up, 2, axis=-1)
    # causal conv on the x-branch
    width = p["conv"].shape[0]
    if state is not None:
        padded = jnp.concatenate([state["conv"].astype(xi.dtype), xi], axis=1)
    else:
        padded = jnp.pad(xi, ((0, 0), (width - 1, 0), (0, 0)))
    xc = sum(padded[:, i:i + s] * p["conv"][i][None, None, :] for i in range(width))
    xc = jax.nn.silu(xc + p["conv_bias"][None, None, :])
    new_conv = padded[:, -(width - 1):]

    xc_h = xc.reshape(b, s, num_heads, hd)
    xi_h = xi.reshape(b, s, num_heads, hd)
    q = jnp.einsum("bshd,hde->bshe", xc_h, p["wq"])
    k = jnp.einsum("bshd,hde->bshe", xc_h, p["wk"])
    v = jnp.einsum("bshd,hde->bshe", xi_h, p["wv"])
    gates = xc.astype(jnp.float32) @ p["w_if"] + p["b_if"][None, None, :]
    log_i, f_pre = jnp.split(gates, 2, axis=-1)  # (B,S,H) each
    log_f = jax.nn.log_sigmoid(f_pre)

    if s == 1 and state is not None:
        y, cell = mlstm_step(state["cell"], q[:, 0], k[:, 0], v[:, 0],
                             log_i[:, 0], log_f[:, 0])
        y = y[:, None]
    else:
        y = _mlstm_parallel(q, k, v, log_i, log_f, chunk=chunk)
        cell = None
        if state is not None:  # prefill that must hand off a decode state
            cell = _mlstm_final_state(k, v, log_i, log_f)
    y = y.reshape(b, s, d_inner)
    # per-block RMS norm + output gating, down-projection
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    yf = yf * jax.nn.silu(z.astype(jnp.float32))
    out = yf.astype(x.dtype) @ p["down_proj"]
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv, "cell": cell}
    return out, new_state


def _mlstm_final_state(k, v, log_i, log_f):
    """Fold a whole prefix into the recurrent state (used at prefill→decode)."""
    b, s, h, d = k.shape
    fcum = jnp.cumsum(log_f, axis=1)
    ftot = fcum[:, -1]  # (B,H)
    w = ftot[:, None, :] - fcum + log_i  # decay from j to end
    m = jnp.max(w, axis=1)  # (B,H)
    scale = jnp.exp(w - m[:, None, :])
    c = jnp.einsum("bsh,bshd,bshe->bhde", scale, k.astype(jnp.float32),
                   v.astype(jnp.float32))
    n = jnp.einsum("bsh,bshd->bhd", scale, k.astype(jnp.float32))
    return {"C": c, "n": n, "m": m}


def init_mlstm_state(bsz: int, d_model: int, num_heads: int, dtype,
                     proj_factor: float = 2.0, conv_width: int = 4) -> dict:
    d_inner = int(proj_factor * d_model)
    hd = d_inner // num_heads
    return {
        "conv": jnp.zeros((bsz, conv_width - 1, d_inner), dtype),
        "cell": {
            "C": jnp.zeros((bsz, num_heads, hd, hd), jnp.float32),
            "n": jnp.zeros((bsz, num_heads, hd), jnp.float32),
            "m": jnp.full((bsz, num_heads), -1e30, jnp.float32),
        },
    }


# ------------------------------------------------------------------- sLSTM
def init_slstm(key, d_model: int, num_heads: int, dtype) -> dict:
    hd = d_model // num_heads
    ks = jax.random.split(key, 3)
    # fused input weights for z,i,f,o and per-head recurrent weights
    return {
        "w_in": dense_init(ks[0], d_model, 4 * d_model, dtype),
        "r": (jax.random.normal(ks[1], (num_heads, hd, 4 * hd))
              / math.sqrt(hd)).astype(dtype),
        "b": jnp.concatenate(
            [jnp.zeros((2 * d_model,)), 3.0 * jnp.ones((d_model,)),
             jnp.zeros((d_model,))]).astype(jnp.float32),
        "norm_scale": jnp.ones((d_model,), dtype=dtype),
        "out_proj": dense_init(ks[2], d_model, d_model, dtype),
    }


def slstm_block(p: dict, x: jax.Array, num_heads: int,
                state: Optional[dict] = None,
                ) -> Tuple[jax.Array, Optional[dict]]:
    """sLSTM block: sequential scan over time. x: (B,S,D)."""
    b, s, d_model = x.shape
    hd = d_model // num_heads
    wx = (x @ p["w_in"]).astype(jnp.float32)  # (B,S,4D)

    if state is None:
        st = init_slstm_state(b, d_model, num_heads)
    else:
        st = state

    rw = p["r"].astype(jnp.float32)  # (H, hd, 4hd)
    bias = p["b"]

    bz, bi, bf, bo = jnp.split(bias, 4)

    def rs(a):  # (B, D) -> (B, H, hd)
        return a.reshape(b, num_heads, hd)

    def step(carry, wx_t):
        c, n, h, m = carry  # each (B, H, hd)
        rec = jnp.einsum("bhd,hde->bhe", h, rw)  # (B, H, 4hd), [z|i|f|o]
        rz, ri, rf, ro = jnp.split(rec, 4, axis=-1)
        xz, xi, xf, xo = jnp.split(wx_t, 4, axis=-1)  # each (B, D)
        z = jnp.tanh(rs(xz) + rz + bz.reshape(1, num_heads, hd))
        log_i = rs(xi) + ri + bi.reshape(1, num_heads, hd)
        log_f = jax.nn.log_sigmoid(rs(xf) + rf + bf.reshape(1, num_heads, hd))
        o = jax.nn.sigmoid(rs(xo) + ro + bo.reshape(1, num_heads, hd))
        m_new = jnp.maximum(log_f + m, log_i)  # per-unit stabilizer
        i_sc = jnp.exp(log_i - m_new)
        f_sc = jnp.exp(log_f + m - m_new)
        c_new = f_sc * c + i_sc * z
        n_new = f_sc * n + i_sc
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    carry0 = (st["c"], st["n"], st["h"], st["m"])
    carry, hs = jax.lax.scan(step, carry0, wx.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d_model)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    out = yf.astype(x.dtype) @ p["out_proj"]
    new_state = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    return out, new_state


def init_slstm_state(bsz: int, d_model: int, num_heads: int) -> dict:
    hd = d_model // num_heads
    return {
        "c": jnp.zeros((bsz, num_heads, hd), jnp.float32),
        "n": jnp.zeros((bsz, num_heads, hd), jnp.float32),
        "h": jnp.zeros((bsz, num_heads, hd), jnp.float32),
        "m": jnp.zeros((bsz, num_heads, hd), jnp.float32),
    }
