"""Heterogeneous fleet tiers: TierSpec + TieredPlatform.

The single :class:`~repro.serverless.platform.ServerlessPlatform` models
one container type; real deployments mix tiers with distinct cost and
latency curves — cheap-slow vs expensive-fast instance families, and
spot-style *preemptible* capacity that the provider can reclaim
mid-batch. :class:`TierSpec` declares one such tier;
:class:`TieredPlatform` owns one ``ServerlessPlatform`` per tier behind
the same submit/conservation surface, so every driver that speaks to a
platform (simulators, benches, chaos suites) works unchanged against a
tiered fleet.

Cost is tracked per tier as a billable-seconds integral and combined
through each tier's ``cost_weight`` (relative $/container-second):
``cost_integral = Σ_tier weight × container_seconds``. The conservation
invariant — ``submitted == completed + queued + inflight`` with zero
lost and zero duplicated batches — is checkable *per tier* and in
aggregate (:meth:`TieredPlatform.assert_conserved` does both), plus one
tier-boundary identity: every batch submitted to the TieredPlatform
landed on exactly one member tier.

Determinism: a 1-tier fleet reuses the caller's RNG streams untouched
and is byte-identical to an untirered ``ServerlessPlatform`` run; an
N-tier fleet shares the service stream (draws happen in event order
regardless of tier) but spawns one fault stream per tier, so chaos on
one tier cannot shift fault draws on another.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.frontend import SpilloverRouter, TierRoute
from repro.core.request import Batch
from repro.serverless.latency import LatencyModel, ScaledLatency
from repro.serverless.platform import PlatformConfig, ServerlessPlatform
from repro.simulation.events import EventQueue


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One fleet tier: its economics, its fleet shape, its router guards.

    ``platform`` overrides the fleet-wide base :class:`PlatformConfig`
    (None = inherit); ``capacity`` caps the tier's ``max_scale`` on top
    of whichever config applies. Latency comes from ``latency`` (an
    explicit per-tier model) or ``latency_scale`` applied to the shared
    base model (1.0 = identical to base). ``preemptible`` tiers lose
    billable containers mid-batch with probability ``preempt_prob`` per
    attempt (the platform's ``preempt`` fault; requeued through the
    attempt ledger, never lost). The ``max_inflight`` /
    ``queue_depth_max`` / ``latency_threshold`` guards feed the
    :class:`~repro.core.frontend.SpilloverRouter` (0 disables each).
    """

    name: str
    cost_weight: float = 1.0
    platform: Optional[PlatformConfig] = None
    latency: Optional[LatencyModel] = None
    latency_scale: float = 1.0
    capacity: Optional[int] = None
    preemptible: bool = False
    preempt_prob: float = 0.0
    max_inflight: int = 0
    queue_depth_max: int = 0
    latency_threshold: float = 0.0

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("TierSpec needs a non-empty name")
        if self.cost_weight <= 0:
            raise ValueError(f"tier {self.name!r}: cost_weight must be > 0")
        if self.latency_scale <= 0:
            raise ValueError(f"tier {self.name!r}: latency_scale must be > 0")
        if not 0.0 <= self.preempt_prob <= 1.0:
            raise ValueError(f"tier {self.name!r}: preempt_prob not in [0,1]")
        if self.preempt_prob > 0 and not self.preemptible:
            raise ValueError(
                f"tier {self.name!r}: preempt_prob > 0 requires preemptible")

    def as_route(self) -> TierRoute:
        """The router-facing slice of this spec."""
        return TierRoute(
            name=self.name, cost_weight=self.cost_weight,
            max_inflight=self.max_inflight,
            queue_depth_max=self.queue_depth_max,
            latency_threshold=self.latency_threshold)

    def effective_config(self, base: PlatformConfig) -> PlatformConfig:
        """Resolve the tier's PlatformConfig against the fleet base."""
        cfg = self.platform if self.platform is not None else base
        overrides: dict = {}
        if self.capacity is not None:
            overrides["max_scale"] = self.capacity
        if self.preemptible and self.preempt_prob > 0:
            overrides["preempt_prob_per_batch"] = self.preempt_prob
        return dataclasses.replace(cfg, **overrides) if overrides else cfg

    def effective_latency(self, base: LatencyModel) -> LatencyModel:
        """Resolve the tier's latency model against the fleet base."""
        if self.latency is not None:
            return self.latency
        if self.latency_scale != 1.0:
            return ScaledLatency(base=base, scale=self.latency_scale,
                                 name=f"{getattr(base, 'name', 'base')}"
                                      f"@{self.name}")
        return base


def routes_for(tiers: Sequence[TierSpec]) -> List[TierRoute]:
    """TierRoutes for a tier list (SpilloverRouter input)."""
    return [t.as_route() for t in tiers]


def make_router(tiers: Sequence[TierSpec], *,
                queue_probe: Optional[Callable[[str], int]] = None,
                tracer=None, **kwargs) -> SpilloverRouter:
    """A SpilloverRouter over ``tiers`` (cheapest-first preference)."""
    return SpilloverRouter(routes_for(tiers), queue_probe=queue_probe,
                           tracer=tracer, **kwargs)


class TieredPlatform:
    """N ServerlessPlatforms (one per tier) behind one platform surface.

    Batches arrive already stamped with ``batch.tier`` (by a
    :class:`~repro.core.frontend.SpilloverRouter` at the dispatch seam);
    unstamped batches land on the *default* tier — the cheapest by
    ``cost_weight`` — so a tier-oblivious driver degrades to a
    single-fleet run rather than erroring.
    """

    def __init__(
        self,
        tiers: Sequence[TierSpec],
        latency_model: LatencyModel,
        events: EventQueue,
        rng: np.random.Generator,
        on_batch_done: Callable[[Batch, float, float], None],
        base_config: Optional[PlatformConfig] = None,
        fault_rng: Optional[np.random.Generator] = None,
        tracer=None,
        recorder=None,
    ) -> None:
        """Mirror of ``ServerlessPlatform.__init__`` with ``tiers`` in
        place of a single config.

        RNG plumbing is the byte-identity seam: with one tier, ``rng``
        and ``fault_rng`` are handed to the member platform untouched
        (identical draw sequence to an untirered run); with N > 1 tiers
        the service ``rng`` is shared and ``fault_rng`` is spawned into
        one independent child stream per tier.
        """
        tiers = tuple(tiers)
        if not tiers:
            raise ValueError("TieredPlatform needs at least one tier")
        names = [t.name for t in tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        base = base_config if base_config is not None else PlatformConfig()
        self.tiers: Tuple[TierSpec, ...] = tiers
        self.specs: Dict[str, TierSpec] = {t.name: t for t in tiers}
        # cheapest tier wins the default slot (stable on cost ties)
        self.default_tier: str = min(
            tiers, key=lambda t: t.cost_weight).name
        self.events = events
        self.on_batch_done = on_batch_done

        shared_faults = fault_rng if fault_rng is not None else rng
        if len(tiers) == 1:
            fault_streams = [shared_faults]
        else:
            fault_streams = shared_faults.spawn(len(tiers))

        self.platforms: Dict[str, ServerlessPlatform] = {}
        for t, faults in zip(tiers, fault_streams):
            self.platforms[t.name] = ServerlessPlatform(
                config=t.effective_config(base),
                latency_model=t.effective_latency(latency_model),
                events=events,
                rng=rng,
                on_batch_done=on_batch_done,
                fault_rng=faults,
                tracer=tracer,
                recorder=recorder,
            )

        # tier-boundary ledger: every submit lands on exactly one tier
        self.submitted_batches = 0
        self.default_routed = 0  # batches that arrived with no tier stamp

    # ------------------------------------------------------------------ api
    def platform(self, tier: str) -> ServerlessPlatform:
        return self.platforms[tier]

    @property
    def tier_names(self) -> Tuple[str, ...]:
        return tuple(t.name for t in self.tiers)

    def start(self, now: float) -> None:
        for p in self.platforms.values():
            p.start(now)

    def submit(self, batch: Batch, now: float) -> None:
        """Route one upstream batch to its stamped (or default) tier."""
        tier = batch.tier
        if tier is None:
            batch.tier = tier = self.default_tier
            self.default_routed += 1
        try:
            plat = self.platforms[tier]
        except KeyError:
            raise KeyError(f"batch stamped with unknown tier {tier!r}; "
                           f"fleet has {sorted(self.platforms)}") from None
        self.submitted_batches += 1
        plat.submit(batch, now)

    def tier_queue_depth(self, tier: str) -> int:
        """Router queue probe: the tier's platform-side queue depth."""
        return self.platforms[tier].queued_batches

    # ------------------------------------------------------------ aggregates
    @property
    def billable_count(self) -> int:
        return sum(p.billable_count for p in self.platforms.values())

    def ready_count(self, now: float) -> int:
        return sum(p.ready_count(now) for p in self.platforms.values())

    @property
    def queued_batches(self) -> int:
        return sum(p.queued_batches for p in self.platforms.values())

    @property
    def cold_starts(self) -> int:
        return sum(p.cold_starts for p in self.platforms.values())

    @property
    def peak_containers(self) -> int:
        # sum of per-tier peaks (an upper bound on the fleet-wide peak:
        # tier peaks need not coincide in time)
        return sum(p.peak_containers for p in self.platforms.values())

    @property
    def container_seconds(self) -> float:
        """Unweighted billable-seconds integral across tiers."""
        return sum(p.container_seconds for p in self.platforms.values())

    @property
    def cost_integral(self) -> float:
        """Weighted cost: Σ tier ``cost_weight × container_seconds``."""
        return sum(self.specs[name].cost_weight * p.container_seconds
                   for name, p in self.platforms.items())

    def cost_by_tier(self) -> Dict[str, Dict[str, float]]:
        """Per-tier billing breakdown (seconds, weight, weighted cost)."""
        return {
            name: {
                "container_seconds": p.container_seconds,
                "cost_weight": self.specs[name].cost_weight,
                "cost_integral": (self.specs[name].cost_weight
                                  * p.container_seconds),
            }
            for name, p in self.platforms.items()
        }

    # --------------------------------------------------------------- billing
    def reset_billing(self, now: float) -> None:
        for p in self.platforms.values():
            p.reset_billing(now)

    def finalize(self, now: float) -> None:
        for p in self.platforms.values():
            p.finalize(now)

    def avg_containers(self, duration: float) -> float:
        """Unweighted average fleet size over ``duration``."""
        return self.container_seconds / duration if duration > 0 else 0.0

    def weighted_cost(self, duration: float) -> float:
        """Weighted cost rate over ``duration`` — the paper's "number of
        containers" metric with per-tier $-weights applied."""
        return self.cost_integral / duration if duration > 0 else 0.0

    # --------------------------------------------------------------- metrics
    def register_metrics(self, registry, prefix: str = "platform") -> None:
        """Bind per-tier ledgers plus the tier-boundary counters."""
        b = registry.bind
        b(f"{prefix}.submitted_batches", lambda: self.submitted_batches)
        b(f"{prefix}.default_routed", lambda: self.default_routed)
        b(f"{prefix}.cost_integral", lambda: self.cost_integral)
        for name, p in self.platforms.items():
            p.register_metrics(registry, prefix=f"{prefix}.{name}")

    # --------------------------------------------------------- conservation
    def conservation(self) -> dict:
        """Aggregate conservation ledger (key-wise sum over tiers)."""
        agg: Dict[str, int] = {}
        for p in self.platforms.values():
            for k, v in p.conservation().items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def conservation_by_tier(self) -> Dict[str, dict]:
        return {name: p.conservation()
                for name, p in self.platforms.items()}

    def assert_conserved(self, require_drained: bool = False) -> dict:
        """Per-tier AND aggregate conservation, plus the tier boundary.

        Raises ``AssertionError`` if any member tier violates its ledger
        invariant, or if the tier boundary leaked: the sum of per-tier
        submissions must equal the batches this TieredPlatform accepted
        (every batch landed on exactly one tier).
        """
        for name, p in self.platforms.items():
            try:
                p.assert_conserved(require_drained=require_drained)
            except AssertionError as exc:
                raise AssertionError(f"tier {name!r}: {exc}") from None
        agg = self.conservation()
        if agg["submitted_batches"] != self.submitted_batches:
            raise AssertionError(
                "tier boundary leak: platform accepted "
                f"{self.submitted_batches} batches but tiers saw "
                f"{agg['submitted_batches']}: {self.conservation_by_tier()}")
        accounted = (agg["completed_batches"] + agg["queued_batches"]
                     + agg["inflight_batches"])
        if accounted != agg["submitted_batches"]:
            raise AssertionError(
                f"aggregate conservation imbalance: {agg}")
        return agg
